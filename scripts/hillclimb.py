"""§Perf hillclimb driver: measure a cell under config overrides and append
hypothesis -> change -> before/after -> verdict entries to reports/perf_log.json.

    PYTHONPATH=src python scripts/hillclimb.py measure <arch> <shape> \
        [key=value ...]                       # ModelConfig/TrainConfig fields
    PYTHONPATH=src python scripts/hillclimb.py log <cell> <iter> \
        --hypothesis ... --change ... --before ... --after ... --verdict ...
"""
import os
# append, never overwrite: a caller's XLA_FLAGS must survive (RS004)
_FLAG = "--xla_force_host_platform_device_count=512"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()
import argparse
import dataclasses
import json
import sys

sys.path.insert(0, "src")

LOG = "reports/perf_log.json"


def _load():
    if os.path.exists(LOG):
        with open(LOG) as f:
            return json.load(f)
    return {"cells": {}}


def _save(log):
    os.makedirs("reports", exist_ok=True)
    with open(LOG, "w") as f:
        json.dump(log, f, indent=1)


def measure(arch, shape, overrides):
    from repro.configs import TrainConfig, get_config
    from repro.launch.dryrun import run_cell, _calibrate, lower_and_compile
    from repro.launch import dryrun

    cfg = get_config(arch)
    tkw, mkw = {}, {}
    tfields = {f.name for f in dataclasses.fields(TrainConfig)}
    for kv in overrides:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        (tkw if k in tfields else mkw)[k] = v
    cfg2 = dataclasses.replace(cfg, **mkw) if mkw else cfg
    tdefaults = dict(microbatches=8, remat="dots")
    tdefaults.update(tkw)
    tcfg = TrainConfig(**tdefaults) if tkw else None

    # run_cell but with overrides: reuse its internals
    import jax
    from repro.analysis.roofline import (measure_compiled, model_flops,
                                         roofline_terms)
    from repro.configs import SHAPES
    from repro.launch.mesh import make_mesh_named
    from repro.launch.specs import build_cell

    mesh = make_mesh_named("single")
    with mesh:
        cell = build_cell(arch, shape, mesh, cfg_override=cfg2, tcfg=tcfg)
        lowered, compiled, compile_s = lower_and_compile(cell)
        flops_raw, bytes_raw, coll_raw, memory = measure_compiled(compiled, mesh.size)
        # calibrated terms (same machinery as the sweep, with overrides)
        import repro.launch.dryrun as dr
        orig_get = dr.get_config
        try:
            dr.get_config = lambda name: cfg2   # calibration sees overrides
            flops, nbytes, wire, cc, cb = dr._calibrate(
                arch, shape, mesh, mesh.size, flops_raw, bytes_raw, coll_raw)
        finally:
            dr.get_config = orig_get
    terms = roofline_terms(flops, nbytes, wire)
    out = {
        "arch": arch, "shape": shape, "overrides": overrides,
        "compile_s": compile_s, "memory": memory,
        "terms": terms.to_dict(),
        "collective_bytes_gb": {k: v / 1e9 for k, v in cb.items()},
        "model_over_hlo": model_flops(get_config(arch), SHAPES[shape]) /
                          (flops * mesh.size) if flops else 0,
    }
    print(json.dumps(out, indent=1, default=float))
    return out


def main():
    if sys.argv[1] == "measure":
        measure(sys.argv[2], sys.argv[3], sys.argv[4:])
        return
    ap = argparse.ArgumentParser()
    ap.add_argument("cmd", choices=["log", "why", "summary"])
    ap.add_argument("cell")
    ap.add_argument("iter", nargs="?")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--change", default="")
    ap.add_argument("--before", default="")
    ap.add_argument("--after", default="")
    ap.add_argument("--verdict", default="")
    ap.add_argument("--text", default="")
    args = ap.parse_args()
    log = _load()
    cell = log["cells"].setdefault(args.cell, {"iterations": []})
    if args.cmd == "log":
        cell["iterations"].append({
            "cell": args.cell, "iter": args.iter,
            "hypothesis": args.hypothesis, "change": args.change,
            "before": args.before, "after": args.after,
            "verdict": args.verdict})
    elif args.cmd == "why":
        cell["why"] = args.text
    else:
        cell["summary"] = args.text
    _save(log)
    print("logged")


if __name__ == "__main__":
    main()
