"""Assemble EXPERIMENTS.md from dry-run reports + benchmark CSV + perf log.

    PYTHONPATH=src python scripts/make_experiments.py
"""
import json
import os
import sys

sys.path.insert(0, "src")

from repro.analysis.report import (dryrun_table, fim_table, gridscale_table,
                                   headline_table, kerneltune_table,
                                   load_bench, load_reports,
                                   perf_log_table, roofline_table,
                                   serving_table, shardscale_table,
                                   streaming_table)

HEADER = """# EXPERIMENTS

System: RDD-Eclat (Singh et al. 2021) on JAX — paper reproduction +
multi-pod LM framework.  Hardware model: TPU v5e — 197 TF/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.  Meshes: single pod (data=16,
model=16) = 256 chips; multi-pod (pod=2, data=16, model=16) = 512 chips.
This container is CPU-only: all LM numbers below are derived from compiled
artifacts (`.lower().compile()` with 512 forced host devices), not
wall-clock; FIM numbers are real CPU wall-clock.

## Methodology notes (read first)

* **Dry-run**: every (arch x shape x mesh) cell jits the production step
  with explicit NamedShardings and must `.lower().compile()`.
  `memory_analysis()` gives per-device bytes; `cost_analysis()` gives
  FLOPs/bytes; collective traffic is parsed from the post-SPMD HLO text
  (`compiled.as_text()`) with ring-algorithm wire factors
  (see `repro.analysis.hlo_parse`).
* **Scan calibration**: XLA's HloCostAnalysis counts a while-loop body once,
  so scanned layer stacks and chunked inner loops under-report.  Totals are
  reconstructed from per-layer-kind depth deltas measured on tiny unrolled
  variants (exact for homogeneous stages): FLOPs from cost-mode compiles
  (inner chunks widened to one iteration — every op visible); bytes and
  collectives from production-mode compiles (the real program; inner-scan
  byte revisits are counted once per layer, so memory terms are lower bounds
  for attention-heavy prefill cells).  The full-size compile is always
  performed — it is the deliverable; calibration only refines the terms.
* **Terms**: compute = FLOPs/dev / 197e12; memory = bytes/dev / 819e9;
  collective = wire-bytes/dev / 50e9.  `compute frac` =
  compute / max(terms) — the roofline fraction if overlap were perfect.
  `MODEL/HLO` = analytic MODEL_FLOPS (6·N_active·D train, 2·N_active·D
  inference) / calibrated HLO FLOPs — values near 1 mean the compiled
  compute is "useful"; decode cells are small by construction (attention
  over the KV cache dominates a 2·N·B step estimate).
"""


def main():
    reports = load_reports()
    parts = [HEADER]

    headline = load_bench("BENCH_headline.json")
    if headline:
        parts.append("\n## §Headline (Apriori vs RDD-Eclat, scale x mesh, "
                     "checksum-verified)\n")
        parts.append(headline_table(headline))
        parts.append("")

    engine = load_bench("BENCH_engine.json")
    if engine:
        parts.append("\n## §FIM engine (batch mining backends, CPU wall-clock)\n")
        parts.append(fim_table(engine))

    streaming = load_bench("BENCH_streaming.json")
    if streaming:
        parts.append("\n\n## §Streaming (sliding-window incremental vs full re-mine)\n")
        parts.append(streaming_table(streaming))

    shardscale = load_bench("BENCH_shardscale.json")
    if shardscale:
        parts.append("\n\n## §Shard-scale (word-sharded frontier: parity + "
                     "per-device memory)\n")
        parts.append(shardscale_table(shardscale))

    gridscale = load_bench("BENCH_gridscale.json")
    if gridscale:
        parts.append("\n\n## §Grid-scale (2D pairs x words mesh vs the 1D "
                     "modes)\n")
        parts.append(gridscale_table(gridscale))

    kerneltune = load_bench("BENCH_kerneltune.json")
    if kerneltune:
        parts.append("\n\n## §Kernel-tune (autotuned tiles + measured "
                     "dispatch crossover)\n")
        parts.append(kerneltune_table(kerneltune))

    serving = load_bench("BENCH_serving.json")
    if serving:
        parts.append("\n\n## §Serving (async admission + version-keyed "
                     "caches under query storms)\n")
        parts.append(serving_table(serving))

    if reports:
        parts.append("\n\n## §Dry-run (compile proof, memory, collective schedule)\n")
        parts.append(
            "Every non-skipped cell below compiled successfully on its mesh.  "
            "Skips are the assignment-sanctioned long_500k exclusions "
            "(DESIGN.md §4).\n")
        parts.append(dryrun_table(reports))

        parts.append("\n\n## §Roofline (single-pod, per arch x shape)\n")
        parts.append(roofline_table(reports, mesh="single"))

    if os.path.exists("reports/perf_log.json"):
        with open("reports/perf_log.json") as f:
            log = json.load(f)
        parts.append("\n\n## §Perf (hypothesis -> change -> measure log)\n")
        for cell, meta in log.get("cells", {}).items():
            parts.append(f"\n### {cell}\n")
            parts.append(meta.get("why", ""))
            parts.append("\n")
            parts.append(perf_log_table(meta["iterations"]))
            if meta.get("summary"):
                parts.append("\n" + meta["summary"])

    if os.path.exists("reports/fim_bench.csv"):
        parts.append("\n\n## §Paper tables (FIM wall-clock, CPU)\n")
        parts.append("```\n" + open("reports/fim_bench.csv").read() + "```\n")

    if os.path.exists("reports/experiments_extra.md"):
        parts.append("\n" + open("reports/experiments_extra.md").read())

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts) + "\n")
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
