"""Docs-integrity gate: no dangling DESIGN.md §N citations, no [[...]]
placeholder refs, no broken intra-repo markdown links.

    python scripts/check_docs.py          # exit 1 + report on any violation

Run by CI and by tests/test_docs_integrity.py.  History: ~12 source files
cited "DESIGN.md §4"/"§5" while DESIGN.md ended at §3; this gate keeps
citations from rotting again.
"""
from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude", "node_modules",
             "reports"}

# "DESIGN.md §4", "DESIGN §4", "[DESIGN.md](DESIGN.md) §2", "DESIGN.md §2-3"
SECTION_REF = re.compile(r"DESIGN[^\n§]{0,12}§(\d+)(?:-(\d+))?")
WIKI_REF = re.compile(r"\[\[[^\]\n]+\]\]")
MD_LINK = re.compile(r"\[[^][\n]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"^(```|~~~).*?^\1\s*$", re.S | re.M)
INLINE_CODE = re.compile(r"`[^`\n]*`")


def _repo_files(exts: Tuple[str, ...]) -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.endswith(exts):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def design_sections() -> set:
    with open(os.path.join(ROOT, "DESIGN.md")) as f:
        return {int(m.group(1)) for m in re.finditer(r"^## §(\d+)", f.read(), re.M)}


def _strip_code(text: str) -> str:
    """Blank out fenced blocks (newline-preserving, so reported line numbers
    stay correct) and inline code spans."""
    blanked = FENCE.sub(lambda m: "\n" * m.group(0).count("\n"), text)
    return INLINE_CODE.sub("", blanked)


def check_section_refs() -> List[str]:
    """Every `DESIGN.md §N` (or §N-M range) must resolve to a `## §N`."""
    known = design_sections()
    errors = []
    for path in _repo_files((".py", ".md")):
        rel = os.path.relpath(path, ROOT)
        with open(path, errors="replace") as f:
            text = f.read()
        for m in SECTION_REF.finditer(text):
            lo = int(m.group(1))
            hi = int(m.group(2)) if m.group(2) else lo
            for sec in range(lo, hi + 1):
                if sec not in known:
                    line = text[: m.start()].count("\n") + 1
                    errors.append(f"{rel}:{line}: cites DESIGN.md §{sec} "
                                  f"but DESIGN.md has no '## §{sec}'")
    return errors


def check_wiki_refs() -> List[str]:
    """[[...]] section placeholders in markdown are always dangling."""
    errors = []
    for path in _repo_files((".md",)):
        rel = os.path.relpath(path, ROOT)
        text = _strip_code(open(path, errors="replace").read())
        for m in WIKI_REF.finditer(text):
            line = text[: m.start()].count("\n") + 1
            errors.append(f"{rel}:{line}: dangling section placeholder "
                          f"{m.group(0)}")
    return errors


def check_md_links() -> List[str]:
    """Relative markdown link targets must exist in the repo."""
    errors = []
    for path in _repo_files((".md",)):
        rel = os.path.relpath(path, ROOT)
        text = _strip_code(open(path, errors="replace").read())
        for m in MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target.split("#", 1)[0]))
            if not os.path.exists(resolved):
                line = text[: m.start()].count("\n") + 1
                errors.append(f"{rel}:{line}: broken link -> {target}")
    return errors


def run_all() -> List[str]:
    return check_section_refs() + check_wiki_refs() + check_md_links()


def main() -> int:
    errors = run_all()
    for e in errors:
        print(f"docs-integrity: {e}", file=sys.stderr)
    if errors:
        print(f"docs-integrity: {len(errors)} violation(s)", file=sys.stderr)
        return 1
    print("docs-integrity: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
