"""Dump the largest collectives of a cell's compiled HLO (1-layer variant).

    PYTHONPATH=src python scripts/diagnose_collectives.py <arch> <shape> [n]
"""
import os
# append, never overwrite: a caller's XLA_FLAGS must survive (RS004)
_FLAG = "--xla_force_host_platform_device_count=512"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()
import dataclasses
import re
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.analysis.roofline import calibration_patterns  # noqa: E402
from repro.configs import TrainConfig, get_config  # noqa: E402
from repro.launch.dryrun import lower_and_compile  # noqa: E402
from repro.launch.mesh import make_mesh_named  # noqa: E402
from repro.launch.specs import build_cell  # noqa: E402
from repro.models.costing import costing  # noqa: E402


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    topn = int(sys.argv[3]) if len(sys.argv) > 3 else 25
    cfg = get_config(arch)
    base_pat, _, _ = calibration_patterns(cfg)
    c = dataclasses.replace(cfg, pattern_override=tuple(base_pat),
                            n_layers=len(base_pat),
                            n_encoder_layers=1 if cfg.n_encoder_layers else 0)
    mesh = make_mesh_named("single")
    with mesh:
        with costing(widen_chunks=False, unroll=True):
            cell = build_cell(arch, shape, mesh, cfg_override=c,
                              tcfg=TrainConfig(microbatches=1, remat="dots"))
            _, compiled, _ = lower_and_compile(cell)
    rows = []
    for line in compiled.as_text().splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+((?:all|reduce|collective)[\w\-]+)\(", s)
        if not m or m.group(2).endswith("-done"):
            continue
        shp, op = m.group(1), m.group(2)
        tot = 0
        for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", shp):
            n = 1
            for d in (dims.split(",") if dims else []):
                n *= int(d)
            byt = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
                   "pred": 1, "f64": 8}.get(dt, 0)
            tot += n * byt
        meta = re.search(r'op_name="([^"]+)"', s)
        rows.append((tot, op, shp[:60], (meta.group(1) if meta else "")[-90:]))
    rows.sort(reverse=True)
    print(f"top {topn} collectives ({arch} x {shape}, 1 layer/kind, m=1):")
    for tot, op, shp, name in rows[:topn]:
        print(f"  {tot/1e6:9.1f}MB {op:20s} {shp:62s} {name}")


if __name__ == "__main__":
    main()
