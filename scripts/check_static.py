"""Static-contracts gate: AST lint, lowered-IR collective budgets, shape audit.

    python scripts/check_static.py                    # full gate, exit 1 on any violation
    python scripts/check_static.py --lint-target F..  # lint specific files (exit 1 on findings)
    python scripts/check_static.py --contract-fixture extra_psum
    python scripts/check_static.py --shape-fixture

Three layers (DESIGN.md §12, ``repro.staticcheck``):

  1. repo AST lint    RS001-RS005 strict over src/repro + scripts,
                      warn-only over tests/ + benchmarks/
  2. IR contracts     lower all five engine backends + the sharded ring
                      write under a forced multi-device mesh, assert the
                      declared collective set / byte budget / reduce axis
  3. shape audit      >= 5 steady-state streaming slides and a cache-warm
                      mine run under jax.transfer_guard + the compile log:
                      zero recompiles, zero implicit transfers, every
                      recorded padding on the bucket ladder

The gate also self-tests its teeth: every committed must-fail fixture
(rs00*_bad.py, the four IR contract fixtures, the shape fixture) must still
produce findings — a fixture that passes means the checker rotted, and the
gate fails the build for it.

Writes a machine-readable findings report (default
``reports/static_findings.json``) for the CI artifact.  Run by CI next to
``scripts/check_docs.py`` and by tests/test_staticcheck.py.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))

# layers 2/3 need a multi-device mesh; append, never overwrite (RS004) —
# must happen before anything imports jax
_FLAG = "--xla_force_host_platform_device_count=4"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

from repro.staticcheck import (Report, iter_python_files, lint_paths,  # noqa: E402
                               rule_ids)
from repro.staticcheck.astlint import lint_file  # noqa: E402

FIXTURE_DIR = os.path.join(ROOT, "src", "repro", "staticcheck", "fixtures")
STRICT_DIRS = (os.path.join("src", "repro"), "scripts")
WARN_DIRS = ("tests", "benchmarks")
DEFAULT_REPORT = os.path.join(ROOT, "reports", "static_findings.json")


def _print(findings, label: str) -> None:
    for f in findings:
        print(f"static: [{label}] {f.format()}", file=sys.stderr)


# ---------------------------------------------------------------------------
# layer 1: AST lint
# ---------------------------------------------------------------------------

def run_lint(report: Report) -> int:
    strict = lint_paths(iter_python_files(ROOT, STRICT_DIRS), root=ROOT)
    warn = lint_paths(iter_python_files(ROOT, WARN_DIRS), root=ROOT,
                      severity="warning")
    _print(strict, "lint")
    _print(warn, "lint/warn-only")
    report.extend(strict)
    report.extend(warn)
    print(f"static: lint strict={len(strict)} warn-only={len(warn)}")
    return len(strict)


def run_lint_fixtures(report: Report) -> int:
    """Every rule's must-fail fixture must still trip exactly that rule."""
    failures = 0
    for rid in rule_ids():
        path = os.path.join(FIXTURE_DIR, f"{rid.lower()}_bad.py")
        found = lint_file(path, root=ROOT)
        if not any(f.rule == rid for f in found):
            failures += 1
            print(f"static: FIXTURE ROTTED — {os.path.relpath(path, ROOT)} "
                  f"no longer triggers {rid}", file=sys.stderr)
    report.summary["lint_fixtures"] = {
        "checked": len(rule_ids()), "rotted": failures}
    print(f"static: lint fixtures {len(rule_ids()) - failures}/"
          f"{len(rule_ids())} still fail as committed")
    return failures


# ---------------------------------------------------------------------------
# layer 2: lowered-IR contracts
# ---------------------------------------------------------------------------

def run_contracts(report: Report) -> int:
    from repro.staticcheck.contracts import check_all_contracts

    findings, summary = check_all_contracts()
    _print(findings, "ir")
    report.extend(findings)
    report.summary["ir_contracts"] = summary
    n_targets = len(summary["backends"]) + 1          # + the ring write
    print(f"static: IR contracts over {n_targets} lowered targets, "
          f"{len(findings)} finding(s)")
    return len(findings)


def run_contract_fixtures(report: Report) -> int:
    from repro.staticcheck.contracts import (CONTRACT_FIXTURES,
                                             check_contract_fixture)

    failures = 0
    for name in sorted(CONTRACT_FIXTURES):
        found = check_contract_fixture(name)
        if not found:
            failures += 1
            print(f"static: FIXTURE ROTTED — IR fixture {name!r} no longer "
                  f"violates its contract", file=sys.stderr)
    report.summary["ir_fixtures"] = {
        "checked": len(CONTRACT_FIXTURES), "rotted": failures}
    print(f"static: IR fixtures {len(CONTRACT_FIXTURES) - failures}/"
          f"{len(CONTRACT_FIXTURES)} still fail as committed")
    return failures


# ---------------------------------------------------------------------------
# layer 3: runtime-shape audit
# ---------------------------------------------------------------------------

def run_shapes(report: Report) -> int:
    import jax

    from repro.dist.compat import make_mesh
    from repro.staticcheck.shapes import audit_mine, audit_streaming

    n_findings = 0
    summaries = []
    targets = [("pallas", "pairs", None)]
    if len(jax.devices()) >= 2:
        n = 4 if len(jax.devices()) >= 4 else 2
        targets.append(("tidsharded", "words",
                        make_mesh((n,), ("data",),
                                  devices=jax.devices()[:n])))
    for backend, shard, mesh in targets:
        findings, summary = audit_streaming(backend=backend, shard=shard,
                                            mesh=mesh)
        _print(findings, "shape")
        report.extend(findings)
        summaries.append(summary)
        n_findings += len(findings)
        print(f"static: shape audit {summary['target']} — "
              f"{summary['audited_slides']} audited slides, "
              f"{len(findings)} finding(s)")
    findings, summary = audit_mine()
    _print(findings, "shape")
    report.extend(findings)
    summaries.append(summary)
    n_findings += len(findings)
    print(f"static: shape audit {summary['target']} — "
          f"{summary['levels']} levels, {len(findings)} finding(s)")
    report.summary["shape_audits"] = summaries
    return n_findings


def run_shape_fixture(report: Report) -> int:
    from repro.staticcheck.shapes import check_shape_fixture

    found = check_shape_fixture()
    rules = sorted({f.rule for f in found})
    rotted = 0 if {"SH001", "SH002", "SH003"} <= set(rules) else 1
    if rotted:
        print(f"static: FIXTURE ROTTED — shape fixture only triggered "
              f"{rules}, expected SH001+SH002+SH003", file=sys.stderr)
    report.summary["shape_fixture"] = {"rules": rules, "rotted": rotted}
    print(f"static: shape fixture trips {rules}")
    return rotted


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def full_gate(report_path: str) -> int:
    report = Report()
    bad = 0
    bad += run_lint(report)
    bad += run_lint_fixtures(report)
    bad += run_contracts(report)
    bad += run_contract_fixtures(report)
    bad += run_shapes(report)
    bad += run_shape_fixture(report)
    report.summary["violations"] = bad
    report.write(report_path)
    print(f"static: report -> {os.path.relpath(report_path, ROOT)}")
    if bad:
        print(f"static: {bad} violation(s)", file=sys.stderr)
        return 1
    print("static: OK")
    return 0


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--lint-target", nargs="+", metavar="PATH",
                    help="lint specific files/dirs; exit 1 on any finding")
    ap.add_argument("--contract-fixture", metavar="NAME",
                    help="run one IR contract fixture; exit 1 when it "
                         "violates its contract (the committed ones must)")
    ap.add_argument("--shape-fixture", action="store_true",
                    help="run the shape-audit fixture; exit 1 when it "
                         "produces findings (the committed one must)")
    ap.add_argument("--report", default=DEFAULT_REPORT, metavar="PATH",
                    help="findings report path (default "
                         "reports/static_findings.json)")
    args = ap.parse_args(argv)

    if args.lint_target:
        findings = []
        for target in args.lint_target:
            path = os.path.abspath(target)
            if os.path.isdir(path):
                findings.extend(lint_paths(
                    iter_python_files(ROOT, [os.path.relpath(path, ROOT)]),
                    root=ROOT))
            else:
                findings.extend(lint_file(path, root=ROOT))
        _print(findings, "lint")
        print(f"static: {len(findings)} finding(s)")
        return 1 if findings else 0

    if args.contract_fixture:
        from repro.staticcheck.contracts import check_contract_fixture

        findings = check_contract_fixture(args.contract_fixture)
        _print(findings, "ir")
        print(f"static: {len(findings)} finding(s)")
        return 1 if findings else 0

    if args.shape_fixture:
        from repro.staticcheck.shapes import check_shape_fixture

        findings = check_shape_fixture()
        _print(findings, "shape")
        print(f"static: {len(findings)} finding(s)")
        return 1 if findings else 0

    return full_gate(os.path.abspath(args.report))


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
