"""Gradient compression: quantization error bounds + error-feedback
convergence + the shard_map compressed psum."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import int8_roundtrip, make_compressor, topk_mask


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    deq, err = int8_roundtrip(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.abs(err).max()) <= scale * 0.5 + 1e-7
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g), rtol=1e-6)


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0], jnp.float32)
    kept, err = topk_mask(g, 0.5)
    np.testing.assert_array_equal(np.asarray(kept), [0.0, -5.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(kept + err), np.asarray(g))


def test_error_feedback_converges():
    """SGD on a quadratic with int8-compressed grads + error feedback reaches
    the optimum; without feedback it stalls at the quantization floor."""
    target = jnp.asarray(np.linspace(-2, 2, 64), jnp.float32)

    def run(method, feedback: bool, steps=400):
        w = jnp.zeros(64)
        init_err, apply = make_compressor(method)
        err = init_err({"w": w})
        for _ in range(steps):
            g = {"w": 2 * (w - target)}
            if feedback:
                g, err = apply(g, err)
            else:
                g2, _ = apply(g, jax.tree.map(jnp.zeros_like, err))
                g = g2
            w = w - 0.05 * g["w"]
        return float(jnp.abs(w - target).max())

    assert run("int8", True) < 1e-2
    assert run("topk", True) < 1e-2


_PSUM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.training import compressed_psum
from repro.dist.compat import make_mesh, shard_map
mesh = make_mesh((4,), ("data",))
x = jnp.arange(16.0).reshape(4, 4) / 7.3
f = jax.jit(shard_map(lambda v: compressed_psum(v[0], "data", "int8")[None],
                      mesh=mesh, in_specs=P("data"), out_specs=P("data")))
out = np.asarray(f(x))
expect = np.asarray(x).mean(0)
err = np.abs(out - expect[None]).max()
assert err < np.abs(expect).max() / 64, err   # int8 grid error bound
print("PSUM_OK")
"""


def test_compressed_psum_sharded():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _PSUM], capture_output=True,
                       text=True, env=env, cwd=os.getcwd())
    assert r.returncode == 0 and "PSUM_OK" in r.stdout, r.stderr
