"""Decode-with-cache must reproduce full-sequence prefill logits exactly —
the serving-path invariant, covering KV caches, SSM/mLSTM/sLSTM states,
sliding windows, local:global patterns, cross attention and vision prefixes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.models import Model, init_params

ARCHS = ["phi-3-vision-4.2b", "gemma-2b", "gemma3-4b", "hymba-1.5b",
         "xlstm-1.3b", "whisper-base", "command-r-35b", "grok-1-314b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.n_experts:
        # capacity-based MoE drops tokens differently at prefill vs decode
        # batch shapes (expected production behaviour); test the cache/state
        # machinery itself with a no-drop capacity.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    b, s, smax = 2, 12, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": toks}
    enc_kv = None
    if cfg.n_encoder_layers:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_len, cfg.d_model)) * 0.05, jnp.float32)
        enc_out = model.encode(params, batch["enc_embeds"])
        enc_kv = model.cross_kv(params, enc_out)
    logits_full, _ = model.prefill(params, batch, smax)
    logits, cache = model.prefill(params, {**batch, "tokens": toks[:, :1]}, smax)
    for t in range(1, s):
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = model.decode_step(params, toks[:, t:t+1], cache, pos,
                                          enc_out=enc_kv)
    err = float(jnp.abs(logits - logits_full).max())
    assert err < 2e-3, f"{arch}: {err}"


def test_xlstm_multichunk_path():
    """mLSTM chunkwise-parallel form must equal the step recurrence across
    chunk boundaries (CHUNK < S exercises the cross-chunk state)."""
    import repro.models.xlstm as xl
    cfg = reduced_config(get_config("xlstm-1.3b"))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(0)
    b, s, smax = 2, 12, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    old = xl.CHUNK
    try:
        xl.CHUNK = 4
        logits_full, _ = model.prefill(params, {"tokens": toks}, smax)
        logits, cache = model.prefill(params, {"tokens": toks[:, :1]}, smax)
        for t in range(1, s):
            logits, cache = model.decode_step(
                params, toks[:, t:t+1], cache, jnp.full((b,), t, jnp.int32))
        assert float(jnp.abs(logits - logits_full).max()) < 2e-3
    finally:
        xl.CHUNK = old


def test_sliding_window_decode():
    """Windowed attention: decode at position p must ignore keys <= p-window."""
    cfg = dataclasses.replace(reduced_config(get_config("gemma-2b")),
                              attn_pattern="window", window=4,
                              skip_shapes=())
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    b, s, smax = 1, 10, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_full, _ = model.prefill(params, {"tokens": toks}, smax)
    logits, cache = model.prefill(params, {"tokens": toks[:, :1]}, smax)
    for t in range(1, s):
        logits, cache = model.decode_step(
            params, toks[:, t:t+1], cache, jnp.full((b,), t, jnp.int32))
    assert float(jnp.abs(logits - logits_full).max()) < 2e-3
