"""Serving engine: batched generation, packing balance, determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.models import Model, init_params
from repro.serving import Request, ServingEngine, pack_requests


def make_engine(temperature=0.0):
    cfg = reduced_config(get_config("gemma-2b"))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return ServingEngine(model, params, s_max=64, temperature=temperature), cfg


def test_greedy_generation_deterministic():
    eng, cfg = make_engine()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=6) for i in range(3)]
    out1, _ = eng.serve(reqs, n_batches=1)
    out2, _ = eng.serve(reqs, n_batches=2)   # different packing, same results
    for i in range(3):
        np.testing.assert_array_equal(out1[i], out2[i])
        assert out1[i].shape == (6,)


def test_batched_matches_single():
    """A request generated inside a heterogeneous batch must equal the same
    request generated alone (left-padding + position bookkeeping)."""
    eng, cfg = make_engine()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 6)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    batched, _ = eng.serve(reqs, n_batches=1)
    for i, p in enumerate(prompts):
        solo, _ = eng.serve([Request(rid=99, prompt=p, max_new_tokens=5)], 1)
        np.testing.assert_array_equal(batched[i], solo[99])


def test_pack_requests_balances_tokens():
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=np.zeros(int(n), np.int32))
            for i, n in enumerate(rng.zipf(1.5, 64).clip(1, 500))]
    assign, stats = pack_requests(reqs, 4)
    # greedy-LPT must beat round-robin and stay near the achievable optimum
    # (a single huge request bounds efficiency from above)
    work = np.array([r.prompt.shape[0] for r in reqs], float)
    rr = np.arange(len(reqs)) % 4
    from repro.core.partitioners import partition_stats
    rr_eff = partition_stats(rr, work, 4)["padding_efficiency"]
    bound = work.sum() / (max(work.max(), work.sum() / 4) * 4)
    assert stats["padding_efficiency"] >= rr_eff - 1e-9
    # LPT guarantee: makespan <= 4/3 OPT  ->  efficiency >= 0.75 x bound
    assert stats["padding_efficiency"] >= 0.75 * bound
    assert set(np.asarray(assign)) <= {0, 1, 2, 3}
