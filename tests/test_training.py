"""Training substrate: optimizer, schedules, microbatching, runner + FT."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.configs.reduced import reduced_config
from repro.data import TokenPipeline
from repro.models import Model, init_params
from repro.training import (RunnerConfig, TrainingRunner, adamw_init,
                            adamw_update, clip_by_global_norm, global_norm,
                            lr_schedule, make_train_step)


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt = adamw_update(params, g, opt, lr=5e-2)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_lr_schedule_shape():
    warm = lr_schedule(jnp.asarray(5), 1e-3, 10, 100)
    peak = lr_schedule(jnp.asarray(10), 1e-3, 10, 100)
    end = lr_schedule(jnp.asarray(100), 1e-3, 10, 100)
    assert float(warm) < float(peak)
    assert abs(float(peak) - 1e-3) < 1e-9
    assert float(end) == pytest.approx(1e-4, rel=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(20.0, rel=1e-5)


def test_microbatching_matches_full_batch():
    cfg = reduced_config(get_config("gemma-2b"))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
    s1 = make_train_step(model, TrainConfig(microbatches=1, remat="none",
                                            grad_clip=1e9, weight_decay=0.0))
    s2 = make_train_step(model, TrainConfig(microbatches=2, remat="none",
                                            grad_clip=1e9, weight_decay=0.0))
    p1, _, m1 = jax.jit(s1)(params, adamw_init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params), batch)
    # microbatch mean loss equals full-batch loss (same tokens)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3  # same update direction, fp accumulation differences only


def test_remat_matches_no_remat():
    cfg = reduced_config(get_config("internlm2-20b"))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    g_plain = jax.grad(lambda p: model.loss(p, batch, remat="none"))(params)
    g_remat = jax.grad(lambda p: model.loss(p, batch, remat="full"))(params)
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_remat)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_runner_checkpoint_restart_with_failures(tmp_path):
    """Injected failures + restart must not change the metrics trajectory."""
    cfg = reduced_config(get_config("gemma-2b"))
    model = Model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, batch=4, seq_len=16, seed=0)
    step_fn = jax.jit(make_train_step(model, TrainConfig(learning_rate=1e-3)))

    def fresh():
        params = init_params(jax.random.PRNGKey(0), cfg)
        return params, adamw_init(params)

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}

    # reference run, no failures
    p, o = fresh()
    ref = TrainingRunner(RunnerConfig(str(tmp_path / "ref"), checkpoint_every=3),
                         step_fn, p, o, batch_fn)
    ref.run(7)
    # failing run: injected failure at steps 2 and 5, retried transparently
    p, o = fresh()
    r = TrainingRunner(
        RunnerConfig(str(tmp_path / "ft"), checkpoint_every=3,
                     fail_injector=lambda s: s in (2, 5)),
        step_fn, p, o, batch_fn)
    r.run(7)
    ref_losses = [m["loss"] for m in ref.metrics_log]
    ft_losses = [m["loss"] for m in r.metrics_log]
    np.testing.assert_allclose(ref_losses, ft_losses, rtol=1e-5)
    # resume-from-checkpoint run: new runner continues from disk
    r2 = TrainingRunner(RunnerConfig(str(tmp_path / "ft"), checkpoint_every=3),
                        step_fn, *fresh(), batch_fn)
    assert r2.maybe_restore() >= 6


def test_data_pipeline_deterministic_and_sharded():
    p = TokenPipeline(1000, batch=8, seq_len=32, seed=1)
    a = p.batch_at(5)["tokens"]
    b = p.batch_at(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = p.batch_at(6)["tokens"]
    assert not np.array_equal(a, c)
    s0 = TokenPipeline(1000, batch=8, seq_len=32, seed=1, shard_index=0, shard_count=2)
    s1 = TokenPipeline(1000, batch=8, seq_len=32, seed=1, shard_index=1, shard_count=2)
    b0, b1 = s0.batch_at(3)["tokens"], s1.batch_at(3)["tokens"]
    assert b0.shape == (4, 32) and b1.shape == (4, 32)
    assert not np.array_equal(b0, b1)
