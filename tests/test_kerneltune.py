"""Kernel-tune suite (ISSUE 7): the raw-speed pass must not change answers.

Three layers under test:

1.  **Compacting fused kernel** — ``fused_intersect_compact_pairs`` (the real
    Pallas kernel under ``interpret=True``) must match the fused XLA oracle
    ``fused_intersect_compact_ref`` bit-for-bit across modes and the edge
    regimes the epilogue has to get right: W not a multiple of ``block_w``,
    zero survivors, all survivors, and ``n_valid < Q`` bucket padding.
2.  **Autotuner mechanics** — shape classes, candidate ladders (including the
    honest single-candidate collapse off-TPU), cost-model-seeded ordering,
    the persistent table (round-trip, corrupt-cache-as-miss), and
    ``tune_shape``/``lookup`` end to end under ``interpret=True``.
3.  **Measured dispatch** — ``DispatchPolicy`` nearest-cell choice from a
    crossover table and ``resolve_engine("auto")`` routing with safe
    fallback when no table exists.

Plus the engine-level guarantee that ties it together: ``compact=True`` (one
fused dispatch, survivors only) and ``compact=False`` (legacy mask-roundtrip
two-step) mine identical itemsets.
"""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import EclatConfig, bruteforce_fim, mine
from repro.core import engine as eng
from repro.kernels import autotune
from repro.kernels.fused_intersect import (DEFAULT_BLOCK_W, compact_epilogue,
                                           fused_intersect_compact_pairs,
                                           fused_intersect_compact_ref,
                                           round_up_lanes)

MODES = [eng.MODE_TIDSET, eng.MODE_TID_TO_DIFF, eng.MODE_DIFFSET]


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Point the autotune cache at a throwaway file and drop the in-process
    table around the test, so tests neither read nor pollute the real cache."""
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    autotune.reset()
    yield path
    autotune.reset()


def _case(q, w, seed=0):
    rng = np.random.default_rng(seed)
    p = max(q, 2)
    bitmaps = jnp.asarray(rng.integers(0, 2 ** 32, (p, w), dtype=np.uint32))
    left = jnp.asarray(rng.integers(0, p, q).astype(np.int32))
    right = jnp.asarray(rng.integers(0, p, q).astype(np.int32))
    supl = jnp.asarray(np.full(q, w * 32, np.int32))
    return bitmaps, left, right, supl


# ---------------------------------------------------------------------------
# 1. compacting kernel parity (interpret kernel vs fused XLA oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("q,w", [(7, 5), (16, 200), (33, 130)])
def test_compact_kernel_matches_oracle(mode, q, w):
    """Bit-exact across modes and W-not-a-multiple-of-block_w shapes, at a
    mid threshold (mixed survivors)."""
    bm, l, r, s = _case(q, w, seed=q * 10 + mode)
    msup = jnp.int32(w * 16)
    nv = jnp.int32(q)
    ref = fused_intersect_compact_ref(bm, l, r, s, msup, nv, mode=mode)
    ker = fused_intersect_compact_pairs(bm, l, r, s, msup, nv, mode=mode,
                                        block_w=128, interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("regime", ["none", "all", "padded"])
def test_compact_kernel_survivor_regimes(regime):
    """Zero survivors, all survivors, and n_valid < Q (bucket-ladder pad
    pairs must never survive, however permissive the threshold)."""
    q, w = 12, 40
    bm, l, r, s = _case(q, w, seed=3)
    msup = {"none": jnp.int32(10 ** 9), "all": jnp.int32(0),
            "padded": jnp.int32(0)}[regime]
    nv = jnp.int32(5 if regime == "padded" else q)
    ref = fused_intersect_compact_ref(bm, l, r, s, msup, nv, mode=0)
    ker = fused_intersect_compact_pairs(bm, l, r, s, msup, nv, mode=0,
                                        block_w=128, interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    n_surv = int(ref[3])
    assert n_surv == {"none": 0, "all": q, "padded": 5}[regime]


def test_compact_epilogue_semantics():
    """Survivors in ascending pair order, pad rows duplicate row 0, n_valid
    excludes the tail, and the count matches the mask."""
    inter = jnp.arange(5 * 4, dtype=jnp.uint32).reshape(5, 4)
    sup = jnp.asarray([9, 1, 9, 9, 9], jnp.int32)
    mask = jnp.asarray([1, 0, 1, 1, 1], jnp.int32)
    compact, sup2, m, n_surv = compact_epilogue(inter, sup, mask, 4)
    assert int(n_surv) == 3                      # row 4 is bucket padding
    np.testing.assert_array_equal(np.asarray(m), [1, 0, 1, 1, 0])
    got = np.asarray(compact)
    np.testing.assert_array_equal(got[:3], np.asarray(inter)[[0, 2, 3]])
    np.testing.assert_array_equal(got[3:], np.asarray(inter)[[0, 0]])
    np.testing.assert_array_equal(np.asarray(sup2), np.asarray(sup))


def test_compact_epilogue_empty():
    """Q=0 is legal for the epilogue (engines early-return before the kernel,
    but the fused oracle must not be the thing that breaks)."""
    inter = jnp.zeros((0, 4), jnp.uint32)
    z = jnp.zeros((0,), jnp.int32)
    compact, sup, m, n_surv = compact_epilogue(inter, z, z, 0)
    assert compact.shape == (0, 4) and int(n_surv) == 0


# ---------------------------------------------------------------------------
# 2. autotuner mechanics
# ---------------------------------------------------------------------------

def test_shape_class_buckets():
    assert autotune.shape_class(1000, 100, 0, "xla") == "q1024_w128_m0_xla"
    # every q on the same pow2 rung shares the class
    assert (autotune.shape_class(513, 100, 0, "xla")
            == autotune.shape_class(1024, 100, 0, "xla"))
    # mode and kind split classes
    assert (autotune.shape_class(1000, 100, 1, "xla")
            != autotune.shape_class(1000, 100, 0, "xla"))
    assert (autotune.shape_class(1000, 100, 0, "tpu")
            != autotune.shape_class(1000, 100, 0, "xla"))


def test_candidates_xla_collapse():
    """Off-TPU the fused path is one XLA executable with no tile knob: the
    candidate list must collapse to a single width (an honest tuner does not
    sweep a parameter the executable ignores)."""
    for w in (5, 100, 600, 4000):
        cands = autotune.block_w_candidates(w, "xla")
        assert cands == [min(DEFAULT_BLOCK_W, round_up_lanes(w))]


def test_candidates_tpu_ladder():
    assert autotune.block_w_candidates(2000, "tpu") == [128, 256, 512, 1024,
                                                        2048]
    assert autotune.block_w_candidates(100, "tpu") == [128]
    # non-pow2 padded width joins the ladder as the single-block tile
    assert 384 in autotune.block_w_candidates(300, "tpu")
    for bw in autotune.block_w_candidates(700, "tpu"):
        assert bw % 128 == 0


def test_seeded_candidates_is_ordered_permutation():
    cands = autotune.block_w_candidates(2000, "tpu")
    seeded = autotune.seeded_candidates(4096, 2000, "tpu")
    assert sorted(seeded) == cands


def test_table_roundtrip(tune_cache):
    t = autotune.AutotuneTable(tune_cache)
    t.put("q64_w128_m0_tpu", autotune.KernelConfig(block_w=256),
          measured_s=1e-4)
    t.save()
    t2 = autotune.AutotuneTable(tune_cache).load()
    cfg = t2.get("q64_w128_m0_tpu")
    assert cfg is not None and cfg.block_w == 256
    assert t2.entries["q64_w128_m0_tpu"]["source"] == "measured"


def test_corrupt_cache_is_a_miss(tune_cache):
    with open(tune_cache, "w") as f:
        f.write("{not json")
    t = autotune.AutotuneTable(tune_cache).load()
    assert t.entries == {}
    assert autotune.load_table(refresh=True).get("anything") is None


def test_lookup_miss_returns_cost_model_seed(tune_cache):
    cfg = autotune.lookup(64, 40, 0, "tpu")
    assert cfg.block_w == autotune.seeded_candidates(64, 40, "tpu")[0]


def test_tune_shape_interpret_caches_winner(tune_cache):
    rec = autotune.tune_shape(16, 8, 0, kind="interpret", reps=1)
    assert rec["kind"] == "interpret"
    assert str(rec["tuned_block_w"]) in rec["candidates"]
    assert rec["model_pick"] == int(
        autotune.seeded_candidates(16, 8, "tpu")[0])
    # the winner landed in the persistent table under the tpu-class key...
    assert os.path.exists(tune_cache)
    cfg = autotune.lookup(16, 8, 0, "tpu")
    assert cfg.block_w == rec["tuned_block_w"]
    # ...and survives a cold reload
    autotune.reset()
    assert autotune.lookup(16, 8, 0, "tpu").block_w == rec["tuned_block_w"]


# ---------------------------------------------------------------------------
# 3. measured dispatch: DispatchPolicy + resolve_engine("auto")
# ---------------------------------------------------------------------------

FAKE_CELLS = [
    {"q": 256, "w": 32, "best_single": "jnp", "best_mesh": "sharded"},
    {"q": 16384, "w": 1024, "best_single": "pallas",
     "best_mesh": "tidsharded"},
    {"q": 4096, "w": 128, "best_single": "pallas"},   # no mesh sweep ran
]


@pytest.fixture
def fake_table(tmp_path):
    path = str(tmp_path / "BENCH_kerneltune.json")
    with open(path, "w") as f:
        json.dump({"crossover": FAKE_CELLS}, f)
    return path


def test_policy_nearest_cell(fake_table):
    pol = eng.DispatchPolicy.load(fake_table)
    assert pol is not None and pol.source == fake_table
    assert pol.choose(100, 16) == "jnp"            # nearest (256, 32)
    assert pol.choose(200000, 4096) == "pallas"    # nearest (16384, 1024)
    assert pol.choose(100, 16, have_mesh=True) == "sharded"
    assert pol.choose(200000, 4096, have_mesh=True) == "tidsharded"
    # cell without a mesh sweep falls back to its single-device winner
    assert pol.choose(4096, 128, have_mesh=True) == "pallas"


def test_policy_missing_corrupt_empty(tmp_path):
    assert eng.DispatchPolicy.load(str(tmp_path / "nope.json")) is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert eng.DispatchPolicy.load(str(bad)) is None
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"crossover": []}))
    assert eng.DispatchPolicy.load(str(empty)) is None
    # cells missing q/w/best_single are filtered -> empty -> None
    junk = tmp_path / "junk.json"
    junk.write_text(json.dumps({"crossover": [{"q": 1}]}))
    assert eng.DispatchPolicy.load(str(junk)) is None


def test_policy_env_path(fake_table, monkeypatch):
    monkeypatch.setenv(eng.KERNELTUNE_ENV, fake_table)
    pol = eng.DispatchPolicy.load()
    assert pol is not None and pol.source == fake_table


def test_resolve_auto_routes_by_hints(fake_table):
    e = eng.resolve_engine("auto", policy_path=fake_table, hints=(100, 16))
    assert e.name == "jnp"
    assert e.dispatch == {"requested": "auto", "auto": True,
                          "policy": fake_table}
    e = eng.resolve_engine("auto", policy_path=fake_table,
                           hints=(200000, 4096))
    assert e.name == "pallas"


def test_resolve_auto_mesh_overrides_shard(fake_table, host_devices):
    """Under auto the policy picks the backend; a policy choice of
    ``tidsharded`` must override the default shard="pairs" instead of
    raising the contradictory-request error."""
    from repro.dist.compat import make_mesh
    mesh = make_mesh((4,), ("data",))
    e = eng.resolve_engine("auto", mesh, policy_path=fake_table,
                           hints=(200000, 4096))
    assert e.name == "tidsharded"
    e = eng.resolve_engine("auto", mesh, policy_path=fake_table,
                           hints=(100, 16))
    assert e.name == "sharded"


def test_resolve_auto_fallbacks(tmp_path):
    # no table at the explicit path -> static default, dispatch records it
    e = eng.resolve_engine("auto", policy_path=str(tmp_path / "nope.json"),
                           hints=(100, 16))
    assert e.name == "pallas"
    assert e.dispatch["auto"] is True and e.dispatch["policy"] is None
    # table but no hints -> static default
    path = tmp_path / "t.json"
    path.write_text(json.dumps({"crossover": FAKE_CELLS}))
    e = eng.resolve_engine("auto", policy_path=str(path))
    assert e.name == "pallas"


def test_resolve_non_auto_unchanged(fake_table):
    e = eng.resolve_engine("jnp", policy_path=fake_table, hints=(100, 16))
    assert e.name == "jnp" and e.dispatch["auto"] is False
    e = eng.resolve_engine("batched")
    assert e.name == "pallas" and e.dispatch["requested"] == "batched"


# ---------------------------------------------------------------------------
# engine-level: compact vs legacy bit-identity + padding accounting
# ---------------------------------------------------------------------------

def _db(seed=7, n_items=10, n_txn=150):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7),
                           replace=False).tolist())
        if rng.random() < 0.5:
            t |= {0, 1, 2, 3}
        txns.append(sorted(t))
    return txns


DB = _db()
ORACLE = bruteforce_fim(DB, min_sup=25)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_mine_compact_matches_legacy(backend):
    maps = {}
    for compact in (True, False):
        res = mine(DB, 10, EclatConfig(min_sup=25, variant="v5", p=3,
                                       backend=backend, bucket_min=32,
                                       compact=compact))
        maps[compact] = res.support_map()
    assert maps[True] == maps[False] == ORACLE


def test_mine_explicit_block_w_and_diffsets():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v6", p=3,
                                   use_diffsets=True, backend="pallas",
                                   bucket_min=32, block_w=256))
    assert res.support_map() == ORACLE


def test_stats_pair_padding():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v5", p=3,
                                   backend="pallas", bucket_min=32))
    pad = res.stats.get("pair_padding")
    assert pad is not None
    assert 0.0 < pad["efficiency"] <= 1.0
    for lvl in pad["per_level"]:
        assert lvl["pairs"] <= lvl["padded_to"]
        assert lvl["efficiency"] == lvl["pairs"] / lvl["padded_to"]


def test_snapshot_is_four_tuple():
    e = eng.make_engine("pallas", bucket_min=8)
    snap = e.snapshot()
    assert snap == (0, 0, 0, 0)
    stats = e.stats(since=snap)
    assert stats["n_intersections"] == 0
