"""Distributed checkpoint: atomic write, async, elastic mesh reshard."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import (AsyncCheckpointer, latest_step,
                            restore_checkpoint, save_checkpoint)


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.zeros(())}}


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, manifest = restore_checkpoint(str(tmp_path), 7, t)
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones((5,), jnp.int32),
                                         "d": jnp.zeros(())}}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)


_ELASTIC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training import restore_checkpoint, save_checkpoint
from repro.dist.compat import make_mesh
d = sys.argv[1]
mesh = make_mesh((2, 2), ("data", "model"))
t = {"w": jnp.arange(64.0).reshape(8, 8)}
sh = {"w": NamedSharding(mesh, P("data", "model"))}
if sys.argv[2] == "save":
    tw = jax.device_put(t["w"], sh["w"])
    save_checkpoint(d, 3, {"w": tw})
else:
    restored, _ = restore_checkpoint(d, 3, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]
    print("ELASTIC_OK")
"""


def test_elastic_reshard_across_processes(tmp_path):
    """Save on a 4-device (2,2) mesh; restore in a fresh process on the same
    mesh AND on 1 device — content identical (mesh-agnostic checkpoints)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _ELASTIC, str(tmp_path), "save"],
                       capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r.returncode == 0, r.stderr
    r = subprocess.run([sys.executable, "-c", _ELASTIC, str(tmp_path), "load"],
                       capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r.returncode == 0 and "ELASTIC_OK" in r.stdout, r.stderr
    # 1-device restore in this process
    restored, _ = restore_checkpoint(
        str(tmp_path), 3, {"w": jnp.zeros((8, 8))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))


# ---------------------------------------------------------------------------
# crash-consistency (DESIGN.md §10): torn writes, corrupt steps, GC races
# ---------------------------------------------------------------------------

from faultinject import crash_at  # noqa: E402
from repro.faults import InjectedFault  # noqa: E402
from repro.training import (load_checkpoint, restore_latest,  # noqa: E402
                            valid_steps)
from repro.training.checkpoint import AsyncCheckpointer as _AC  # noqa: E402


def test_mid_write_crash_preserves_previous_step(tmp_path):
    """A writer killed after the leaves but before the manifest leaves no
    visible step — restore falls back to the previous one."""
    save_checkpoint(str(tmp_path), 1, tree(), extra={"v": 1})
    with crash_at("checkpoint:mid_write"), pytest.raises(InjectedFault):
        save_checkpoint(str(tmp_path), 2, tree(), extra={"v": 2})
    assert valid_steps(str(tmp_path)) == [1]
    _, manifest, step = restore_latest(str(tmp_path))
    assert step == 1 and manifest["extra"]["v"] == 1


def test_overwrite_same_step_is_crash_safe(tmp_path):
    """Re-saving an existing step must never destroy the only copy: a kill
    just before the rename leaves the old content fully restorable
    (regression: the old rmtree-then-replace deleted it first)."""
    save_checkpoint(str(tmp_path), 5, {"a": jnp.arange(4.0)}, extra={"v": "old"})
    with crash_at("checkpoint:pre_replace"), pytest.raises(InjectedFault):
        save_checkpoint(str(tmp_path), 5, {"a": jnp.zeros(4)}, extra={"v": "new"})
    flat, manifest = load_checkpoint(str(tmp_path), 5)
    assert manifest["extra"]["v"] == "old"
    np.testing.assert_array_equal(flat["a"], np.arange(4.0))
    # a successful re-save lands the new content and leaves no .old debris
    save_checkpoint(str(tmp_path), 5, {"a": jnp.zeros(4)}, extra={"v": "new"})
    flat, manifest = load_checkpoint(str(tmp_path), 5)
    assert manifest["extra"]["v"] == "new"
    np.testing.assert_array_equal(flat["a"], np.zeros(4))
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".old")]


def test_restore_latest_falls_back_past_corrupt_steps(tmp_path):
    """A truncated leaf or a missing manifest in the newest step must not
    stop restore (regression: it crashed instead of falling back)."""
    save_checkpoint(str(tmp_path), 1, tree(), extra={"v": 1})
    save_checkpoint(str(tmp_path), 2, tree(), extra={"v": 2})
    save_checkpoint(str(tmp_path), 3, tree(), extra={"v": 3})
    # step 3: manifest intact but a leaf truncated mid-write
    leaf = os.path.join(tmp_path, "step_00000003", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.truncate(8)
    # step 2: manifest gone entirely
    os.remove(os.path.join(tmp_path, "step_00000002", "manifest.json"))
    assert valid_steps(str(tmp_path)) == [1, 3]    # 3 still *looks* valid
    _, manifest, step = restore_latest(str(tmp_path))
    assert step == 1 and manifest["extra"]["v"] == 1
    # with like= the same fallback applies
    restored, manifest, step = restore_latest(str(tmp_path), like=tree())
    assert step == 1
    # nothing restorable at all -> FileNotFoundError, not a crash
    with open(os.path.join(tmp_path, "step_00000001", "leaf_00000.npy"),
              "r+b") as f:
        f.truncate(8)
    with pytest.raises(FileNotFoundError):
        restore_latest(str(tmp_path))


def test_gc_spares_newest_and_just_written(tmp_path):
    """GC keeps the newest ``keep`` steps and never collects a step at or
    above the save that triggered it, even if an older save's GC runs late
    (regression: a racing collector could eat the step just written)."""
    for s in (1, 2, 3):
        save_checkpoint(str(tmp_path), s, tree())
    ck = _AC(str(tmp_path), keep=1)
    ck._gc(just_wrote=2)             # a stale collector for the step-2 save
    assert valid_steps(str(tmp_path)) == [2, 3]
    ck._gc(just_wrote=3)
    assert valid_steps(str(tmp_path)) == [3]
    # no half-deleted ".gc" victims left in the step namespace
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".gc")]


def test_async_checkpointer_surfaces_writer_error_on_wait(tmp_path):
    """A fault on the background writer thread is re-raised by wait(), once
    — deterministic surfacing, no silent checkpoint loss."""
    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    ck.save(1, tree())
    ck.wait()
    with crash_at("checkpoint:mid_write"):
        ck.save(2, tree())
        with pytest.raises(InjectedFault):
            ck.wait()
    ck.wait()                        # error was consumed; wait is reusable
    assert valid_steps(str(tmp_path)) == [1]
    ck.save(3, tree())               # the checkpointer survives the fault
    ck.wait()
    assert valid_steps(str(tmp_path)) == [1, 3]
