"""Distributed checkpoint: atomic write, async, elastic mesh reshard."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import (AsyncCheckpointer, latest_step,
                            restore_checkpoint, save_checkpoint)


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32), "d": jnp.zeros(())}}


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, manifest = restore_checkpoint(str(tmp_path), 7, t)
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones((5,), jnp.int32),
                                         "d": jnp.zeros(())}}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)


_ELASTIC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training import restore_checkpoint, save_checkpoint
from repro.dist.compat import make_mesh
d = sys.argv[1]
mesh = make_mesh((2, 2), ("data", "model"))
t = {"w": jnp.arange(64.0).reshape(8, 8)}
sh = {"w": NamedSharding(mesh, P("data", "model"))}
if sys.argv[2] == "save":
    tw = jax.device_put(t["w"], sh["w"])
    save_checkpoint(d, 3, {"w": tw})
else:
    restored, _ = restore_checkpoint(d, 3, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]
    print("ELASTIC_OK")
"""


def test_elastic_reshard_across_processes(tmp_path):
    """Save on a 4-device (2,2) mesh; restore in a fresh process on the same
    mesh AND on 1 device — content identical (mesh-agnostic checkpoints)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _ELASTIC, str(tmp_path), "save"],
                       capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r.returncode == 0, r.stderr
    r = subprocess.run([sys.executable, "-c", _ELASTIC, str(tmp_path), "load"],
                       capture_output=True, text=True, env=env, cwd=os.getcwd())
    assert r.returncode == 0 and "ELASTIC_OK" in r.stdout, r.stderr
    # 1-device restore in this process
    restored, _ = restore_checkpoint(
        str(tmp_path), 3, {"w": jnp.zeros((8, 8))})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
