"""Unit tests for the repro.dist layer: mesh registry, param_spec rules,
spec/sharding tree round-trips, constrain semantics, compat shims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat
from repro.dist.sharding import (batch_spec, constrain, dp_axes, get_mesh,
                                 param_spec, reset_mesh, set_mesh,
                                 sharding_tree, spec_tree)


class FakeMesh:
    """Shape-rule tests don't need devices, just axis names + sizes."""
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 4}


class FakeDataMesh:
    axis_names = ("data",)
    shape = {"data": 4}


M = FakeMesh()


# ---------------------------------------------------------------------------
# mesh registry
# ---------------------------------------------------------------------------

def test_registry_set_get_reset():
    reset_mesh()
    assert get_mesh() is None
    assert set_mesh(M) is M
    assert get_mesh() is M
    reset_mesh()
    assert get_mesh() is None


def test_get_mesh_falls_back_to_context(host_devices):
    reset_mesh()
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    with mesh:
        assert get_mesh() is not None
        assert tuple(get_mesh().axis_names) == ("data", "model")
    assert get_mesh() is None


# ---------------------------------------------------------------------------
# dp_axes / batch_spec
# ---------------------------------------------------------------------------

def test_dp_axes_defaults_and_mesh_order():
    reset_mesh()
    assert dp_axes() == ("data",)
    assert dp_axes(M) == ("data",)

    class PodMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 4}

    assert dp_axes(PodMesh()) == ("pod", "data")


def test_batch_spec_divisibility():
    assert batch_spec(8, M) == P("data")
    assert batch_spec(6, M) == P(None)   # 6 % 4 != 0 -> replicate
    reset_mesh()
    assert batch_spec(8, None) == P(None)  # no mesh anywhere


# ---------------------------------------------------------------------------
# param_spec rules per shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path,shape,expect", [
    # column-parallel projections: output dim over 'model'
    ("stages/s0/stk_wq", (8, 64, 64), P(None, None, "model")),
    ("stages/s0/stk_w_gate", (8, 64, 256), P(None, None, "model")),
    ("stages/s0/stk_ssm_in_proj", (8, 64, 256), P(None, None, "model")),
    ("stages/s0/stk_m_in_proj", (8, 64, 256), P(None, None, "model")),
    # row-parallel projections: input dim over 'model'
    ("stages/s0/stk_wo", (8, 64, 64), P(None, "model", None)),
    ("stages/s0/stk_ssm_out_proj", (8, 128, 64), P(None, "model", None)),
    ("stages/s0/stk_m_out_proj", (8, 128, 64), P(None, "model", None)),
    # replicated leaves
    ("stages/s0/stk_norm1_scale", (8, 64), P(None, None)),
    ("final_norm/scale", (64,), P(None)),
    ("stages/s0/stk_router", (8, 64, 16), P(None, None, None)),
    ("stages/s0/stk_ssm_conv", (8, 4, 128), P(None, None, None)),
    ("stages/s0/stk_ssm_a_log", (8, 128, 16), P(None, None, None)),
    ("enc_pos", (1500, 64), P(None, None)),
    # embedding / unembedding, divisibility-guarded
    ("embed", (1024, 64), P("model", None)),
    ("embed", (1023, 64), P(None, None)),
    ("lm_head", (64, 1024), P(None, "model")),
    ("lm_head", (64, 1023), P(None, None)),
    # experts: EP over 'data', d_ff over 'model'
    ("stages/s0/stk_experts_up", (8, 16, 64, 256), P(None, "data", None, "model")),
    ("stages/s0/stk_experts_down", (8, 16, 256, 64), P(None, "data", "model", None)),
    # non-divisible expert count stays unsharded, d_ff still splits
    ("stages/s0/stk_experts_up", (8, 6, 64, 256), P(None, None, None, "model")),
])
def test_param_spec_rules(path, shape, expect):
    assert param_spec(path, shape, M) == expect


def test_param_spec_without_model_axis():
    """A data-only mesh (the sharded Eclat backend) never names 'model'."""
    m = FakeDataMesh()
    assert param_spec("stages/s0/stk_wq", (8, 64, 64), m) == P(None, None, None)
    assert param_spec("embed", (1024, 64), m) == P(None, None)


def test_param_spec_mlp_dp_replicates_ffn():
    assert param_spec("stages/s0/stk_w_up", (8, 64, 256), M,
                      mlp_dp=True) == P(None, None, None)
    assert param_spec("stages/s0/stk_w_down", (8, 256, 64), M,
                      mlp_dp=True) == P(None, None, None)
    # attention weights untouched by the flag
    assert param_spec("stages/s0/stk_wq", (8, 64, 64), M,
                      mlp_dp=True) == P(None, None, "model")


def test_param_spec_tp2d_experts():
    got = param_spec("stages/s0/stk_experts_up", (8, 6, 64, 256), M,
                     expert_sharding="tp2d")
    assert got == P(None, None, None, ("data", "model"))
    got = param_spec("stages/s0/stk_experts_down", (8, 6, 256, 64), M,
                     expert_sharding="tp2d")
    assert got == P(None, None, ("data", "model"), None)


# ---------------------------------------------------------------------------
# spec_tree / sharding_tree round-trip over a nested pytree
# ---------------------------------------------------------------------------

def _fake_params():
    SDS = jax.ShapeDtypeStruct
    return {
        "embed": SDS((1024, 64), jnp.float32),
        "stages": {
            "s0": {
                "stk_wq": SDS((8, 64, 64), jnp.float32),
                "stk_wo": SDS((8, 64, 64), jnp.float32),
                "stk_norm1_scale": SDS((8, 64), jnp.float32),
            },
        },
        "final_norm": {"scale": SDS((64,), jnp.float32)},
    }


def test_spec_tree_paths_and_rules():
    specs = spec_tree(_fake_params(), M)
    assert specs["embed"] == P("model", None)
    assert specs["stages"]["s0"]["stk_wq"] == P(None, None, "model")
    assert specs["stages"]["s0"]["stk_wo"] == P(None, "model", None)
    assert specs["stages"]["s0"]["stk_norm1_scale"] == P(None, None)
    assert specs["final_norm"]["scale"] == P(None)


def test_sharding_tree_round_trips_spec_tree(host_devices):
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    params = _fake_params()
    specs = spec_tree(params, mesh)
    shards = sharding_tree(params, mesh)
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_shards = jax.tree_util.tree_leaves(shards)
    assert len(flat_specs) == len(flat_shards) == 5
    for sp, sh in zip(flat_specs, flat_shards):
        assert isinstance(sh, NamedSharding)
        assert sh.mesh is mesh and sh.spec == sp


# ---------------------------------------------------------------------------
# constrain
# ---------------------------------------------------------------------------

def test_constrain_identity_without_mesh():
    reset_mesh()
    x = jnp.arange(8.0).reshape(2, 4)
    assert constrain(x, P("data", "model")) is x


def test_constrain_places_on_mesh(host_devices):
    mesh = compat.make_mesh((2, 2), ("data", "model"))
    set_mesh(mesh)
    try:
        x = jnp.arange(16.0).reshape(4, 4)
        y = jax.jit(lambda v: constrain(v, P("data", "model")))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        assert y.sharding.spec == P("data", "model")
        # non-divisible dim falls back to replicated instead of erroring,
        # and absent axis names are dropped
        z = jnp.arange(12.0).reshape(3, 4)
        out = jax.jit(lambda v: constrain(v, P("data", "nope")))(z)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(z))
    finally:
        reset_mesh()


# ---------------------------------------------------------------------------
# compat shims
# ---------------------------------------------------------------------------

def test_compat_make_mesh_accepts_axis_types(host_devices):
    mesh = compat.make_mesh((4,), ("data",),
                            axis_types=(compat.AxisType.Auto,))
    assert tuple(mesh.axis_names) == ("data",)
    assert mesh.shape["data"] == 4


def test_compat_shard_map_runs(host_devices):
    mesh = compat.make_mesh((4,), ("data",))
    x = jnp.arange(4.0)
    f = jax.jit(compat.shard_map(
        lambda v: jax.lax.psum(v, "data"),
        mesh=mesh, in_specs=P("data"), out_specs=P()))
    np.testing.assert_allclose(np.asarray(f(x)), 6.0)
