"""Kill-and-restore at every phase boundary, on every backend (DESIGN.md §10).

The contract under test: crash the streaming miner at any named kill point —
mid-append, mid-evict, between the cached level-2 delta and the deep
expansion, mid-checkpoint-write, pre-replace — restore the newest durable
checkpoint, replay the deterministic stream, and the final window's support
map is bit-exact with a run that never crashed.  Cross-mesh cases prove the
restore side is free to bring a *different* mesh (live re-meshing): a
4-device word-sharded checkpoint onto 2 devices, a 2x2 grid onto 4x1, a
sharded run onto a single device.
"""
import os

import jax
import pytest

from faultinject import (ALL_POINTS, CHECKPOINT_POINTS, crashed_run,
                         make_batches, resume_run, stream_run)
from repro.dist.compat import make_mesh
from repro.faults import InjectedFault
from repro.streaming import StreamConfig, StreamingMiner, restore_miner
from repro.training import valid_steps

N_ITEMS = 12
KILL_SLIDE = 2
BATCHES = make_batches(4, 24, seed=42, n_items=N_ITEMS)
BACKENDS = ["jnp", "pallas", "sharded", "tidsharded", "grid"]


def _setup(backend):
    """(StreamConfig, mesh) for each of the five engine backends."""
    kw = dict(min_sup=5, n_blocks=3, block_txns=32, bucket_min=16)
    if backend in ("sharded", "tidsharded"):
        return (StreamConfig(backend=backend, **kw),
                make_mesh((4,), ("data",)))
    if backend == "grid":
        return (StreamConfig(backend="grid", shard="grid", **kw),
                make_mesh((2, 2), ("class", "data"),
                          devices=jax.devices()[:4]))
    return StreamConfig(backend=backend, **kw), None


_REF = {}


def _reference():
    """Support map of an uninterrupted run (computed once; every backend is
    bit-exact with it, so one jnp reference serves the whole matrix)."""
    if "ref" not in _REF:
        cfg, mesh = _setup("jnp")
        _REF["ref"] = stream_run(N_ITEMS, cfg, BATCHES,
                                 mesh=mesh).support_map()
    return _REF["ref"]


# ---------------------------------------------------------------------------
# the full matrix: five backends x five phase boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", ALL_POINTS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_kill_and_restore_bit_exact(backend, point, tmp_path):
    cfg, mesh = _setup(backend)
    step = crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path), point,
                       KILL_SLIDE, mesh=mesh)
    # a kill during slide s — in the miner or in step s+1's write — always
    # leaves step s as the newest durable checkpoint
    assert step == KILL_SLIDE
    res = resume_run(N_ITEMS, BATCHES, str(tmp_path), mesh=mesh)
    assert res.support_map() == _reference(), f"{backend} @ {point}"


# ---------------------------------------------------------------------------
# live re-meshing: restore under a different mesh factorization
# ---------------------------------------------------------------------------

def test_remesh_tidsharded_4_to_2_devices(tmp_path):
    cfg, mesh4 = _setup("tidsharded")
    crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path), "miner:mid_append",
                KILL_SLIDE, mesh=mesh4)
    mesh2 = make_mesh((2,), ("data",), devices=jax.devices()[:2])
    res = resume_run(N_ITEMS, BATCHES, str(tmp_path), mesh=mesh2)
    assert res.support_map() == _reference()


def test_remesh_grid_2x2_to_4x1(tmp_path):
    cfg, mesh22 = _setup("grid")
    crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path),
                "miner:pre_deep_expand", KILL_SLIDE, mesh=mesh22)
    mesh41 = make_mesh((4, 1), ("class", "data"),
                       devices=jax.devices()[:4])
    res = resume_run(N_ITEMS, BATCHES, str(tmp_path), mesh=mesh41)
    assert res.support_map() == _reference()


def test_remesh_sharded_to_single_device(tmp_path):
    cfg, mesh4 = _setup("sharded")
    crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path), "miner:mid_evict",
                KILL_SLIDE, mesh=mesh4)
    res = resume_run(N_ITEMS, BATCHES, str(tmp_path), mesh=None,
                     backend="pallas", shard="pairs")
    assert res.support_map() == _reference()


def test_remesh_single_device_to_grid(tmp_path):
    """The other direction: a plain pallas checkpoint scaled OUT onto the
    2D grid mesh."""
    cfg, _ = _setup("pallas")
    crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path), "miner:mid_append",
                KILL_SLIDE, mesh=None)
    _, mesh22 = _setup("grid")
    res = resume_run(N_ITEMS, BATCHES, str(tmp_path), mesh=mesh22,
                     backend="grid", shard="grid")
    assert res.support_map() == _reference()


# ---------------------------------------------------------------------------
# durability edges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("point", CHECKPOINT_POINTS)
def test_torn_checkpoint_is_invisible(point, tmp_path):
    """A write killed mid-flight leaves debris (a temp dir, never a step
    directory with a readable manifest) that valid_steps/restore ignore."""
    cfg, _ = _setup("jnp")
    crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path), point, KILL_SLIDE)
    steps = valid_steps(str(tmp_path))
    assert steps and steps[-1] == KILL_SLIDE
    # the torn write's temp dir is still on disk, outside the step namespace
    debris = [d for d in os.listdir(tmp_path) if d.startswith(".tmp_ckpt_")]
    assert debris, "expected the killed write's temp dir to remain"
    miner, start = restore_miner(str(tmp_path))
    assert start == KILL_SLIDE and miner.ring.n_txn > 0


def test_crash_before_first_checkpoint_restores_nothing(tmp_path):
    """A kill during slide 0 predates any durable state: restore raises and
    recovery falls back to a fresh miner over the full stream."""
    cfg, _ = _setup("jnp")
    with pytest.raises(InjectedFault):
        stream_run(N_ITEMS, cfg, BATCHES, directory=str(tmp_path),
                   kill=("miner:mid_append", 0))
    assert valid_steps(str(tmp_path)) == []
    with pytest.raises(FileNotFoundError):
        restore_miner(str(tmp_path))
    miner = StreamingMiner(N_ITEMS, cfg, keep_transactions=False)
    res = None
    for b in BATCHES:
        res = miner.advance(b)
    assert res.support_map() == _reference()


def test_checkpoint_cadence_replays_uncheckpointed_slides(tmp_path):
    """every=2 means the newest durable step can trail the crash by a full
    slide; the replay covers the gap bit-exactly."""
    cfg, _ = _setup("pallas")
    step = crashed_run(N_ITEMS, cfg, BATCHES, str(tmp_path),
                       "miner:pre_deep_expand", 3, every=2)
    assert step == 2            # steps 1 and 3 were never cadence slides
    res = resume_run(N_ITEMS, BATCHES, str(tmp_path))
    assert res.support_map() == _reference()
