"""Sliding-window incremental mining: ring mechanics, incremental state,
bit-exact parity with batch ``mine()`` on every backend, and the live query
service (DESIGN.md §5)."""
import numpy as np
import pytest

from repro.core import EclatConfig, mine
from repro.core.bitmap import support_np
from repro.core.triangular import cooccurrence_counts
from repro.data import stream_spec, transaction_stream
from repro.serving import ItemsetQuery, StreamQueryService
from repro.streaming import StreamConfig, StreamingMiner, WindowRing

import jax.numpy as jnp

N_ITEMS = 12


def _batches(n_batches, batch_txns, seed=0, n_items=N_ITEMS):
    """Small dense batches so multi-level itemsets appear at tiny scale."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        batch = []
        for _ in range(batch_txns):
            t = set(rng.choice(n_items, size=rng.integers(3, 7),
                               replace=False).tolist())
            if rng.random() < 0.5:
                t |= {0, 1, 2}
            batch.append(sorted(t))
        out.append(batch)
    return out


# ---------------------------------------------------------------------------
# WindowRing mechanics
# ---------------------------------------------------------------------------

def test_ring_geometry_validation():
    with pytest.raises(ValueError, match="multiple of 32"):
        WindowRing(N_ITEMS, n_blocks=2, block_txns=33)
    with pytest.raises(ValueError, match="at least one block"):
        WindowRing(N_ITEMS, n_blocks=0, block_txns=32)
    ring = WindowRing(N_ITEMS, n_blocks=2, block_txns=32)
    with pytest.raises(ValueError, match="exceeds block capacity"):
        ring.push([[0]] * 33)


def test_ring_fill_evict_and_order():
    ring = WindowRing(N_ITEMS, n_blocks=3, block_txns=32)
    batches = _batches(5, 20, seed=1)
    for i, b in enumerate(batches):
        new_block, old_block, n_evicted = ring.push(b)
        ring.validate()
        if i < 3:
            assert n_evicted == 0 and not old_block.any()
        else:
            assert n_evicted == 20 and old_block.any()
        assert ring.n_txn == min(i + 1, 3) * 20
    # live window = the 3 newest batches, oldest first
    expect = [list(t) for b in batches[2:] for t in b]
    assert ring.window_transactions() == expect


def test_ring_partial_batches_pad_with_zero_columns():
    ring = WindowRing(N_ITEMS, n_blocks=2, block_txns=64)
    b = _batches(1, 10, seed=2)[0]
    ring.push(b)
    assert ring.n_txn == 10
    # zero pad columns contribute no support
    assert support_np(ring.words).sum() == sum(len(set(t)) for t in b)


# ---------------------------------------------------------------------------
# incremental state: supports + co-occurrence counts stay exact across slides
# ---------------------------------------------------------------------------

def test_incremental_state_matches_recompute():
    cfg = StreamConfig(min_sup=2, n_blocks=3, block_txns=32)
    miner = StreamingMiner(N_ITEMS, cfg)
    for b in _batches(6, 24, seed=3):
        miner.push(b)
        np.testing.assert_array_equal(miner.supports,
                                      support_np(miner.ring.words))
        full_cooc = cooccurrence_counts(jnp.asarray(miner.ring.words))
        np.testing.assert_array_equal(miner.cooc, full_cooc.astype(np.int64))


# ---------------------------------------------------------------------------
# parity: windowed == batch mine() over the window, all three backends
# ---------------------------------------------------------------------------

def _mesh4():
    from repro.dist.compat import make_mesh
    return make_mesh((4,), ("data",))


@pytest.mark.parametrize("backend", ["jnp", "pallas", "sharded", "tidsharded"])
def test_windowed_matches_batch_mine(backend):
    mesh = _mesh4() if backend in ("sharded", "tidsharded") else None
    cfg = StreamConfig(min_sup=5, n_blocks=3, block_txns=32,
                       backend=backend, bucket_min=16)
    miner = StreamingMiner(N_ITEMS, cfg, mesh=mesh)
    for i, batch in enumerate(_batches(6, 28, seed=4)):
        res = miner.advance(batch)
        window = miner.window_transactions()
        batch_res = mine(window, N_ITEMS,
                         EclatConfig(min_sup=5, variant="v4", p=4,
                                     backend="jnp", bucket_min=16),
                         mesh=None)
        assert res.n_txn == len(window)
        assert res.support_map() == batch_res.support_map(), f"slide {i}"
    if backend in ("sharded", "tidsharded"):
        assert miner.engine.name == backend


def test_windowed_matches_batch_fractional_min_sup():
    """Fractional min_sup resolves against the live window txn count."""
    cfg = StreamConfig(min_sup=0.2, n_blocks=2, block_txns=32)
    miner = StreamingMiner(N_ITEMS, cfg)
    for batch in _batches(4, 20, seed=5):
        res = miner.advance(batch)
        window = miner.window_transactions()
        batch_res = mine(window, N_ITEMS, EclatConfig(min_sup=0.2))
        assert res.stats["abs_min_sup"] == batch_res.stats["abs_min_sup"]
        assert res.support_map() == batch_res.support_map()


def test_windowed_parity_on_paper_stream():
    """A real T10-shaped stream (sparse, wide universe) stays bit-exact."""
    spec = stream_spec("T10I4D100K")
    cfg = StreamConfig(min_sup=0.02, n_blocks=2, block_txns=128)
    miner = StreamingMiner(spec.n_items, cfg)
    for batch in transaction_stream("T10I4D100K", 128, 4, seed=6):
        res = miner.advance(batch)
        batch_res = mine(miner.window_transactions(), spec.n_items,
                         EclatConfig(min_sup=0.02))
        assert res.support_map() == batch_res.support_map()


def test_class_crossing_bookkeeping_under_drift():
    cfg = StreamConfig(min_sup=6, n_blocks=2, block_txns=64)
    miner = StreamingMiner(20, cfg)
    rng = np.random.default_rng(7)
    entered = exited = 0
    for i in range(6):
        # regime flips halfway: items 10..19 replace items 0..9
        lo = 0 if i < 3 else 10
        batch = [sorted(set(rng.choice(range(lo, lo + 10), size=4).tolist()))
                 for _ in range(40)]
        res = miner.advance(batch)
        entered += res.stats["classes"]["n_entered"]
        exited += res.stats["classes"]["n_exited"]
    assert entered > 0 and exited > 0


@pytest.mark.parametrize("backend", ["jnp", "pallas", "grid"])
@pytest.mark.parametrize("max_k", [1, 2, 3, None])
def test_streaming_max_k_matches_batch(backend, max_k):
    """Regression: mine_window ignored max_k < 3 — level 2 was always
    expanded and recorded.  Streaming must stay bit-exact with batch mine()
    at every max_k boundary."""
    if backend == "grid":
        from repro.dist.compat import make_mesh
        import jax
        mesh = make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])
        cfg = StreamConfig(min_sup=5, n_blocks=2, block_txns=32,
                           shard="grid", max_k=max_k, bucket_min=16)
    else:
        mesh = None
        cfg = StreamConfig(min_sup=5, n_blocks=2, block_txns=32,
                           backend=backend, max_k=max_k, bucket_min=16)
    miner = StreamingMiner(N_ITEMS, cfg, mesh=mesh)
    for batch in _batches(3, 28, seed=4):
        res = miner.advance(batch)
        batch_res = mine(miner.window_transactions(), N_ITEMS,
                         EclatConfig(min_sup=5, backend="jnp", max_k=max_k,
                                     bucket_min=16))
        assert res.support_map() == batch_res.support_map()
        if max_k is not None:
            assert len(res.counts) <= max_k


def test_streaming_max_k_validation():
    miner = StreamingMiner(N_ITEMS, StreamConfig(min_sup=5, n_blocks=2,
                                                 block_txns=32, max_k=0))
    miner.push(_batches(1, 20, seed=3)[0])
    with pytest.raises(ValueError, match="max_k"):
        miner.mine_window()


def test_per_slide_engine_stats_are_deltas():
    """stats['n_intersections'] is this slide's work, not the lifetime total
    of the miner's persistent engine."""
    cfg = StreamConfig(min_sup=5, n_blocks=2, block_txns=32)
    miner = StreamingMiner(N_ITEMS, cfg)
    per_slide = [miner.advance(b).stats["n_intersections"]
                 for b in _batches(4, 28, seed=11)]
    assert sum(per_slide) == miner.engine.n_intersections
    assert all(c > 0 for c in per_slide)


def test_push_mine_separately():
    """Mining on a cadence: push() N times, mine_window() once."""
    cfg = StreamConfig(min_sup=4, n_blocks=4, block_txns=32)
    miner = StreamingMiner(N_ITEMS, cfg)
    for batch in _batches(3, 20, seed=8):
        miner.push(batch)
    res = miner.mine_window()
    batch_res = mine(miner.window_transactions(), N_ITEMS,
                     EclatConfig(min_sup=4))
    assert res.support_map() == batch_res.support_map()


def test_empty_window_and_empty_batches():
    cfg = StreamConfig(min_sup=2, n_blocks=2, block_txns=32)
    miner = StreamingMiner(N_ITEMS, cfg)
    res = miner.mine_window()
    assert res.total == 0 and res.support_map() == {}
    res = miner.advance([])
    assert res.total == 0


# ---------------------------------------------------------------------------
# invariant checks are real exceptions (they must survive `python -O`)
# ---------------------------------------------------------------------------

def _corrupt_and_mine(miner):
    """Items 0/1/2 are all frequent but 1 and 2 never co-occur; inflating
    the cached count makes the prefilter pass a pair the engine refutes."""
    miner.push([[0, 1]] * 8 + [[0, 2]] * 8)
    miner.cooc[1, 2] = miner.cooc[2, 1] = 50
    return miner.mine_window()


def test_cached_count_disagreement_raises():
    """Regression: the level-2 cross-check was a bare ``assert`` — under
    ``python -O`` a corrupt count matrix produced silently wrong windows."""
    cfg = StreamConfig(min_sup=5, n_blocks=2, block_txns=32)
    miner = StreamingMiner(N_ITEMS, cfg)
    with pytest.raises(RuntimeError, match="co-occurrence counts disagree"):
        _corrupt_and_mine(miner)


def test_ring_validate_raises_on_divergence():
    ring = WindowRing(N_ITEMS, n_blocks=2, block_txns=32)
    ring.push(_batches(1, 20, seed=13)[0])
    ring.validate()
    ring.words[0, 0] ^= np.uint32(1)            # corrupt the host mirror
    with pytest.raises(RuntimeError, match="diverged"):
        ring.validate()
    ring.words[0, 0] ^= np.uint32(1)
    ring.block_counts[0] = -1                   # corrupt the occupancy
    with pytest.raises(RuntimeError, match="block_counts"):
        ring.validate()
    ring.block_counts[0] = 0                    # support > live txns in slot
    with pytest.raises(RuntimeError, match="live transactions"):
        ring.validate()


def test_invariants_fire_under_python_O():
    """The whole point of the fix: run the corruption scenario in a
    ``python -O`` subprocess (asserts stripped) and require the exception."""
    import subprocess
    import sys
    snippet = (
        "import numpy as np\n"
        "from repro.streaming import StreamConfig, StreamingMiner\n"
        "assert False, 'proof this build strips asserts'  # -O removes this\n"
        "miner = StreamingMiner(12, StreamConfig(min_sup=5, n_blocks=2, "
        "block_txns=32))\n"
        "miner.push([[0, 1]] * 8 + [[0, 2]] * 8)\n"
        "miner.cooc[1, 2] = miner.cooc[2, 1] = 50\n"
        "try:\n"
        "    miner.mine_window()\n"
        "except RuntimeError as e:\n"
        "    print('RAISED:', type(e).__name__)\n"
        "else:\n"
        "    raise SystemExit('invariant did NOT fire under -O')\n"
    )
    import os
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + ".")
    r = subprocess.run([sys.executable, "-O", "-c", snippet],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr
    assert "RAISED: RuntimeError" in r.stdout


# ---------------------------------------------------------------------------
# the serving-layer query surface
# ---------------------------------------------------------------------------

def _service(seed=9):
    cfg = StreamConfig(min_sup=5, n_blocks=2, block_txns=32)
    service = StreamQueryService(StreamingMiner(N_ITEMS, cfg))
    for batch in _batches(3, 30, seed=seed):
        service.ingest(batch)
    return service


def test_topk_sorted_and_bounded():
    service = _service()
    top = service.top_k_itemsets(k=5, min_len=2)
    assert 0 < len(top) <= 5
    sups = [s for _, s in top]
    assert sups == sorted(sups, reverse=True)
    assert all(len(it) >= 2 for it, _ in top)
    # support() agrees with the snapshot
    it, s = top[0]
    assert service.support(it) == s
    assert service.support((11, 10, 9)) in (0, service.support((9, 10, 11)))


def test_rules_confidence_and_cache():
    service = _service()
    rules = service.rules(min_conf=0.6)
    smap = service.result.support_map()
    for ante, cons, conf, sup in rules:
        assert conf >= 0.6
        assert sup == smap[tuple(sorted(ante + cons))]
        assert abs(conf - sup / smap[ante]) < 1e-12
    assert service.rules(min_conf=0.6) is rules          # cached per snapshot
    service.ingest(_batches(1, 30, seed=10)[0])
    assert service.rules(min_conf=0.6) is not rules      # invalidated by slide


def test_answer_batch_packs_and_answers_all():
    service = _service()
    queries = [ItemsetQuery(qid=i, kind="topk", k=3, min_len=1 + i % 2)
               for i in range(5)]
    queries.append(ItemsetQuery(qid=99, kind="rules", min_conf=0.7, k=4))
    answers, stats = service.answer_batch(queries, n_batches=3)
    assert set(answers) == {0, 1, 2, 3, 4, 99}
    assert len(answers[99]) <= 4
    assert 0 < stats["padding_efficiency"] <= 1.0
    with pytest.raises(ValueError, match="unknown query kind"):
        service.answer_batch([ItemsetQuery(qid=1, kind="nope")], 1)


def test_answer_batch_executes_the_packing_it_reports():
    """Regression: answer_batch computed a greedy-LPT packing, answered in
    input order, and discarded the assignment — the reported
    padding_efficiency described work that never happened.  The per-slot
    counts must now match the assignment pack_queries produced."""
    from repro.serving import pack_queries
    service = _service()
    queries = [ItemsetQuery(qid=i, kind="rules" if i % 3 == 0 else "topk")
               for i in range(7)]
    answers, stats = service.answer_batch(queries, n_batches=3)
    assert set(answers) == set(range(7))
    per_slot = stats["queries_per_slot"]
    assert len(per_slot) == 3 and sum(per_slot) == len(queries)
    # the executed slot loads are exactly the ones the partitioner assigned
    assign, _ = pack_queries(queries, 3, max(len(service._itemsets), 1))
    expect = [int((assign == s).sum()) for s in range(3)]
    assert per_slot == expect
    # heterogeneous work means the pack is non-trivial (not all one slot)
    assert max(per_slot) < len(queries)


def test_windowresult_rules_passthrough():
    service = _service()
    res = service.result
    assert res.rules(0.9) == [r for r in res.rules(0.9) if r[2] >= 0.9]
