"""The runnable examples must stay runnable (fast reduced invocations)."""
import os
import subprocess
import sys

import pytest

ENV = dict(os.environ, PYTHONPATH="src")


def run(args, timeout=900):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=ENV, cwd=os.getcwd(), timeout=timeout)


def test_quickstart():
    r = run(["examples/quickstart.py", "--dataset", "mushroom",
             "--min-sup", "0.4", "--scale", "0.1"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "frequent itemsets" in r.stdout


def test_mine_driver():
    r = run(["-m", "repro.launch.mine", "--dataset", "chess",
             "--min-sup", "0.85", "--scale", "0.1", "--variant", "v6"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[mine]" in r.stdout


def test_mine_distributed():
    r = run(["examples/mine_distributed.py", "--devices", "2",
             "--min-sup", "0.35"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "recovered" in r.stdout
