"""The runnable examples must stay runnable (fast reduced invocations)."""
import os
import subprocess
import sys

import pytest

ENV = dict(os.environ, PYTHONPATH="src")


def run(args, timeout=900):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=ENV, cwd=os.getcwd(), timeout=timeout)


def test_quickstart():
    r = run(["examples/quickstart.py", "--dataset", "mushroom",
             "--min-sup", "0.4", "--scale", "0.1"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "frequent itemsets" in r.stdout


def test_quickstart_rules_output():
    """ARM step 2 through the quickstart surface: rules printed, conf bound."""
    r = run(["examples/quickstart.py", "--dataset", "chess",
             "--min-sup", "0.85", "--scale", "0.1", "--rules"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "association rules at conf>=0.9" in r.stdout
    n_rules = int(r.stdout.split(" association rules")[0].rsplit("\n", 1)[-1])
    assert n_rules > 0
    # every printed rule line carries a confidence within [0.9, 1]
    printed = [l for l in r.stdout.splitlines() if "conf=" in l]
    assert printed, r.stdout
    for line in printed:
        conf = float(line.split("conf=")[1].split()[0])
        assert 0.9 <= conf <= 1.0


def test_mine_driver():
    r = run(["-m", "repro.launch.mine", "--dataset", "chess",
             "--min-sup", "0.85", "--scale", "0.1", "--variant", "v6"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[mine]" in r.stdout


def test_mine_driver_min_conf_rules():
    """generate_rules through the launch.mine --min-conf CLI path."""
    r = run(["-m", "repro.launch.mine", "--dataset", "chess",
             "--min-sup", "0.85", "--scale", "0.1", "--min-conf", "0.8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "rules at conf>=0.8" in r.stdout
    n_rules = int(r.stdout.split("[mine] ")[2].split(" rules")[0])
    assert n_rules > 0


def test_stream_driver():
    r = run(["-m", "repro.launch.stream", "--batches", "4", "--n-blocks", "2",
             "--block-txns", "128", "--min-sup", "0.02", "--min-conf", "0.8",
             "--backend", "jnp"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[stream] slide   3" in r.stdout
    assert "rules at conf>=0.8" in r.stdout


def test_stream_example_parity():
    r = run(["examples/stream_topk.py", "--batches", "4", "--n-blocks", "2",
             "--block-txns", "128"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "parity: windowed == batch mine()" in r.stdout


def test_mine_distributed():
    r = run(["examples/mine_distributed.py", "--devices", "2",
             "--min-sup", "0.35"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "recovered" in r.stdout
