"""Lineage recovery + mining checkpoints (fault tolerance of the mining job)."""
import os

import numpy as np

from repro.core import (EclatConfig, assign_partitions, build_vertical,
                        load_mining_checkpoint, mine, recover_partition)


def make_db(seed=7, n_items=14, n_txn=200):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 8), replace=False).tolist())
        if rng.random() < 0.4:
            t |= {0, 1, 2, 3}
        txns.append(sorted(t))
    return txns


def test_recover_partition_reproduces_subtree():
    txns = make_db()
    ms, p = 30, 8
    db = build_vertical(txns, 14, ms)
    table = assign_partitions(db.n_items - 1, "hash", p)
    full = mine(txns, 14, EclatConfig(min_sup=ms, variant="v4", p=p))
    rank_of_item = {int(it): r for r, it in enumerate(db.items)}
    for pid in range(p):
        rec = recover_partition(db, table, pid=pid, abs_min_sup=ms)
        expect = {}
        for iset, sup in full.support_map().items():
            if len(iset) < 2:
                continue
            ranks = sorted(rank_of_item[i] for i in iset)
            if table[ranks[0]] == pid:
                expect[iset] = sup
        assert rec == expect, f"partition {pid}"


def test_mining_checkpoint_roundtrip(tmp_path):
    txns = make_db()
    cfg = EclatConfig(min_sup=30, variant="v4", p=4,
                      checkpoint_dir=str(tmp_path), checkpoint_every_level=True)
    res = mine(txns, 14, cfg)
    ckpts = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert ckpts, "no checkpoints written"
    store, frontier = load_mining_checkpoint(os.path.join(tmp_path, ckpts[-1]))
    # restored levels must be a prefix (by level) of the final store
    for lvl_restored, lvl_final in zip(store.levels, res.store.levels):
        np.testing.assert_array_equal(lvl_restored.support, lvl_final.support)
        np.testing.assert_array_equal(lvl_restored.item_rank, lvl_final.item_rank)
    assert frontier["bitmaps"].ndim == 2
