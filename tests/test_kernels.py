"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.popcount_support import (popcount_support,
                                            popcount_support_ref)
from repro.kernels.trimatrix import (cooccurrence_mxu_ref, trimatrix,
                                     trimatrix_ref)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,w", [(1, 1), (7, 3), (64, 17), (300, 130), (257, 513)])
@pytest.mark.parametrize("bm,bw", [(64, 128), (16, 16)])
def test_popcount_support_sweep(m, w, bm, bw):
    a = jnp.asarray(RNG.integers(0, 2**32, (m, w), dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, (m, w), dtype=np.uint32))
    ir, sr = popcount_support_ref(a, b)
    ik, sk = popcount_support(a, b, block_m=bm, block_w=bw, interpret=True)
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ik))
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(sk))


@pytest.mark.parametrize("n,w", [(1, 1), (5, 3), (33, 9), (70, 40), (130, 65)])
def test_trimatrix_sweep(n, w):
    b = jnp.asarray(RNG.integers(0, 2**32, (n, w), dtype=np.uint32))
    r = trimatrix_ref(b)
    k = trimatrix(b, block_n=32, block_w=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(k))


def test_trimatrix_matches_mxu_variant():
    b = jnp.asarray(RNG.integers(0, 2**32, (24, 7), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(trimatrix_ref(b)), np.asarray(cooccurrence_mxu_ref(b, 7 * 32)))


def test_trimatrix_diag_is_support():
    from repro.core.bitmap import support_np
    b = RNG.integers(0, 2**32, (12, 5), dtype=np.uint32)
    c = np.asarray(trimatrix_ref(jnp.asarray(b)))
    np.testing.assert_array_equal(np.diag(c), support_np(b))


@pytest.mark.parametrize(
    "b,h,hkv,s,d,causal,win",
    [
        (1, 2, 2, 64, 16, True, None),
        (2, 4, 2, 100, 32, True, None),     # GQA + ragged tail
        (1, 8, 1, 128, 16, False, None),    # MQA, bidirectional
        (1, 4, 4, 96, 16, True, 24),        # sliding window
    ],
)
def test_flash_attention_sweep(b, h, hkv, s, d, causal, win):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    r = attention_ref(q, k, v, causal=causal, window=win)
    o = flash_attention(q, k, v, causal=causal, window=win,
                        block_q=32, block_k=32, interpret=True)
    assert float(jnp.abs(r - o).max()) < 2e-5


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 64, 16)), jnp.bfloat16)
    r = attention_ref(q, k, v, causal=True)
    o = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    assert float(jnp.abs(r.astype(jnp.float32) - o.astype(jnp.float32)).max()) < 3e-2


def test_chunked_flash_matches_kernel_semantics():
    """The XLA fallback used by the models must agree with the kernel oracle."""
    from repro.models.attention import flash_chunked
    q = jnp.asarray(RNG.normal(size=(2, 70, 4, 16)), jnp.float32)   # (B,S,H,D)
    k = jnp.asarray(RNG.normal(size=(2, 70, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 70, 2, 16)), jnp.float32)
    out = flash_chunked(q, k, v, causal=True, window=0, sm_scale=16 ** -0.5,
                        q_chunk=32, k_chunk=32)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    assert float(jnp.abs(out - ref.transpose(0, 2, 1, 3)).max()) < 2e-5


@pytest.mark.parametrize(
    "b,kv,g,s,d,win,bs",
    [
        (2, 2, 3, 64, 16, 0, 32),
        (1, 4, 1, 100, 32, 0, 32),    # ragged tail
        (2, 2, 2, 96, 16, 24, 32),    # sliding window
        (1, 1, 8, 33, 64, 0, 16),     # MQA, many groups
    ],
)
def test_decode_attention_sweep(b, kv, g, s, d, win, bs):
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    q = jnp.asarray(RNG.normal(size=(b, kv, g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    ln = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    r = decode_attention_ref(q, k, v, ln, window=win)
    o = decode_attention(q, k, v, ln, window=win, block_s=bs, interpret=True)
    assert float(jnp.abs(r - o).max()) < 2e-5


def test_decode_attention_matches_model_path():
    """Kernel semantics must equal the model's grouped decode attention."""
    from repro.kernels.decode_attention import decode_attention_ref
    from repro.models.attention import _decode_attend
    b, kv, g, s, d = 2, 2, 3, 40, 16
    q4 = jnp.asarray(RNG.normal(size=(b, 1, kv * g, d)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    length = 33
    model_out = _decode_attend(q4, ck, cv, length, d ** -0.5, 0, 0.0)
    kern_out = decode_attention_ref(
        q4.reshape(b, kv, g, d), ck, cv,
        jnp.full((b,), length, jnp.int32))
    assert float(jnp.abs(model_out.reshape(b, kv, g, d) - kern_out).max()) < 2e-5
