"""Dataset generators: Table-2 statistical-shape conformance + determinism."""
import numpy as np
import pytest

from repro.data import PAPER_DATASETS, generate


@pytest.mark.parametrize("name", list(PAPER_DATASETS))
def test_table2_shape_conformance(name):
    spec = PAPER_DATASETS[name]
    sc = 0.05 if spec.n_txn > 20000 else 0.2
    txns, _ = generate(name, scale=sc, seed=1)
    widths = np.array([len(t) for t in txns])
    items = set(i for t in txns for i in t)
    assert len(txns) == max(16, int(round(spec.n_txn * sc)))
    assert max(items) < spec.n_items
    # average transaction width within 15% of Table 2
    assert abs(widths.mean() - spec.avg_width) / spec.avg_width < 0.15
    # items must be valid and transactions deduplicated + sorted
    for t in txns[:50]:
        assert t == sorted(set(t))


def test_generator_deterministic():
    a, _ = generate("chess", scale=0.1, seed=3)
    b, _ = generate("chess", scale=0.1, seed=3)
    assert a == b
    c, _ = generate("chess", scale=0.1, seed=4)
    assert a != c


def test_attribute_data_is_dense():
    txns, spec = generate("chess", scale=0.1, seed=0)
    widths = {len(t) for t in txns}
    # chess rows are fixed-width attribute vectors (modulo rare collisions)
    assert max(widths) <= 37 and min(widths) >= 35


def test_clickstream_is_sparse_zipf():
    txns, spec = generate("BMS_WebView_2", scale=0.05, seed=0)
    counts = {}
    for t in txns:
        for i in t:
            counts[i] = counts.get(i, 0) + 1
    freq = sorted(counts.values(), reverse=True)
    # zipf head: top item at least 20x the median
    assert freq[0] >= 20 * freq[len(freq) // 2]
