"""RDD-Eclat variants vs the brute-force oracle (the system's core invariant:
every variant, every knob, bit-identical frequent itemsets + supports)."""
import numpy as np
import pytest

from repro.core import EclatConfig, apriori_mine, bruteforce_fim, mine


def make_db(seed=7, n_items=10, n_txn=150, base=(0, 1, 2, 3)):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= set(base)
        txns.append(sorted(t))
    return txns


DB = make_db()
ORACLES = {ms: bruteforce_fim(DB, min_sup=ms) for ms in (20, 35, 60)}


@pytest.mark.parametrize("variant", ["v1", "v2", "v3", "v4", "v5", "v6"])
@pytest.mark.parametrize("min_sup", [20, 35, 60])
def test_variant_matches_oracle(variant, min_sup):
    res = mine(DB, 10, EclatConfig(min_sup=min_sup, variant=variant, p=3,
                                   use_diffsets=(variant == "v6")))
    assert res.support_map() == ORACLES[min_sup]


def test_no_trimatrix_path():
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v5", p=3, tri_matrix=False))
    assert res.support_map() == ORACLES[20]


def test_fractional_min_sup():
    res = mine(DB, 10, EclatConfig(min_sup=0.3, variant="v4", p=3))
    oracle = bruteforce_fim(DB, min_sup=res.stats["abs_min_sup"])
    assert res.support_map() == oracle
    assert res.stats["abs_min_sup"] == int(np.ceil(0.3 * len(DB)))


def _mesh_for(backend):
    from repro.dist.compat import make_mesh
    import jax
    if backend in ("sharded", "tidsharded"):
        return make_mesh((4,), ("data",))
    if backend == "grid":
        return make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])
    return None


@pytest.mark.parametrize("backend", ["jnp", "pallas", "sharded",
                                     "tidsharded", "grid"])
@pytest.mark.parametrize("max_k", [1, 2, 3, None])
def test_max_k_boundaries_all_backends(backend, max_k):
    """Regression: max_k < 3 was ignored — level 2 was always expanded and
    recorded (max_k=1 returned two levels).  Every backend must return
    exactly the oracle truncated at max_k."""
    shard = {"tidsharded": "words", "grid": "grid"}.get(backend, "pairs")
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3,
                                   backend=backend, shard=shard,
                                   max_k=max_k, bucket_min=32),
               mesh=_mesh_for(backend))
    expect = {k: v for k, v in ORACLES[20].items()
              if max_k is None or len(k) <= max_k}
    assert res.support_map() == expect
    if max_k is not None:
        assert len(res.counts) <= max_k


def test_max_k_one_keeps_stats_shape():
    """The max_k<2 early return must carry the same stats keys as a full
    run (balance + engine counters), just with no device work recorded."""
    full = mine(DB, 10, EclatConfig(min_sup=20, variant="v6", p=3))
    k1 = mine(DB, 10, EclatConfig(min_sup=20, variant="v6", p=3, max_k=1))
    assert k1.stats["backend"] == full.stats["backend"]
    assert (k1.stats["partition_balance"]["estimated_loads"]
            == full.stats["partition_balance"]["estimated_loads"])
    assert k1.stats["n_intersections"] == 0


def test_max_k_validation():
    for bad in (0, -1):
        with pytest.raises(ValueError, match="max_k"):
            mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3,
                                     max_k=bad))


def test_max_k_one_no_trimatrix_path():
    """max_k=1 must also skip the chunked no-tri level 2."""
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v5", p=3,
                                   tri_matrix=False, max_k=1))
    assert res.support_map() == {k: v for k, v in ORACLES[20].items()
                                 if len(k) == 1}
    assert len(res.counts) == 1


# ---------------------------------------------------------------------------
# the tri-matrix level-2 cross-check is a real exception (survives -O)
# ---------------------------------------------------------------------------

def test_trimatrix_corruption_raises(monkeypatch):
    """Regression: the batch tri path assumed 'the mask is all-true' without
    checking — a corrupt co-occurrence pass would misalign iu/ju (all
    pre-filtered pairs) against res.supports (survivors only) silently."""
    from repro.core import eclat as eclat_mod
    real = eclat_mod.cooccurrence_counts

    def corrupt(bitmaps, *a, **kw):
        # inflate every pair count past the threshold: genuinely infrequent
        # pairs now pass the prefilter and the engine refutes them
        return real(bitmaps, *a, **kw) + 60

    monkeypatch.setattr(eclat_mod, "cooccurrence_counts", corrupt)
    with pytest.raises(RuntimeError, match="tri-matrix pass is corrupt"):
        mine(DB, 10, EclatConfig(min_sup=60, variant="v4", p=3))


def test_apriori_matches_oracle():
    for ms in (20, 35, 60):
        ap = apriori_mine(DB, 10, ms)
        assert ap.support_map == ORACLES[ms]


def test_eclat_fewer_db_passes_than_apriori():
    """The algorithmic claim behind the paper's speedups: Eclat touches the
    horizontal DB once; Apriori re-scans it every level."""
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3))
    ap = apriori_mine(DB, 10, 20)
    assert len(ap.stats["level_s"]) >= 3      # re-scans: one per level >= 2
    assert max(len(k) for k in res.support_map()) == max(len(k) for k in ap.support_map)


def test_filtering_stats_reported():
    res = mine(DB, 10, EclatConfig(min_sup=60, variant="v2", p=3))
    assert "filter_reduction" in res.stats
    assert 0.0 <= res.stats["filter_reduction"] <= 1.0


def test_empty_result_below_support():
    res = mine(DB, 10, EclatConfig(min_sup=len(DB) + 1, variant="v4", p=3))
    assert res.total == 0


def test_rules_generation():
    from repro.core import generate_rules
    res = mine(DB, 10, EclatConfig(min_sup=35, variant="v4", p=3))
    rules = generate_rules(res.support_map(), min_conf=0.8)
    sm = res.support_map()
    for ante, cons, conf, sup in rules:
        joint = tuple(sorted(set(ante) | set(cons)))
        assert abs(conf - sm[joint] / sm[ante]) < 1e-9
        assert conf >= 0.8


# ---------------------------------------------------------------------------
# min_sup resolution: type disambiguates fraction vs count
# ---------------------------------------------------------------------------

def test_resolve_min_sup_boundaries():
    from repro.core.eclat import resolve_min_sup
    n = 200
    # float in (0, 1] is a fraction of n_txn
    assert resolve_min_sup(1.0, n) == n          # 100% support, NOT count 1
    assert resolve_min_sup(0.5, n) == 100
    assert resolve_min_sup(0.003, n) == 1        # ceil, floored at 1
    assert resolve_min_sup(np.float64(1.0), n) == n
    # int >= 1 (or float > 1) is an absolute count
    assert resolve_min_sup(1, n) == 1
    assert resolve_min_sup(np.int64(1), n) == 1
    assert resolve_min_sup(25, n) == 25
    assert resolve_min_sup(2.0, n) == 2
    # rejected: zero, negatives, bools, non-integral float counts
    for bad in (0, -3, 0.0, -0.5, 10.7):
        with pytest.raises(ValueError):
            resolve_min_sup(bad, n)
    with pytest.raises(TypeError):
        resolve_min_sup(True, n)


def test_min_sup_full_support_fraction_mines_universal_items():
    """min_sup=1.0 must mean 'in every transaction' — the regression was
    parsing it as absolute count 1 (i.e. everything is frequent)."""
    txns = [[0, 1, 2], [0, 1, 3], [0, 2, 3]] * 10
    res = mine(txns, 4, EclatConfig(min_sup=1.0, variant="v4", p=2))
    assert res.stats["abs_min_sup"] == len(txns)
    assert set(res.support_map()) == {(0,)}      # only item 0 is universal
    # streaming and the Apriori baseline resolve identically (shared
    # resolve_min_sup)
    from repro.streaming import StreamConfig
    assert StreamConfig(min_sup=1.0, n_blocks=2,
                        block_txns=32).resolve_min_sup(len(txns)) == len(txns)
    assert apriori_mine(txns, 4, 1.0).stats["abs_min_sup"] == len(txns)


# ---------------------------------------------------------------------------
# use_diffsets is rejected (not silently ignored) off v6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["v1", "v2", "v3", "v4", "v5"])
def test_use_diffsets_rejected_off_v6(variant):
    with pytest.raises(ValueError, match="use_diffsets"):
        mine(DB, 10, EclatConfig(min_sup=20, variant=variant, p=3,
                                 use_diffsets=True))


def test_use_diffsets_accepted_on_v6():
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v6", p=3,
                                   use_diffsets=True))
    assert res.support_map() == ORACLES[20]


# ---------------------------------------------------------------------------
# partition balance reports the estimated loads that drove partitioning
# ---------------------------------------------------------------------------

def test_partition_balance_uses_pair_work_estimate():
    from repro.core.equivalence import pair_work
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v6", p=3))
    bal = res.stats["partition_balance"]
    loads = np.asarray(bal["estimated_loads"])
    assert loads.shape == (3,)
    n1 = res.stats["n_freq_items"]
    sizes1 = (n1 - 1 - np.arange(n1 - 1)).clip(min=0)
    est = pair_work(sizes1 + 1, res.stats["n_words"])
    # the reported loads partition exactly the estimate that was optimized
    assert loads.sum() == pytest.approx(est.sum())
    # uniform weighting would make every v6 class identical; the real
    # estimate is skewed (class work falls with prefix rank)
    assert est.max() != est.min()
    assert bal["padding_efficiency"] == pytest.approx(
        loads.sum() / (loads.max() * 3))
