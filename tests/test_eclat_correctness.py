"""RDD-Eclat variants vs the brute-force oracle (the system's core invariant:
every variant, every knob, bit-identical frequent itemsets + supports)."""
import numpy as np
import pytest

from repro.core import EclatConfig, apriori_mine, bruteforce_fim, mine


def make_db(seed=7, n_items=10, n_txn=150, base=(0, 1, 2, 3)):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= set(base)
        txns.append(sorted(t))
    return txns


DB = make_db()
ORACLES = {ms: bruteforce_fim(DB, min_sup=ms) for ms in (20, 35, 60)}


@pytest.mark.parametrize("variant", ["v1", "v2", "v3", "v4", "v5", "v6"])
@pytest.mark.parametrize("min_sup", [20, 35, 60])
def test_variant_matches_oracle(variant, min_sup):
    res = mine(DB, 10, EclatConfig(min_sup=min_sup, variant=variant, p=3,
                                   use_diffsets=(variant == "v6")))
    assert res.support_map() == ORACLES[min_sup]


def test_no_trimatrix_path():
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v5", p=3, tri_matrix=False))
    assert res.support_map() == ORACLES[20]


def test_fractional_min_sup():
    res = mine(DB, 10, EclatConfig(min_sup=0.3, variant="v4", p=3))
    oracle = bruteforce_fim(DB, min_sup=res.stats["abs_min_sup"])
    assert res.support_map() == oracle
    assert res.stats["abs_min_sup"] == int(np.ceil(0.3 * len(DB)))


def test_max_k_truncates():
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3, max_k=2))
    full = ORACLES[20]
    expect = {k: v for k, v in full.items() if len(k) <= 2}
    assert res.support_map() == expect


def test_apriori_matches_oracle():
    for ms in (20, 35, 60):
        ap = apriori_mine(DB, 10, ms)
        assert ap.support_map == ORACLES[ms]


def test_eclat_fewer_db_passes_than_apriori():
    """The algorithmic claim behind the paper's speedups: Eclat touches the
    horizontal DB once; Apriori re-scans it every level."""
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3))
    ap = apriori_mine(DB, 10, 20)
    assert len(ap.stats["level_s"]) >= 3      # re-scans: one per level >= 2
    assert max(len(k) for k in res.support_map()) == max(len(k) for k in ap.support_map)


def test_filtering_stats_reported():
    res = mine(DB, 10, EclatConfig(min_sup=60, variant="v2", p=3))
    assert "filter_reduction" in res.stats
    assert 0.0 <= res.stats["filter_reduction"] <= 1.0


def test_empty_result_below_support():
    res = mine(DB, 10, EclatConfig(min_sup=len(DB) + 1, variant="v4", p=3))
    assert res.total == 0


def test_rules_generation():
    from repro.core import generate_rules
    res = mine(DB, 10, EclatConfig(min_sup=35, variant="v4", p=3))
    rules = generate_rules(res.support_map(), min_conf=0.8)
    sm = res.support_map()
    for ante, cons, conf, sup in rules:
        joint = tuple(sorted(set(ante) | set(cons)))
        assert abs(conf - sm[joint] / sm[ante]) < 1e-9
        assert conf >= 0.8
