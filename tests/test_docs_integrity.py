"""The docs-integrity gate (scripts/check_docs.py) passes on the repo and
actually detects the rot classes it exists for."""
import importlib.util
import os
import sys

_SPEC = importlib.util.spec_from_file_location(
    "check_docs",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_docs)


def test_repo_docs_are_clean():
    assert check_docs.run_all() == []


def test_design_has_all_cited_sections():
    # the historically-dangling citations (§4 serving/configs, §5 streaming,
    # §6 kernel dispatch) must resolve
    assert {1, 2, 3, 4, 5, 6} <= check_docs.design_sections()


def test_section_ref_regex_matches_citation_styles():
    pat = check_docs.SECTION_REF
    assert pat.search("see DESIGN.md §4 for details").group(1) == "4"
    assert pat.search("(DESIGN §4, paper-technique transfer)").group(1) == "4"
    assert pat.search("[DESIGN.md](DESIGN.md) §2 has it").group(1) == "2"
    m = pat.search("model (see DESIGN.md §2-3): the host")
    assert (m.group(1), m.group(2)) == ("2", "3")
    assert pat.search("plain § sign, no DESIGN nearby") is None


def test_wiki_and_link_regexes():
    assert check_docs.WIKI_REF.search("see [[streaming-contract]] later")
    assert check_docs.WIKI_REF.search("normal [text](x.md) link") is None
    assert check_docs.MD_LINK.search("[text](DESIGN.md)").group(1) == "DESIGN.md"
    # code spans are stripped before link/placeholder checks
    assert check_docs._strip_code("a `[[x]]` b") == "a  b"
    assert "```" not in check_docs._strip_code("a\n```\n[[x]]\n```\nb")
