"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, shape + finiteness asserts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs
from repro.configs.reduced import reduced_config
from repro.models import Model, init_params, stages_meta

ARCHS = list_configs()


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.asarray(np.arange(b * s).reshape(b, s) % cfg.vocab_size, jnp.int32)}
    if cfg.n_encoder_layers:
        batch["enc_embeds"] = jnp.full((b, cfg.encoder_len, cfg.d_model), 0.01, jnp.float32)
    if cfg.frontend == "vision":
        batch["img_embeds"] = jnp.full((b, cfg.frontend_len, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = reduced_config(get_config(arch))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), path


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    from repro.training.optimizer import adamw_init, adamw_update
    cfg = reduced_config(get_config(arch))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    batch = make_batch(cfg)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
        params, opt = adamw_update(params, grads, opt, lr=1e-3)
        return params, opt, loss

    params2, opt2, loss1 = jax.jit(step)(params, opt, batch)
    _, _, loss2 = jax.jit(step)(params2, opt2, batch)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    assert float(loss2) < float(loss1) + 0.5  # no blow-up after an update


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_metadata(arch):
    """Full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    assert sum(c for _, c in stages_meta(cfg)) == cfg.n_layers


def test_param_counts_in_range():
    """Sanity: analytic N roughly matches each model's nameplate size."""
    expect = {
        "gemma-2b": (2.0e9, 3.5e9),
        "internlm2-20b": (17e9, 23e9),
        "gemma3-4b": (3.0e9, 5.5e9),
        "command-r-35b": (30e9, 40e9),
        "hymba-1.5b": (1.0e9, 2.0e9),
        "whisper-base": (0.05e9, 0.12e9),
        # our mLSTM block (dense in_proj + blockdiag qkv) lands at 1.82B for
        # the 48L/d2048 config — close to but above the 1.3B nameplate
        # (the published block is leaner); range reflects the implementation
        "xlstm-1.3b": (0.9e9, 2.0e9),
        "grok-1-314b": (250e9, 360e9),
        "llama4-maverick-400b-a17b": (330e9, 480e9),
        "phi-3-vision-4.2b": (3.4e9, 5.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
