"""Equivalence-class partitioners: Algorithm-10 formulas + balance props."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (assign_partitions, default_partitioner,
                        greedy_partitioner, hash_partitioner,
                        partition_stats, reverse_hash_partitioner)


def test_hash_partitioner_formula():
    v = np.arange(17)
    np.testing.assert_array_equal(hash_partitioner(v, 5), v % 5)


def test_reverse_hash_formula_paper_example():
    """Paper Algorithm 10: r = v % p; v >= p ? (p-1)-r : r."""
    p = 4
    v = np.arange(12)
    got = reverse_hash_partitioner(v, p)
    expect = []
    for vi in v:
        r = vi % p
        expect.append((p - 1) - r if vi >= p else r)
    np.testing.assert_array_equal(got, expect)


def test_default_is_identity_mod_cores():
    v = np.arange(9)
    np.testing.assert_array_equal(default_partitioner(v, 4), v % 4)


def test_greedy_beats_hash_on_skewed_work():
    """The paper's point: class work is heavily skewed by prefix rank; the
    reverse/greedy schemes must balance strictly better than plain hash."""
    n, p = 64, 8
    work = (n - 1 - np.arange(n)).astype(float) ** 2   # first-level pair work
    res = {}
    for name in ("hash", "reverse_hash", "greedy"):
        a = assign_partitions(n, name, p, work=work)
        res[name] = partition_stats(a, work, p)["padding_efficiency"]
    assert res["greedy"] >= res["reverse_hash"] >= res["hash"] - 1e-9
    assert res["greedy"] > 0.95


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 200), st.integers(1, 16))
def test_property_all_partitions_in_range(n, p):
    for name in ("default", "hash", "reverse_hash", "greedy"):
        a = assign_partitions(n, name, p)
        assert a.shape == (n,)
        assert a.min() >= 0
        # default creates up to n partitions then schedules mod p
        limit = p
        assert a.max() < max(limit, 1)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 100), st.integers(2, 8), st.integers(0, 10_000))
def test_property_greedy_no_worse_than_any_hash(n, p, seed):
    rng = np.random.default_rng(seed)
    work = rng.exponential(1.0, n) ** 2
    g = partition_stats(assign_partitions(n, "greedy", p, work=work), work, p)
    h = partition_stats(assign_partitions(n, "hash", p, work=work), work, p)
    assert g["max"] <= h["max"] + 1e-9


def test_partition_stats_fields():
    a = np.array([0, 0, 1, 1])
    w = np.array([1.0, 1.0, 1.0, 1.0])
    s = partition_stats(a, w, 2)
    assert s["max"] == 2.0 and s["mean"] == 2.0
    assert abs(s["padding_efficiency"] - 1.0) < 1e-9
