"""Engine backend parity: every backend x mode x shape must be bit-exact.

The jnp reference defines the semantics; the fused pallas path (both the
dispatching jit and the real kernel under ``interpret=True``) and the sharded
shard_map path must reproduce its survivor masks, supports, and bitmaps
bit-for-bit — including empty, singleton, and non-multiple-of-block shapes.
Full ``mine()`` runs must agree across backends for every variant v1-v6.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import EclatConfig, bruteforce_fim, mine
from repro.core import engine as eng
from repro.core.bitmap import popcount_np

RNG = np.random.default_rng(42)

MODES = [eng.MODE_TIDSET, eng.MODE_TID_TO_DIFF, eng.MODE_DIFFSET]


def _mesh4():
    from repro.dist.compat import make_mesh
    return make_mesh((4,), ("data",))


def _grid22():
    import jax
    from repro.dist.compat import make_mesh
    return make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])


def _engine(backend):
    if backend == "jnp":
        return eng.make_engine("jnp", bucket_min=8)
    if backend == "pallas":
        return eng.make_engine("pallas", bucket_min=8)
    if backend == "pallas-kernel":
        return eng.make_engine("pallas", bucket_min=8, interpret=True)
    if backend == "sharded-jnp":
        return eng.make_engine("sharded", mesh=_mesh4(), bucket_min=8, inner="jnp")
    if backend == "sharded-pallas-kernel":
        return eng.make_engine("sharded", mesh=_mesh4(), bucket_min=8,
                               inner="pallas", interpret=True)
    if backend == "tidsharded-jnp":
        return eng.make_engine("tidsharded", mesh=_mesh4(), bucket_min=8,
                               inner="jnp")
    if backend == "tidsharded-pallas-kernel":
        return eng.make_engine("tidsharded", mesh=_mesh4(), bucket_min=8,
                               inner="pallas", interpret=True)
    if backend == "grid-jnp":
        return eng.make_engine("grid", mesh=_grid22(), bucket_min=8,
                               inner="jnp")
    if backend == "grid-pallas-kernel":
        return eng.make_engine("grid", mesh=_grid22(), bucket_min=8,
                               inner="pallas", interpret=True)
    raise AssertionError(backend)


def _check_level(res, ref_bm, ref_sup, ref_mask, w):
    """Shared parity assertions.  The tid-sharded backend zero-pads the word
    axis to a shard multiple, so bitmap comparison is on [:, :w] plus an
    all-zero check on any pad columns."""
    np.testing.assert_array_equal(res.mask, ref_mask)
    np.testing.assert_array_equal(res.supports, ref_sup)
    # survivors live in rows [:S]; rows beyond are rung padding
    assert res.bitmaps.shape[0] >= ref_bm.shape[0]
    got = np.asarray(res.bitmaps)[: ref_bm.shape[0]]
    np.testing.assert_array_equal(got[:, :w], ref_bm)
    assert not got[:, w:].any()


def _oracle(bitmaps, left, right, sup_left, mode, min_sup):
    a = bitmaps[left]
    b = bitmaps[right]
    if mode == eng.MODE_TIDSET:
        inter = a & b
        sup = popcount_np(inter).sum(-1)
    elif mode == eng.MODE_TID_TO_DIFF:
        inter = a & ~b
        sup = sup_left - popcount_np(inter).sum(-1)
    else:
        inter = b & ~a
        sup = sup_left - popcount_np(inter).sum(-1)
    mask = sup >= min_sup
    return inter[mask], sup[mask], mask


def _case(p, w, q, seed):
    rng = np.random.default_rng(seed)
    bitmaps = rng.integers(0, 2**32, (p, w), dtype=np.uint32)
    left = rng.integers(0, p, q).astype(np.int32)
    right = rng.integers(0, p, q).astype(np.int32)
    sup_left = popcount_np(bitmaps[left]).sum(-1).astype(np.int32) if q else np.zeros(0, np.int32)
    dev = rng.integers(0, 4, q).astype(np.int64)
    return bitmaps, left, right, sup_left, dev


# interpret-mode pallas is slow; keep its shapes small but still cover the
# empty / singleton / non-multiple-of-block corners
SHAPES_FAST = [(1, 1, 0), (1, 1, 1), (5, 3, 13), (64, 4, 37), (130, 9, 21)]
SHAPES_INTERP = [(1, 1, 0), (1, 1, 1), (5, 3, 13), (9, 5, 7)]


@pytest.mark.parametrize("backend", ["jnp", "pallas", "sharded-jnp",
                                     "tidsharded-jnp", "grid-jnp"])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("p,w,q", SHAPES_FAST)
def test_backend_parity(backend, mode, p, w, q):
    bitmaps, left, right, sup_left, dev = _case(p, w, q, seed=p * 1000 + w * 10 + q)
    min_sup = max(1, int(0.4 * w * 32))
    ref_bm, ref_sup, ref_mask = _oracle(bitmaps, left, right, sup_left, mode, min_sup)
    e = _engine(backend)
    res = e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                   mode=mode, min_sup=min_sup,
                   device_of_pair=dev % max(e.n_devices, 1))
    _check_level(res, ref_bm, ref_sup, ref_mask, w)


@pytest.mark.parametrize("backend", ["pallas-kernel", "sharded-pallas-kernel",
                                     "tidsharded-pallas-kernel",
                                     "grid-pallas-kernel"])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("p,w,q", SHAPES_INTERP)
def test_pallas_kernel_parity(backend, mode, p, w, q):
    """The real Pallas kernel (interpret=True on this CPU host) is bit-exact."""
    bitmaps, left, right, sup_left, dev = _case(p, w, q, seed=p * 77 + w * 5 + q)
    min_sup = max(1, int(0.4 * w * 32))
    ref_bm, ref_sup, ref_mask = _oracle(bitmaps, left, right, sup_left, mode, min_sup)
    e = _engine(backend)
    res = e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                   mode=mode, min_sup=min_sup,
                   device_of_pair=dev % max(e.n_devices, 1))
    _check_level(res, ref_bm, ref_sup, ref_mask, w)


def test_sharded_rejects_out_of_range_device_ids():
    """Regression: an out-of-range device id used to leave slot_of_pair
    uninitialized (np.empty garbage) and return wrong supports silently."""
    bitmaps, left, right, sup_left, _ = _case(16, 4, 9, seed=3)
    e = _engine("sharded-jnp")  # 4-device mesh
    for bad in (np.full(9, 4, np.int64),                   # == n_devices
                np.array([0, 1, 2, 3, 0, 1, 2, 3, 17]),    # far out
                np.array([0, -1, 0, 0, 0, 0, 0, 0, 0])):   # negative
        with pytest.raises(ValueError, match="device_of_pair"):
            e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                     mode=eng.MODE_TIDSET, min_sup=1,
                     device_of_pair=bad)
    with pytest.raises(ValueError, match="device_of_pair"):
        e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                 mode=eng.MODE_TIDSET, min_sup=1,
                 device_of_pair=np.zeros(5, np.int64))      # wrong shape


def test_kernel_multi_word_blocks():
    """W spanning several word blocks exercises the popcount accumulator."""
    from repro.kernels.fused_intersect import (fused_intersect_pairs,
                                               fused_intersect_ref)
    bitmaps, left, right, sup_left, _ = _case(12, 300, 6, seed=5)
    bm = jnp.asarray(bitmaps)
    l, r, s = jnp.asarray(left), jnp.asarray(right), jnp.asarray(sup_left)
    for mode in MODES:
        ri, rs, rm = fused_intersect_ref(bm, l, r, s, 900, mode=mode)
        ki, ks, km = fused_intersect_pairs(bm, l, r, s, 900, mode=mode,
                                           block_w=128, interpret=True)
        np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
        np.testing.assert_array_equal(np.asarray(rs), np.asarray(ks))
        np.testing.assert_array_equal(np.asarray(rm), np.asarray(km))


# ---------------------------------------------------------------------------
# full mine() parity across backends
# ---------------------------------------------------------------------------

def _db(seed=7, n_items=10, n_txn=150):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= {0, 1, 2, 3}
        txns.append(sorted(t))
    return txns


DB = _db()
ORACLE = bruteforce_fim(DB, min_sup=25)


@pytest.mark.parametrize("variant", ["v1", "v2", "v3", "v4", "v5", "v6"])
def test_mine_backend_parity(variant):
    maps = {}
    for backend in ("jnp", "pallas"):
        res = mine(DB, 10, EclatConfig(min_sup=25, variant=variant, p=3,
                                       use_diffsets=(variant == "v6"),
                                       backend=backend, bucket_min=32))
        assert res.stats["backend"] == backend
        maps[backend] = res.support_map()
    assert maps["jnp"] == maps["pallas"] == ORACLE


def test_mine_no_trimatrix_backend_parity():
    r_jnp = mine(DB, 10, EclatConfig(min_sup=25, variant="v5", p=3,
                                     tri_matrix=False, backend="jnp"))
    r_pal = mine(DB, 10, EclatConfig(min_sup=25, variant="v5", p=3,
                                     tri_matrix=False, backend="pallas"))
    assert r_jnp.support_map() == r_pal.support_map() == ORACLE


def test_mine_mesh_routes_to_sharded():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v4", p=4), mesh=_mesh4())
    assert res.stats["backend"] == "sharded"
    assert res.support_map() == ORACLE
    assert "device_balance" in res.stats


def test_mine_legacy_batched_alias():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v4", p=3, backend="batched"))
    assert res.stats["backend"] == "pallas"
    assert res.support_map() == ORACLE


# ---------------------------------------------------------------------------
# registry + bucket ladder
# ---------------------------------------------------------------------------

def test_registry_surface():
    assert set(eng.available_backends()) >= {"jnp", "pallas", "sharded",
                                             "tidsharded", "grid"}
    with pytest.raises(ValueError, match="unknown engine backend"):
        eng.make_engine("nope")
    for meshful in ("sharded", "tidsharded", "grid"):
        with pytest.raises(ValueError, match="requires a mesh"):
            eng.make_engine(meshful)


def test_pair_buffers_ladder_reuse():
    bufs = eng.PairBuffers(floor=8)
    qb1, l1, _, _ = bufs.fill(np.arange(5, dtype=np.int32),
                              np.arange(5, dtype=np.int32),
                              np.arange(5, dtype=np.int32))
    assert qb1 == 8 and l1.shape == (8,) and (l1[5:] == 0).all()
    # stale tail from a previous, larger fill must be rezeroed
    qb2, l2, _, _ = bufs.fill(np.full(3, 7, np.int32),
                              np.full(3, 7, np.int32),
                              np.full(3, 7, np.int32))
    assert qb2 == 8 and l2 is l1 and (l2[3:] == 0).all()
    qb3, l3, _, _ = bufs.fill(np.zeros(20, np.int32),
                              np.zeros(20, np.int32),
                              np.zeros(20, np.int32))
    assert qb3 == 24 and l3.shape == (24,) and l3 is not l1


def test_bucket_size_ladder():
    # half-pow2 ladder: floor * {1, 1.5, 2, 3, 4, 6, 8, ...}
    assert [eng.bucket_size(n, 8) for n in (0, 1, 8, 9, 12, 13, 100)] \
        == [8, 8, 8, 12, 12, 16, 128]
    assert [eng.bucket_size(n, 1024) for n in (1, 1025, 1537, 3073)] \
        == [1024, 1536, 2048, 4096]
    # every pair rung (floor f) is also a rung of the finer survivor ladder
    # (floor f/8), so fused-epilogue compaction slices never exceed the block
    for n in (1, 7, 9, 100, 1000, 5000):
        qb = eng.bucket_size(n, 1024)
        assert eng.bucket_size(n, 128) <= qb
