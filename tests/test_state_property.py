"""Property tests on the serializable state contract (DESIGN.md §10).

Invariants held on random streams: snapshot -> tree -> state -> miner ->
snapshot is a fixed point (round-trip idempotence), the disk encoding through
``training.checkpoint`` is lossless, and a snapshot cut at any point of the
stream restores — under the same mesh or any other backend/mesh pairing —
into a miner whose remaining slides are bit-exact with one that never
serialized.
"""
import tempfile

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.streaming import MinerState, RingState, StreamConfig, StreamingMiner
from repro.training import load_checkpoint, save_checkpoint

N_ITEMS = 10

batches_strategy = st.lists(
    st.lists(st.lists(st.integers(0, N_ITEMS - 1), min_size=0, max_size=5),
             min_size=1, max_size=20),
    min_size=1, max_size=4,
)

ALL_BACKENDS = ("jnp", "pallas", "sharded", "tidsharded", "grid")


def _mesh_for(backend):
    import jax
    from repro.dist.compat import make_mesh
    if backend in ("sharded", "tidsharded"):
        return make_mesh((4,), ("data",))
    if backend == "grid":
        return make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])
    return None


def _cfg_for(backend, min_sup):
    shard = {"tidsharded": "words", "grid": "grid"}.get(backend, "pairs")
    return StreamConfig(min_sup=min_sup, n_blocks=2, block_txns=32,
                        backend=backend, shard=shard, bucket_min=16)


def _clean(batches):
    return [[sorted(set(t)) for t in b] for b in batches]


def _miner_with(batches, cfg, mesh=None, keep_transactions=False):
    miner = StreamingMiner(N_ITEMS, cfg, mesh=mesh,
                           keep_transactions=keep_transactions)
    for b in batches:
        miner.advance(b)
    return miner


def _assert_trees_equal(a, b):
    (ta, ea), (tb, eb) = a, b
    assert set(ta) == set(tb), (sorted(ta), sorted(tb))
    for k in ta:
        np.testing.assert_array_equal(ta[k], tb[k], err_msg=k)
    assert ea == eb


@settings(max_examples=8, deadline=None)
@given(batches_strategy, st.integers(1, 8), st.booleans())
def test_property_snapshot_roundtrip_is_identity(batches, min_sup, keep):
    """state -> to_tree -> from_tree -> from_state -> snapshot is a fixed
    point, with and without kept transactions (the ragged encoding)."""
    batches = _clean(batches)
    miner = _miner_with(batches, _cfg_for("jnp", min_sup),
                        keep_transactions=keep)
    state = miner.snapshot_state()
    rebuilt = MinerState.from_tree(*state.to_tree())
    _assert_trees_equal(state.to_tree(), rebuilt.to_tree())
    again = StreamingMiner.from_state(rebuilt).snapshot_state()
    _assert_trees_equal(state.to_tree(), again.to_tree())


@settings(max_examples=8, deadline=None)
@given(batches_strategy, st.integers(1, 8))
def test_property_ring_state_roundtrip(batches, min_sup):
    """RingState alone survives the flat-vector txn encoding exactly."""
    batches = _clean(batches)
    miner = _miner_with(batches, _cfg_for("jnp", min_sup),
                        keep_transactions=True)
    state = miner.ring.snapshot_state()
    rebuilt = RingState.from_tree(*state.to_tree())
    _assert_trees_equal(state.to_tree(), rebuilt.to_tree())
    assert rebuilt.txns == state.txns
    # the rebuilt ring replays the identical live window
    from repro.streaming import WindowRing
    assert (WindowRing.from_state(rebuilt).window_transactions()
            == miner.window_transactions())


@settings(max_examples=6, deadline=None)
@given(batches_strategy, st.integers(1, 8))
def test_property_disk_roundtrip_lossless(batches, min_sup):
    """The encoding through training.checkpoint (npy leaves + JSON manifest)
    loses nothing: restored trees are array-equal and the restored miner's
    next mine matches the original's."""
    batches = _clean(batches)
    miner = _miner_with(batches, _cfg_for("pallas", min_sup))
    state = miner.snapshot_state()
    tree, extra = state.to_tree()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree, extra=extra)
        flat, manifest = load_checkpoint(d, 1)
    rebuilt = MinerState.from_tree(flat, manifest["extra"])
    _assert_trees_equal((tree, extra), rebuilt.to_tree())
    restored = StreamingMiner.from_state(rebuilt)
    assert (restored.mine_window().support_map()
            == miner.mine_window().support_map())


@settings(max_examples=6, deadline=None)
@given(batches_strategy, st.integers(1, 8),
       st.sampled_from(ALL_BACKENDS), st.sampled_from(ALL_BACKENDS),
       st.integers(0, 3))
def test_property_cross_mesh_restore_bit_exact(batches, min_sup, src, dst,
                                               cut_frac):
    """Cut the stream at a random point, snapshot under backend ``src``,
    restore under backend ``dst`` (different mesh factorization or none at
    all), replay the rest: the final window is bit-exact with a miner that
    never serialized."""
    batches = _clean(batches)
    cut = min(cut_frac, len(batches) - 1)
    head, tail = batches[:cut + 1], batches[cut + 1:]

    src_miner = _miner_with(head, _cfg_for(src, min_sup), mesh=_mesh_for(src))
    state = src_miner.snapshot_state()
    shard = {"tidsharded": "words", "grid": "grid"}.get(dst, "pairs")
    restored = StreamingMiner.from_state(state, mesh=_mesh_for(dst),
                                         backend=dst, shard=shard)

    ref = _miner_with(head + tail, _cfg_for("jnp", min_sup))
    res = None
    for b in tail:
        res = restored.advance(b)
    if res is None:
        res = restored.mine_window()
    assert res.support_map() == ref.mine_window().support_map(), (src, dst)
