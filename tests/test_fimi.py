"""FIMI-format ingestion: round-trip bit-exactness with pack_transactions.

The contract: a transaction database written as a FIMI ``.dat`` file and
parsed back must produce the *bit-identical* packed vertical bitmap as the
in-memory path — including through real-file noise (ragged lines, blank
lines, trailing whitespace, CRLF, unsorted/duplicated items).
"""
import os

import numpy as np
import pytest

from repro.core import EclatConfig, apriori_mine, mine
from repro.core import bitmap as bm
from repro.data import (fimi_universe, generate, load_fimi, parse_fimi,
                        write_fimi)


def test_roundtrip_bit_exact(tmp_path):
    """Retail-style generated data through write -> parse -> pack equals the
    in-memory pack."""
    txns, spec = generate("T10I4D100K", scale=0.005, seed=3)
    path = str(tmp_path / "retail_style.dat")
    write_fimi(path, txns)
    parsed, n_items = load_fimi(path)
    assert len(parsed) == len(txns)
    assert n_items <= spec.n_items
    a = bm.pack_transactions(txns, spec.n_items)
    b = bm.pack_transactions(parsed, spec.n_items)
    assert a.dtype == b.dtype == np.uint32
    assert np.array_equal(a, b), "FIMI round-trip is not bit-exact"


def test_parse_ragged_blank_and_whitespace():
    """Real .dat files: ragged rows, blank/whitespace-only separator lines,
    trailing spaces/tabs, CRLF endings, unsorted + duplicate items."""
    lines = [
        "30 31 32   \n",          # trailing run of spaces
        "\n",                     # blank separator — NOT an empty txn
        "33 34 35 36 38 39 40 41 42\r\n",   # CRLF + ragged (long)
        "   \t \n",               # whitespace-only separator
        "38\n",                   # singleton line
        "39 38 39 32\t\n",        # unsorted + duplicate + trailing tab
        "48 39 47 48",            # no final newline
    ]
    txns = parse_fimi(lines)
    assert txns == [[30, 31, 32],
                    [33, 34, 35, 36, 38, 39, 40, 41, 42],
                    [38],
                    [32, 38, 39],
                    [39, 47, 48]]
    assert fimi_universe(txns) == 49


def test_noisy_file_matches_clean_memory_path(tmp_path):
    """A file with every noise class packs bit-identically to the clean
    in-memory transactions it encodes."""
    clean = [[1, 2, 5], [0, 7], [3], [2, 5, 6, 7]]
    noisy = "1 2 5  \n\n0 7\r\n3\n   \n2 5 6 7 2\t\n"
    path = str(tmp_path / "noisy.dat")
    with open(path, "w") as f:
        f.write(noisy)
    parsed, n_items = load_fimi(path)
    assert n_items == 8
    assert np.array_equal(bm.pack_transactions(parsed, 8),
                          bm.pack_transactions(clean, 8))


def test_parse_rejects_bad_tokens():
    with pytest.raises(ValueError, match="line 2"):
        parse_fimi(["1 2\n", "3 x 4\n"])
    with pytest.raises(ValueError, match="negative"):
        parse_fimi(["1 -2\n"])


def test_empty_file():
    assert parse_fimi([]) == []
    assert fimi_universe([]) == 0


def test_mining_parity_file_vs_memory(tmp_path):
    """End to end: mine() and apriori_mine agree between the file-ingested
    and in-memory forms of the same database."""
    txns, spec = generate("chess", scale=0.03, seed=2)
    path = str(tmp_path / "chess.dat")
    write_fimi(path, txns)
    parsed, n_items = load_fimi(path)
    mem = mine(txns, spec.n_items,
               EclatConfig(min_sup=0.9, variant="v4", p=3)).support_map()
    fil = mine(parsed, n_items,
               EclatConfig(min_sup=0.9, variant="v4", p=3)).support_map()
    assert mem == fil
    assert apriori_mine(parsed, n_items, 0.9).support_map == fil


def test_launch_mine_fimi_cli(tmp_path, capsys):
    """--fimi reaches the driver (with --mode and --top-k composition)."""
    from repro.launch import mine as mine_cli
    txns, _ = generate("T10I4D100K", scale=0.003, seed=1)
    path = str(tmp_path / "t10.dat")
    write_fimi(path, txns)
    mine_cli.main(["--fimi", path, "--min-sup", "0.05", "--mode", "closed"])
    out = capsys.readouterr().out
    assert "t10.dat" in out and "closed=" in out
    mine_cli.main(["--fimi", path, "--top-k", "5"])
    out = capsys.readouterr().out
    assert "top-5" in out and "(5 returned)" in out
