"""Deterministic fault-injection harness for the streaming miner.

The resilience contract (DESIGN.md §10) says: crash the miner at any phase
boundary, restore the newest durable checkpoint, replay the deterministic
stream, and the final window's itemsets are bit-exact with a run that never
crashed.  This module provides the three pieces the tests compose:

* :func:`crash_at` / :func:`raiser` — install a ``repro.faults`` hook that
  raises :class:`InjectedFault` at exactly the Nth hit of a named kill
  point.  The kill is deterministic in (point name, occurrence), never in
  wall-clock or writer-thread scheduling.
* :func:`stream_run` / :func:`crashed_run` — drive a miner over a batch
  list with per-slide checkpoints and an explicit ``wait()`` after each
  save, so durability at the moment of the crash is a function of the
  slide index alone.
* :func:`resume_run` — restore from the directory (optionally onto a
  different mesh / backend — live re-meshing) and replay the remaining
  batches.

Checkpoint step semantics (streaming/persist.py): step ``s`` = state after
``s`` completed slides.  A kill during slide ``s`` — whether in the miner
itself or inside the checkpoint write for step ``s+1`` — always leaves step
``s`` as the newest durable checkpoint, so recovery replays ``batches[s:]``.
"""
from __future__ import annotations

import contextlib

import numpy as np

from repro.faults import InjectedFault, clear_kill_hook, set_kill_hook
from repro.streaming import StreamCheckpointer, StreamingMiner, restore_miner
from repro.training import valid_steps

# every phase boundary the production code names (faults.kill_point sites)
MINER_POINTS = ("miner:mid_append", "miner:mid_evict",
                "miner:pre_deep_expand")
CHECKPOINT_POINTS = ("checkpoint:mid_write", "checkpoint:pre_replace")
ALL_POINTS = MINER_POINTS + CHECKPOINT_POINTS


def raiser(point, occurrence=1):
    """A kill hook: raise InjectedFault at the Nth hit of ``point``."""
    seen = {"n": 0}

    def hook(name):
        if name == point:
            seen["n"] += 1
            if seen["n"] >= occurrence:
                raise InjectedFault(f"{point} (hit {seen['n']})")
    return hook


@contextlib.contextmanager
def crash_at(point, occurrence=1):
    """Context manager form of :func:`raiser` (hook cleared on exit)."""
    set_kill_hook(raiser(point, occurrence))
    try:
        yield
    finally:
        clear_kill_hook()


def make_batches(n_batches, batch_txns, seed=0, n_items=12):
    """Small dense micro-batches so multi-level itemsets appear at tiny
    scale (same generator shape as tests/test_streaming.py)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        batch = []
        for _ in range(batch_txns):
            t = set(rng.choice(n_items, size=rng.integers(3, 7),
                               replace=False).tolist())
            if rng.random() < 0.5:
                t |= {0, 1, 2}
            batch.append(sorted(t))
        out.append(batch)
    return out


def stream_run(n_items, cfg, batches, *, mesh=None, directory=None,
               every=1, keep=3, kill=None):
    """Drive a fresh miner over ``batches``; return the last WindowResult.

    With ``directory``, checkpoint every ``every`` slides and ``wait()``
    after each save so durability is deterministic.  With
    ``kill=(point, slide)``, arm the kill hook entering that slide; the
    resulting :class:`InjectedFault` propagates to the caller (out of the
    miner for miner-phase points, out of the post-save ``wait()`` for
    checkpoint-phase points).
    """
    miner = StreamingMiner(n_items, cfg, mesh=mesh, keep_transactions=False)
    ck = (StreamCheckpointer(directory, every=every, keep=keep)
          if directory else None)
    res = None
    try:
        for i, batch in enumerate(batches):
            if kill is not None and i == kill[1]:
                set_kill_hook(raiser(kill[0]))
            res = miner.advance(batch)
            if ck is not None and ck.maybe_save(miner, i + 1):
                ck.wait()
    finally:
        clear_kill_hook()
        if ck is not None:
            with contextlib.suppress(InjectedFault):
                ck.wait()
    return res


def crashed_run(n_items, cfg, batches, directory, point, kill_slide,
                *, mesh=None, every=1, keep=3):
    """A run guaranteed to die at ``point`` during slide ``kill_slide``.

    Asserts the fault actually fired and that a durable checkpoint
    survived; returns the newest durable step (== ``kill_slide`` for every
    phase boundary, per the step semantics above).
    """
    try:
        stream_run(n_items, cfg, batches, mesh=mesh, directory=directory,
                   every=every, keep=keep, kill=(point, kill_slide))
    except InjectedFault:
        pass
    else:
        raise AssertionError(f"kill point {point!r} never fired")
    steps = valid_steps(directory)
    assert steps, f"no durable checkpoint survived the {point!r} crash"
    return steps[-1]


def resume_run(n_items, batches, directory, *, mesh=None, backend=None,
               shard=None):
    """Restore the newest durable checkpoint (optionally re-meshed onto
    ``mesh`` / ``backend`` / ``shard``) and replay the remaining batches;
    return the final WindowResult."""
    miner, start = restore_miner(directory, mesh=mesh, backend=backend,
                                 shard=shard, keep_transactions=False)
    assert 0 <= start <= len(batches), (start, len(batches))
    res = None
    for batch in batches[start:]:
        res = miner.advance(batch)
    return res if res is not None else miner.mine_window()
