"""Workload-mode invariants: closed/maximal post-filters and top-k ladder.

The lattice-theory contract (DESIGN.md §9):
  maximal ⊆ closed ⊆ frequent,
  closure reconstruction from the closed set recovers the full frequent
  map with supports, and top-k returns exactly k (or all, if fewer) under
  a deterministic tie rule.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (EclatConfig, bruteforce_fim, closed_itemsets,
                        filter_mode, frequent_from_closed, maximal_itemsets,
                        mine, top_k_mine)
from repro.core.postfilter import topk_sort_key

db_strategy = st.lists(
    st.lists(st.integers(0, 7), min_size=0, max_size=6),
    min_size=1, max_size=60,
)


def make_db(seed=7, n_items=10, n_txn=120, base=(0, 1, 2, 3)):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= set(base)
        txns.append(sorted(t))
    return txns


DB = make_db()


# ---------------------------------------------------------------------------
# containment chain + closure reconstruction (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(db_strategy, st.integers(1, 15))
def test_property_maximal_subset_closed_subset_frequent(txns, min_sup):
    txns = [sorted(set(t)) for t in txns]
    sm = bruteforce_fim(txns, min_sup)
    cl = closed_itemsets(sm)
    mx = maximal_itemsets(sm)
    assert set(mx) <= set(cl) <= set(sm)
    for s in cl:
        assert cl[s] == sm[s]
    for s in mx:
        assert mx[s] == sm[s]
    # definitional checks against the full map
    for itemset, sup in sm.items():
        has_equal_super = any(
            len(other) > len(itemset) and set(itemset) < set(other)
            and osup == sup for other, osup in sm.items())
        has_any_super = any(
            len(other) > len(itemset) and set(itemset) < set(other)
            for other in sm)
        assert (itemset in cl) == (not has_equal_super)
        assert (itemset in mx) == (not has_any_super)


@settings(max_examples=20, deadline=None)
@given(db_strategy, st.integers(1, 15))
def test_property_closure_reconstruction_recovers_frequent(txns, min_sup):
    """The closed set is a lossless compression of the frequent set."""
    txns = [sorted(set(t)) for t in txns]
    sm = bruteforce_fim(txns, min_sup)
    assert frequent_from_closed(closed_itemsets(sm)) == sm


@settings(max_examples=10, deadline=None)
@given(db_strategy, st.integers(1, 12), st.sampled_from(["closed", "maximal"]))
def test_property_mine_mode_matches_postfiltered_oracle(txns, min_sup, mode):
    """EclatConfig.mode plumbs the post-filter through mine() itself."""
    txns = [sorted(set(t)) for t in txns]
    res = mine(txns, 8, EclatConfig(min_sup=min_sup, variant="v4", p=3,
                                    mode=mode))
    oracle = filter_mode(bruteforce_fim(txns, min_sup), mode)
    assert res.workload_map() == oracle
    assert res.stats["mode"] == mode
    assert res.stats["mode_itemsets"] == len(oracle)
    # the full lattice is still there underneath the filter
    assert res.support_map() == bruteforce_fim(txns, min_sup)


def test_mode_all_is_identity():
    res = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3))
    assert res.mode == "all"
    assert res.workload_map() == res.support_map()
    assert "mode_itemsets" not in res.stats


def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="workload mode"):
        mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3, mode="open"))
    with pytest.raises(ValueError, match="workload mode"):
        filter_mode({}, "open")


@pytest.mark.parametrize("backend", ["jnp", "pallas", "sharded",
                                     "tidsharded", "grid"])
def test_modes_identical_across_backends(backend):
    """closed/maximal are host-side post-filters on the lineage, so every
    engine backend must hand back the identical filtered maps."""
    import jax
    from repro.dist.compat import make_mesh
    shard = {"tidsharded": "words", "grid": "grid"}.get(backend, "pairs")
    mesh = (make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])
            if backend == "grid" else
            make_mesh((4,), ("data",)) if backend in ("sharded", "tidsharded")
            else None)
    oracle = bruteforce_fim(DB, 20)
    for mode in ("closed", "maximal"):
        res = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3,
                                       backend=backend, shard=shard,
                                       bucket_min=32, mode=mode),
                   mesh=mesh)
        assert res.workload_map() == filter_mode(oracle, mode)


# ---------------------------------------------------------------------------
# top-k: exactly k (or all), deterministic ties, threshold-free
# ---------------------------------------------------------------------------

def _oracle_topk(txns, k, min_len=1):
    sm = [(s, v) for s, v in bruteforce_fim(txns, 1).items()
          if len(s) >= min_len]
    return sorted(sm, key=topk_sort_key)[:k]


@pytest.mark.parametrize("k", [1, 5, 17, 10_000])
def test_topk_exactly_k_or_all(k):
    tk = top_k_mine(DB, 10, k)
    total = len(bruteforce_fim(DB, 1))
    assert len(tk.itemsets) == min(k, total)
    assert tk.itemsets == _oracle_topk(DB, k)
    sups = [s for _, s in tk.itemsets]
    assert sups == sorted(sups, reverse=True)


def test_topk_deterministic_tie_rule():
    """Equal supports order by (length asc, items lex asc) — and repeat
    calls return the identical list."""
    txns = [[0, 1], [0, 1], [2], [2], [3, 4], [3, 4]]
    tk = top_k_mine(txns, 5, 4)
    assert tk.itemsets == top_k_mine(txns, 5, 4).itemsets
    assert tk.itemsets == [((0,), 2), ((1,), 2), ((2,), 2), ((3,), 2)]


def test_topk_ladder_is_recorded_and_monotone():
    tk = top_k_mine(DB, 10, 12)
    assert tk.ladder, "ladder rungs must be recorded"
    rungs = [r["abs_min_sup"] for r in tk.ladder]
    assert rungs == sorted(rungs, reverse=True)
    assert tk.abs_min_sup == rungs[-1]
    # enough itemsets cleared the final rung
    assert tk.ladder[-1]["n_found"] >= min(12, len(bruteforce_fim(DB, 1)))


def test_topk_min_len_uses_deeper_rungs():
    """min_len=2 cannot rely on the singleton-support seed rung alone; the
    halving fallback must still find the k best pairs-and-longer."""
    tk = top_k_mine(DB, 10, 6, min_len=2)
    assert len(tk.itemsets) == 6
    assert all(len(s) >= 2 for s, _ in tk.itemsets)
    assert tk.itemsets == _oracle_topk(DB, 6, min_len=2)


def test_topk_fewer_than_k_items_returns_all():
    txns = [[0], [0], [1]]
    tk = top_k_mine(txns, 2, 50)
    assert tk.itemsets == _oracle_topk(txns, 50)
    assert tk.abs_min_sup == 1


def test_topk_validation():
    with pytest.raises(ValueError, match="k >= 1"):
        top_k_mine(DB, 10, 0)
    with pytest.raises(ValueError, match="min_len"):
        top_k_mine(DB, 10, 3, min_len=0)


def test_topk_respects_config_template():
    """Backend/variant plumb through the ladder unchanged."""
    tk = top_k_mine(DB, 10, 8, config=EclatConfig(min_sup=1, variant="v6",
                                                  backend="jnp", p=3))
    assert tk.stats["variant"] == "v6"
    assert tk.itemsets == _oracle_topk(DB, 8)
