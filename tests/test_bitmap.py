"""Bitmap tidset representation: pack/unpack, popcount, compaction."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import bitmap as bm


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    for n_items, n_txn in [(1, 1), (3, 31), (5, 32), (7, 33), (10, 257)]:
        dense = rng.random((n_items, n_txn)) < 0.3
        packed = bm.pack_bool_matrix(dense)
        assert packed.shape == (n_items, bm.n_words(n_txn))
        np.testing.assert_array_equal(bm.unpack_bitmap(packed, n_txn), dense)


def test_pack_transactions_matches_dense():
    txns = [[0, 2], [1], [0, 1, 3], [], [3, 3, 3]]
    packed = bm.pack_transactions(txns, n_items=4)
    dense = np.zeros((4, 5), bool)
    for tid, t in enumerate(txns):
        for i in set(t):
            dense[i, tid] = True
    np.testing.assert_array_equal(bm.unpack_bitmap(packed, 5), dense)


def test_popcount_np_matches_python():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 2**32, size=128, dtype=np.uint32)
    expect = np.array([bin(int(v)).count("1") for v in x])
    np.testing.assert_array_equal(bm.popcount_np(x), expect)


def test_support_device_matches_host():
    rng = np.random.default_rng(2)
    packed = rng.integers(0, 2**32, size=(17, 9), dtype=np.uint32)
    np.testing.assert_array_equal(
        np.asarray(bm.support(jnp.asarray(packed))), bm.support_np(packed))


def test_column_compact():
    dense = np.array([[1, 0, 1, 0, 0], [0, 0, 1, 0, 1]], bool)
    packed = bm.pack_bool_matrix(dense)
    keep = dense.any(axis=0)
    compact, kept = bm.column_compact(packed, 5, keep)
    assert kept == 3
    np.testing.assert_array_equal(
        bm.unpack_bitmap(compact, 3), dense[:, keep])


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 6), st.integers(1, 80), st.integers(0, 2**31))
def test_property_pack_support(n_items, n_txn, seed):
    """Property: support == number of distinct txns containing the item."""
    rng = np.random.default_rng(seed)
    txns = [rng.choice(n_items, size=rng.integers(0, n_items + 1), replace=False).tolist()
            for _ in range(n_txn)]
    packed = bm.pack_transactions(txns, n_items)
    sup = bm.support_np(packed)
    for i in range(n_items):
        assert sup[i] == sum(1 for t in txns if i in t)


def test_intersect_support_is_and():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 2**32, (11, 5), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (11, 5), dtype=np.uint32))
    inter, sup = bm.intersect_support(a, b)
    np.testing.assert_array_equal(np.asarray(inter), np.asarray(a) & np.asarray(b))
    np.testing.assert_array_equal(np.asarray(sup), bm.support_np(np.asarray(inter)))
