"""Thin ``hypothesis`` fallback so property tests collect without the package.

When ``hypothesis`` is installed (requirements-dev.txt; CI does), this module
re-exports the real ``given``/``settings``/``strategies`` untouched.  When it
is missing (the hermetic container), a deterministic miniature replaces it:
each strategy draws from a seeded ``random.Random`` and ``given`` simply runs
the test body ``max_examples`` times.  No shrinking, no database — enough to
keep the invariants exercised, not a substitute for the real engine.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:

    import random
    import types

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elements.draw(rng)
            for _ in range(rng.randint(min_size, max_size))])

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = types.SimpleNamespace(
        integers=_integers, lists=_lists, sampled_from=_sampled_from,
        booleans=_booleans, floats=_floats)

    def given(*strategies_args):
        def deco(fn):
            # no functools.wraps: the wrapper must expose a ZERO-arg
            # signature or pytest treats the drawn params as fixtures
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0xEC1A7)
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strategies_args))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = 20
            return wrapper
        return deco

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
