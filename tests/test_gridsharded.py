"""Grid-sharded (pairs x words) execution on a 2D ("class", "data") mesh:
routing, placement, per-axis work/memory scaling, and bit-exact parity with
the single-device backends (DESIGN.md §8).

The contract under test: candidate pairs are split over the class axis (as
in the pair-sharded engine) while the frontier's packed word axis is split
over the data axis (as in the tid-sharded engine); the frontier is carried
``P(None, "data")`` — replicated over class, word-sharded over data —
supports are recovered with one psum over the data axis only, survivor
compaction keeps the word constraint, and none of it is visible in the
mined itemsets for batch v1–v6 or streaming windows, on the 2x2 grid and on
both degenerate grids (4x1 ~ pair-sharding, 1x4 ~ word-sharding).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import EclatConfig, bruteforce_fim, mine
from repro.core import engine as eng
from repro.core.bitmap import popcount_np
from repro.dist.compat import make_mesh
from repro.streaming import StreamConfig, StreamingMiner

GRIDS = [(2, 2), (4, 1), (1, 4)]


def _grid(n_class, n_data):
    return make_mesh((n_class, n_data), ("class", "data"),
                     devices=jax.devices()[: n_class * n_data])


def make_db(seed=7, n_items=10, n_txn=150):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= {0, 1, 2, 3}
        txns.append(sorted(t))
    return txns


DB = make_db()
ORACLE = bruteforce_fim(DB, min_sup=25)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_resolve_engine_routes_grid_mode():
    mesh = _grid(2, 2)
    e = eng.resolve_engine("pallas", mesh, shard="grid")
    assert e.name == "grid" and e.inner == "pallas"
    e = eng.resolve_engine("jnp", mesh, shard="grid")
    assert e.name == "grid" and e.inner == "jnp"
    assert eng.resolve_engine("grid", mesh).name == "grid"
    # graceful degrade without a mesh, like the other mesh-mapped backends
    assert eng.resolve_engine("grid", None).name == "pallas"
    with pytest.raises(ValueError, match="shard mode"):
        eng.resolve_engine("pallas", mesh, shard="gird")
    # grid + default shard still routes to grid (backend implies the mode)
    assert eng.resolve_engine("grid", mesh, shard="pairs").name == "grid"


def test_resolve_engine_rejects_contradictory_backend_shard():
    """Regression: backend='tidsharded' silently overrode an explicit
    shard='grid' request — the CLI then logged a grid run that executed as
    word-sharding.  A named mesh backend with a *different* non-default
    shard is now rejected."""
    mesh = _grid(2, 2)
    for backend, shard in (("tidsharded", "grid"), ("grid", "words"),
                           ("sharded", "grid"), ("sharded", "words")):
        with pytest.raises(ValueError, match="implies shard"):
            eng.resolve_engine(backend, mesh, shard=shard)


def test_grid_requires_a_2d_class_data_mesh():
    with pytest.raises(ValueError, match="requires a mesh"):
        eng.make_engine("grid")
    with pytest.raises(ValueError, match="mesh has axes"):
        eng.make_engine("grid", mesh=make_mesh((4,), ("data",)))


def test_mine_config_shard_grid_routes_to_grid():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v4", p=4,
                                   shard="grid"), mesh=_grid(2, 2))
    assert res.stats["backend"] == "grid"
    assert res.stats["grid"] == [2, 2]
    assert res.stats["n_class_shards"] == 2
    assert res.stats["n_word_shards"] == 2
    assert res.support_map() == ORACLE


# ---------------------------------------------------------------------------
# batch parity matrix: v1–v6 x inner executor x 2x2 / 4x1 / 1x4 grids
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["v1", "v2", "v3", "v4", "v5", "v6"])
@pytest.mark.parametrize("inner", ["jnp", "pallas"])
@pytest.mark.parametrize("grid", GRIDS, ids=lambda g: f"{g[0]}x{g[1]}")
def test_mine_grid_matches_oracle(variant, inner, grid):
    res = mine(DB, 10, EclatConfig(min_sup=25, variant=variant, p=3,
                                   use_diffsets=(variant == "v6"),
                                   backend=inner, shard="grid",
                                   bucket_min=32), mesh=_grid(*grid))
    assert res.stats["backend"] == "grid"
    assert res.stats["grid"] == list(grid)
    assert res.support_map() == ORACLE


def test_mine_grid_no_trimatrix():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v5", p=3,
                                   tri_matrix=False, shard="grid",
                                   bucket_min=32), mesh=_grid(2, 2))
    assert res.support_map() == ORACLE


# ---------------------------------------------------------------------------
# placement: frontier P(None, "data"), pairs split over the class axis
# ---------------------------------------------------------------------------

def _case(p=32, w=8, q=24, n_class=2, seed=0):
    rng = np.random.default_rng(seed)
    bitmaps = rng.integers(0, 2**32, (p, w), dtype=np.uint32)
    left = rng.integers(0, p, q).astype(np.int32)
    right = rng.integers(0, p, q).astype(np.int32)
    sup_left = popcount_np(bitmaps[left]).sum(-1).astype(np.int32)
    dev = rng.integers(0, n_class, q).astype(np.int64)
    return bitmaps, left, right, sup_left, dev


def test_frontier_word_sharded_and_class_replicated():
    bitmaps, left, right, sup_left, dev = _case()
    mesh = _grid(2, 2)
    e = eng.make_engine("grid", mesh=mesh, bucket_min=8, inner="jnp")
    res = e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                   mode=eng.MODE_TIDSET, min_sup=1, device_of_pair=dev)
    sh = res.bitmaps.sharding
    assert isinstance(sh, NamedSharding)
    assert sh.spec == P(None, "data")
    # each device holds all survivor rows but only 1/n_data of the words —
    # replicated over the 2-wide class axis, split over the 2-wide data axis
    assert res.bitmaps.addressable_shards[0].data.shape[0] == res.bitmaps.shape[0]
    assert res.bitmaps.addressable_shards[0].data.nbytes * 2 == res.bitmaps.nbytes
    # feeding the frontier back in (the bottom-up loop) keeps it placed
    res2 = e.expand(res.bitmaps, np.zeros(4, np.int32), np.zeros(4, np.int32),
                    res.supports[:1].repeat(4).astype(np.int32),
                    mode=eng.MODE_TIDSET, min_sup=1,
                    device_of_pair=np.array([0, 1, 0, 1]))
    assert res2.bitmaps.sharding.spec == P(None, "data")


def test_pairs_split_over_class_words_over_data():
    """The point of the mode: per-device pair work ~ 1/n_class (vs the
    word-sharded engine, which replicates all pairs) AND per-device frontier
    bytes ~ 1/n_data (vs the pair-sharded engine, which replicates the
    frontier) — at identical supports."""
    bitmaps, left, right, sup_left, dev = _case(p=64, w=16, q=40, n_class=2,
                                                seed=1)
    sups = {}
    # grid 2x2
    e = eng.make_engine("grid", mesh=_grid(2, 2), bucket_min=8, inner="jnp")
    res = e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                   mode=eng.MODE_TIDSET, min_sup=1, device_of_pair=dev)
    sups["grid"] = res.supports.tolist()
    counts = e.device_pair_counts[-1]
    assert counts.shape == (2,) and counts.sum() == 40   # pairs split 2 ways
    grid_frontier_per_dev = res.bitmaps.addressable_shards[0].data.nbytes
    assert grid_frontier_per_dev * 2 == res.bitmaps.nbytes
    # word-sharded engine on the same 4 devices: every device sees all pairs
    ew = eng.make_engine("tidsharded", mesh=make_mesh((4,), ("data",)),
                         bucket_min=8, inner="jnp")
    resw = ew.expand(jnp.asarray(bitmaps), left, right, sup_left,
                     mode=eng.MODE_TIDSET, min_sup=1)
    sups["words"] = resw.supports.tolist()
    assert not ew.device_pair_counts                     # no pair distribution
    # pair-sharded engine: pairs split 4 ways but the frontier replicated
    ep = eng.make_engine("sharded", mesh=make_mesh((4,), ("data",)),
                         bucket_min=8, inner="jnp")
    resp = ep.expand(jnp.asarray(bitmaps), left, right, sup_left,
                     mode=eng.MODE_TIDSET, min_sup=1,
                     device_of_pair=dev % 4)
    sups["pairs"] = resp.supports.tolist()
    assert sups["grid"] == sups["words"] == sups["pairs"]


def test_grid_rejects_out_of_range_class_ids():
    bitmaps, left, right, sup_left, _ = _case(q=9)
    e = eng.make_engine("grid", mesh=_grid(2, 2), bucket_min=8, inner="jnp")
    for bad in (np.full(9, 2, np.int64),                  # == n_class
                np.array([0, -1, 0, 0, 0, 0, 0, 0, 0])):  # negative
        with pytest.raises(ValueError, match="device_of_pair"):
            e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                     mode=eng.MODE_TIDSET, min_sup=1, device_of_pair=bad)


def test_empty_frontier_and_single_pair():
    mesh = _grid(2, 2)
    e = eng.make_engine("grid", mesh=mesh, bucket_min=8, inner="jnp")
    bm = jnp.asarray(np.random.default_rng(2).integers(
        0, 2**32, (1, 1), dtype=np.uint32))
    res = e.expand(bm, np.zeros(0, np.int32), np.zeros(0, np.int32),
                   np.zeros(0, np.int32), mode=eng.MODE_TIDSET, min_sup=1)
    assert res.mask.shape == (0,) and res.supports.shape == (0,)
    res = e.expand(bm, np.zeros(1, np.int32), np.zeros(1, np.int32),
                   np.zeros(1, np.int32), mode=eng.MODE_TIDSET, min_sup=1)
    assert res.mask.shape == (1,)


def test_grid_mesh_construction_helpers():
    from repro.launch.mesh import factor_grid, make_grid_mesh, parse_grid_arg
    assert factor_grid(4) == (2, 2)
    assert factor_grid(8) == (2, 4)
    assert factor_grid(6) == (2, 3)
    assert factor_grid(7) == (1, 7)
    with pytest.raises(ValueError):
        factor_grid(0)
    mesh = make_grid_mesh()                    # auto: 4 forced host devices
    assert tuple(mesh.axis_names) == ("class", "data")
    assert (mesh.shape["class"], mesh.shape["data"]) == (2, 2)
    mesh = make_grid_mesh(4, 1)
    assert (mesh.shape["class"], mesh.shape["data"]) == (4, 1)
    mesh = make_grid_mesh(n_data=4)
    assert (mesh.shape["class"], mesh.shape["data"]) == (1, 4)
    with pytest.raises(ValueError, match="visible"):
        make_grid_mesh(8, 8)
    with pytest.raises(ValueError, match="does not divide"):
        make_grid_mesh(n_class=3)
    assert parse_grid_arg(None) == (None, None)
    assert parse_grid_arg("2x2") == (2, 2)
    assert parse_grid_arg("4X1") == (4, 1)
    with pytest.raises(ValueError, match="RxC"):
        parse_grid_arg("2x2x2")
    with pytest.raises(ValueError, match="RxC"):
        parse_grid_arg("twoxtwo")


def test_mesh_for_mining_routes_and_rejects_stray_grid_arg():
    from repro.launch.mesh import mesh_for_mining
    assert mesh_for_mining("pallas", "pairs") is None
    assert mesh_for_mining("jnp", "pairs") is None
    assert tuple(mesh_for_mining("pallas", "words").axis_names) == ("data",)
    assert tuple(mesh_for_mining("sharded", "pairs").axis_names) == ("data",)
    mesh = mesh_for_mining("pallas", "grid", "2x2")
    assert tuple(mesh.axis_names) == ("class", "data")
    assert tuple(mesh_for_mining("grid", "pairs").axis_names) == ("class",
                                                                  "data")
    # a --grid argument outside the grid mode would otherwise be silently
    # dropped — the run would measure a different configuration
    with pytest.raises(ValueError, match="requires the grid mode"):
        mesh_for_mining("pallas", "pairs", "2x2")
    with pytest.raises(ValueError, match="requires the grid mode"):
        mesh_for_mining("tidsharded", "pairs", "2x2")


# ---------------------------------------------------------------------------
# streaming windows: grid-placed ring + grid engine, bit-exact
# ---------------------------------------------------------------------------

def _batches(n_batches, batch_txns, seed=0, n_items=12):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        batch = []
        for _ in range(batch_txns):
            t = set(rng.choice(n_items, size=rng.integers(3, 7),
                               replace=False).tolist())
            if rng.random() < 0.5:
                t |= {0, 1, 2}
            batch.append(sorted(t))
        out.append(batch)
    return out


@pytest.mark.parametrize("route", ["shard_grid", "backend_name"])
def test_streaming_grid_matches_batch_mine(route):
    mesh = _grid(2, 2)
    if route == "shard_grid":
        cfg = StreamConfig(min_sup=5, n_blocks=3, block_txns=32,
                           backend="pallas", shard="grid", bucket_min=16)
    else:
        cfg = StreamConfig(min_sup=5, n_blocks=3, block_txns=32,
                           backend="grid", bucket_min=16)
    miner = StreamingMiner(12, cfg, mesh=mesh)
    assert miner.engine.name == "grid"
    # the window ring is carried exactly the way the grid engine wants its
    # frontier: word-sharded over data, replicated over class
    assert miner.ring.device.sharding.spec == P(None, "data")
    for i, batch in enumerate(_batches(6, 28, seed=4)):
        res = miner.advance(batch)
        miner.ring.validate()
        window = miner.window_transactions()
        batch_res = mine(window, 12, EclatConfig(min_sup=5, variant="v4",
                                                 p=4, backend="jnp",
                                                 bucket_min=16))
        assert res.support_map() == batch_res.support_map(), f"slide {i}"


@pytest.mark.parametrize("grid", [(4, 1), (1, 4)],
                         ids=lambda g: f"{g[0]}x{g[1]}")
def test_streaming_grid_degenerate_meshes(grid):
    miner = StreamingMiner(12, StreamConfig(min_sup=5, n_blocks=2,
                                            block_txns=32, shard="grid",
                                            bucket_min=16),
                           mesh=_grid(*grid))
    for i, batch in enumerate(_batches(4, 24, seed=5)):
        res = miner.advance(batch)
        batch_res = mine(miner.window_transactions(), 12,
                         EclatConfig(min_sup=5, backend="jnp", bucket_min=16))
        assert res.support_map() == batch_res.support_map(), f"slide {i}"


def test_streaming_grid_empty_window():
    miner = StreamingMiner(12, StreamConfig(min_sup=2, n_blocks=2,
                                            block_txns=32, shard="grid"),
                           mesh=_grid(2, 2))
    res = miner.mine_window()
    assert res.total == 0 and res.support_map() == {}
    res = miner.advance([])
    assert res.total == 0
