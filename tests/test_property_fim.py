"""Hypothesis property tests on FIM system invariants."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import EclatConfig, bruteforce_fim, mine

db_strategy = st.lists(
    st.lists(st.integers(0, 7), min_size=0, max_size=6),
    min_size=1, max_size=60,
)


@settings(max_examples=25, deadline=None)
@given(db_strategy, st.integers(1, 20), st.sampled_from(["v1", "v4", "v6"]))
def test_property_exact_vs_oracle(txns, min_sup, variant):
    txns = [sorted(set(t)) for t in txns]
    res = mine(txns, 8, EclatConfig(min_sup=min_sup, variant=variant, p=3,
                                    use_diffsets=(variant == "v6")))
    assert res.support_map() == bruteforce_fim(txns, min_sup)


@settings(max_examples=20, deadline=None)
@given(db_strategy, st.integers(1, 15))
def test_property_antimonotone(txns, min_sup):
    """Apriori property: every subset of a frequent itemset is frequent with
    support >= the superset's."""
    txns = [sorted(set(t)) for t in txns]
    sm = mine(txns, 8, EclatConfig(min_sup=min_sup, variant="v4", p=3)).support_map()
    for iset, sup in sm.items():
        for drop in range(len(iset)):
            sub = tuple(x for i, x in enumerate(iset) if i != drop)
            if sub:
                assert sub in sm and sm[sub] >= sup


@settings(max_examples=20, deadline=None)
@given(db_strategy, st.integers(1, 15))
def test_property_min_sup_monotone(txns, min_sup):
    """Raising min_sup can only shrink the result set."""
    txns = [sorted(set(t)) for t in txns]
    lo = mine(txns, 8, EclatConfig(min_sup=min_sup, variant="v4", p=3)).support_map()
    hi = mine(txns, 8, EclatConfig(min_sup=min_sup + 3, variant="v4", p=3)).support_map()
    assert set(hi) <= set(lo)
    for k, v in hi.items():
        assert lo[k] == v
