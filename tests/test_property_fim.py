"""Hypothesis property tests on FIM system invariants."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import EclatConfig, apriori_mine, bruteforce_fim, mine

db_strategy = st.lists(
    st.lists(st.integers(0, 7), min_size=0, max_size=6),
    min_size=1, max_size=60,
)

ALL_BACKENDS = ("jnp", "pallas", "sharded", "tidsharded", "grid")


def _mesh_for(backend):
    """The mesh each engine backend needs (conftest forces 4 host devices)."""
    import jax
    from repro.dist.compat import make_mesh
    if backend in ("sharded", "tidsharded"):
        return make_mesh((4,), ("data",))
    if backend == "grid":
        return make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])
    return None


@settings(max_examples=25, deadline=None)
@given(db_strategy, st.integers(1, 20), st.sampled_from(["v1", "v4", "v6"]))
def test_property_exact_vs_oracle(txns, min_sup, variant):
    txns = [sorted(set(t)) for t in txns]
    res = mine(txns, 8, EclatConfig(min_sup=min_sup, variant=variant, p=3,
                                    use_diffsets=(variant == "v6")))
    assert res.support_map() == bruteforce_fim(txns, min_sup)


@settings(max_examples=20, deadline=None)
@given(db_strategy, st.integers(1, 15))
def test_property_antimonotone(txns, min_sup):
    """Apriori property: every subset of a frequent itemset is frequent with
    support >= the superset's."""
    txns = [sorted(set(t)) for t in txns]
    sm = mine(txns, 8, EclatConfig(min_sup=min_sup, variant="v4", p=3)).support_map()
    for iset, sup in sm.items():
        for drop in range(len(iset)):
            sub = tuple(x for i, x in enumerate(iset) if i != drop)
            if sub:
                assert sub in sm and sm[sub] >= sup


@settings(max_examples=8, deadline=None)
@given(db_strategy, st.integers(1, 20))
def test_property_apriori_differential_all_backends(txns, min_sup):
    """Differential oracle: random baskets mined by the horizontal Apriori
    baseline and by all five engine backends must produce the identical
    (itemset, support) set — two independent algorithm families (level-wise
    horizontal rescan vs vertical tidset intersection) agreeing on random
    inputs is the cross-implementation contract the headline bench relies
    on (DESIGN.md §9)."""
    txns = [sorted(set(t)) for t in txns]
    expect = apriori_mine(txns, 8, min_sup).support_map
    for backend in ALL_BACKENDS:
        shard = {"tidsharded": "words", "grid": "grid"}.get(backend, "pairs")
        got = mine(txns, 8, EclatConfig(min_sup=min_sup, variant="v4", p=3,
                                        backend=backend, shard=shard,
                                        bucket_min=32),
                   mesh=_mesh_for(backend)).support_map()
        assert got == expect, f"backend {backend} diverges from apriori"


@settings(max_examples=20, deadline=None)
@given(db_strategy, st.integers(1, 15))
def test_property_min_sup_monotone(txns, min_sup):
    """Raising min_sup can only shrink the result set."""
    txns = [sorted(set(t)) for t in txns]
    lo = mine(txns, 8, EclatConfig(min_sup=min_sup, variant="v4", p=3)).support_map()
    hi = mine(txns, 8, EclatConfig(min_sup=min_sup + 3, variant="v4", p=3)).support_map()
    assert set(hi) <= set(lo)
    for k, v in hi.items():
        assert lo[k] == v
