"""End-to-end behaviour tests for the paper's system.

The headline claims, verified on generated Table-2-shaped data:
  1. RDD-Eclat >= Apriori in speed at low min_sup (paper: 2-9x; we assert
     a conservative >=1.5x on the chess analogue where the gap is widest).
  2. All variants agree bit-exactly with each other.
  3. Partition-balanced variants (V5/V6) beat V4/hash in padding efficiency.
"""
import time

import pytest

from repro.core import EclatConfig, apriori_mine, mine
from repro.data import generate


@pytest.fixture(scope="module")
def chess():
    return generate("chess", scale=0.25, seed=1)


def test_eclat_beats_apriori_at_low_minsup(chess):
    txns, spec = chess
    ms = 0.75   # lowest assigned chess min_sup -> deepest lattice
    # warm both code paths (jit compile is not part of the paper's claim)
    mine(txns, spec.n_items, EclatConfig(min_sup=ms, variant="v4", p=10))
    apriori_mine(txns, spec.n_items, ms)
    t0 = time.perf_counter()
    res = mine(txns, spec.n_items, EclatConfig(min_sup=ms, variant="v4", p=10))
    t_eclat = time.perf_counter() - t0
    t0 = time.perf_counter()
    ap = apriori_mine(txns, spec.n_items, ms)
    t_apriori = time.perf_counter() - t0
    assert res.support_map() == ap.support_map
    assert res.total > 100          # non-trivial lattice
    speedup = t_apriori / t_eclat
    assert speedup >= 1.5, f"speedup only {speedup:.2f}x"


def test_variants_bit_identical(chess):
    txns, spec = chess
    maps = {}
    for v in ("v1", "v2", "v3", "v4", "v5", "v6"):
        maps[v] = mine(txns, spec.n_items,
                       EclatConfig(min_sup=0.8, variant=v, p=10)).support_map()
    base = maps["v1"]
    for v, m in maps.items():
        assert m == base, v


def test_balanced_partitioners_improve_padding(chess):
    txns, spec = chess
    effs = {}
    for v in ("v4", "v5", "v6"):
        res = mine(txns, spec.n_items, EclatConfig(min_sup=0.8, variant=v, p=10))
        effs[v] = res.stats["partition_balance"]["padding_efficiency"]
    assert effs["v6"] >= effs["v4"] - 1e-9
