"""Sharding-rule unit tests + a tiny-mesh end-to-end dry-run (subprocess)."""
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import param_spec


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


M = FakeMesh()


@pytest.mark.parametrize("path,shape,expect", [
    ("stages/s0/stk_wq", (48, 6144, 6144), P(None, None, "model")),
    ("stages/s0/stk_wo", (48, 6144, 6144), P(None, "model", None)),
    ("stages/s0/stk_w_up", (48, 6144, 16384), P(None, None, "model")),
    ("stages/s0/stk_w_down", (48, 16384, 6144), P(None, "model", None)),
    ("embed", (256000, 2048), P("model", None)),
    ("embed", (51865, 512), P(None, None)),              # vocab not divisible
    ("lm_head", (6144, 92544), P(None, "model")),
    ("stages/s0/stk_norm1_scale", (48, 6144), P(None, None)),
    ("stages/s0/stk_experts_up", (24, 128, 5120, 8192), P(None, "data", None, "model")),
    ("stages/s0/stk_experts_down", (24, 128, 8192, 5120), P(None, "data", "model", None)),
    ("stages/s0/stk_router", (24, 5120, 128), P(None, None, None)),
])
def test_param_spec_rules(path, shape, expect):
    assert param_spec(path, shape, M) == expect


def test_expert_tp2d():
    got = param_spec("stages/s0/stk_experts_up", (64, 8, 6144, 32768), M,
                     expert_sharding="tp2d")
    assert got == P(None, None, None, ("data", "model"))


_TINY_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax
from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.launch.mesh import make_mesh_named
from repro.launch.specs import build_cell
mesh = make_mesh_named("tiny")   # (2, 2) data x model
cfg = dataclasses.replace(
    reduced_config(get_config("gemma3-4b")), d_model=64, vocab_size=512)
with mesh:
    for shape in ("train_4k", "decode_32k"):
        # full-size input shapes against the reduced-width model
        cell = build_cell("gemma3-4b", shape, mesh, cfg_override=cfg)
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        compiled = jitted.lower(*cell.args).compile()
        assert compiled.memory_analysis() is not None
        print("TINY_OK", shape)
"""


def test_tiny_mesh_dryrun_subprocess():
    """The dry-run machinery end-to-end on a 4-device mesh (fast)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _TINY_DRYRUN],
                       capture_output=True, text=True, env=env,
                       cwd=os.getcwd(), timeout=600)
    assert r.returncode == 0 and r.stdout.count("TINY_OK") == 2, r.stderr[-3000:]
