"""Apriori baseline boundary semantics — the differential-oracle contract.

``apriori_mine`` is raced against every engine backend by the headline
bench and the differential property tests, so its boundary behavior
(max_k, resolve_min_sup edge cases, degenerate databases) must match the
Eclat drivers exactly — mirroring the PR 5 ``max_k`` matrix in
tests/test_eclat_correctness.py.
"""
import numpy as np
import pytest

from repro.core import EclatConfig, apriori_mine, bruteforce_fim, mine


def make_db(seed=7, n_items=10, n_txn=150, base=(0, 1, 2, 3)):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= set(base)
        txns.append(sorted(t))
    return txns


DB = make_db()
ORACLE20 = bruteforce_fim(DB, min_sup=20)


# ---------------------------------------------------------------------------
# max_k matrix (mirrors test_max_k_boundaries_all_backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("max_k", [1, 2, 3, None])
def test_apriori_max_k_boundaries(max_k):
    """Apriori must return exactly the oracle truncated at max_k — the same
    contract the five engine backends honor."""
    res = apriori_mine(DB, 10, 20, max_k=max_k)
    expect = {k: v for k, v in ORACLE20.items()
              if max_k is None or len(k) <= max_k}
    assert res.support_map == expect
    if max_k is not None:
        assert len(res.counts) <= max_k


@pytest.mark.parametrize("max_k", [1, 2, 3, None])
def test_apriori_max_k_matches_eclat_driver(max_k):
    """Level-by-level agreement with mine() under the same max_k."""
    ap = apriori_mine(DB, 10, 20, max_k=max_k)
    ec = mine(DB, 10, EclatConfig(min_sup=20, variant="v4", p=3, max_k=max_k))
    assert ap.support_map == ec.support_map()
    assert ap.counts == ec.counts
    assert ap.total == ec.total


def test_apriori_max_k_validation():
    """Regression: ``max_k or n1`` read the (invalid) max_k=0 as *unbounded*
    via truthiness; now every max_k < 1 is rejected like the Eclat driver."""
    for bad in (0, -1):
        with pytest.raises(ValueError, match="max_k"):
            apriori_mine(DB, 10, 20, max_k=bad)


def test_apriori_cand_chunk_validation():
    with pytest.raises(ValueError, match="cand_chunk"):
        apriori_mine(DB, 10, 20, cand_chunk=0)


def test_apriori_tiny_cand_chunk_same_answer():
    """Chunked candidate counting must not depend on the chunk size."""
    assert apriori_mine(DB, 10, 20, cand_chunk=7).support_map == ORACLE20


# ---------------------------------------------------------------------------
# degenerate databases (empty / singleton universe), vs the Eclat drivers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("txns,n_items", [
    ([], 8),                                  # empty database
    ([[], [], []], 8),                        # all-empty transactions
    ([[0], [0], []], 1),                      # singleton item universe
    ([[0]], 1),                               # one txn, one item
    ([[1, 3], [1, 3], [1, 3]], 5),            # every itemset ties at n_txn
])
def test_apriori_degenerate_matches_eclat(txns, n_items):
    ap = apriori_mine(txns, n_items, 1)
    ec = mine(txns, n_items, EclatConfig(min_sup=1, variant="v4", p=3))
    assert ap.support_map == ec.support_map()
    assert ap.total == ec.total


def test_apriori_fraction_thresholds_match_eclat():
    """resolve_min_sup is shared; the *resolved* behavior must agree on the
    fraction/count boundary cases (1.0 = every txn, 0.5 = half, count 2)."""
    for ms in (1.0, 0.5, 2):
        ap = apriori_mine(DB, 10, ms)
        ec = mine(DB, 10, EclatConfig(min_sup=ms, variant="v4", p=3))
        assert ap.stats["abs_min_sup"] == ec.stats["abs_min_sup"]
        assert ap.support_map == ec.support_map()


def test_apriori_rejects_bad_min_sup():
    for bad in (0, -1, 1.5, True):
        with pytest.raises((ValueError, TypeError)):
            apriori_mine(DB, 10, bad)
