"""analysis.hlo_parse edge cases the IR contract layer leans on.

The budgets in staticcheck.contracts are only as trustworthy as the HLO
textual pass: a collective the parser drops (while bodies, ROOT-prefixed
instructions, async -start/-done pairs) is traffic the budget silently
stops bounding.  These tests pin the counting rules with synthetic HLO.
"""
import pytest

from repro.analysis.hlo_parse import (CollectiveInstr, DTYPE_BYTES,
                                      parse_collectives)


def test_basic_all_reduce_counted_with_instr_record():
    hlo = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %e (x: f32[256]) -> f32[256] {
  %x = f32[256]{0} parameter(0)
  %all-reduce.1 = f32[256]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %r = f32[256]{0} copy(%all-reduce.1)
}
"""
    stats = parse_collectives(hlo, n_devices=4)
    assert stats.count == {"all-reduce": 1}
    assert stats.bytes_raw["all-reduce"] == 256 * 4
    # ring all-reduce: 2 (g-1)/g x bytes
    assert stats.bytes_wire["all-reduce"] == pytest.approx(256 * 4 * 2 * 3 / 4)
    (instr,) = stats.instrs
    assert isinstance(instr, CollectiveInstr)
    assert (instr.kind, instr.op, instr.group_size) == (
        "all-reduce", "all-reduce", 4)
    assert instr.line == 12


def test_root_prefixed_collective_is_not_skipped():
    # the tidsharded psum lowers to `ROOT %all-reduce...` inside the
    # shard_map body computation — missing it voids the whole budget check
    hlo = ("ROOT %all-reduce.7 = s32[256]{0} all-reduce(%p), "
           "replica_groups={{0,1}}, to_apply=%add")
    stats = parse_collectives(hlo, n_devices=2)
    assert stats.count == {"all-reduce": 1}
    assert stats.instrs[0].group_size == 2


def test_while_body_collective_counted_once():
    # HLO text holds each computation once; an all-gather inside a while
    # body must contribute exactly one instruction (the roofline layer
    # re-multiplies by trip count, not this pass)
    hlo = """
%body (s: (s32[], u32[64])) -> (s32[], u32[64]) {
  %s = (s32[], u32[64]) parameter(0)
  %v = u32[64]{0} get-tuple-element(%s), index=1
  %all-gather.1 = u32[64]{0} all-gather(%v), replica_groups={{0,1}}, dimensions={0}
  ROOT %t = (s32[], u32[64]) tuple(%i, %all-gather.1)
}

ENTRY %e (x: (s32[], u32[64])) -> (s32[], u32[64]) {
  %x = (s32[], u32[64]) parameter(0)
  ROOT %w = (s32[], u32[64]) while(%x), condition=%cond, body=%body
}
"""
    stats = parse_collectives(hlo, n_devices=2)
    assert stats.count == {"all-gather": 1}
    assert stats.total_count == 1


def test_async_start_done_pair_counted_once():
    hlo = """
  %all-reduce-start.1 = f32[128]{0} all-reduce-start(%x), replica_groups={{0,1}}, to_apply=%add
  %all-reduce-done.1 = f32[128]{0} all-reduce-done(%all-reduce-start.1)
"""
    stats = parse_collectives(hlo, n_devices=2)
    assert stats.count == {"all-reduce": 1}


def test_replica_group_size_one_is_zero_wire():
    # a degenerate group never crosses a link: raw bytes recorded, wire 0
    hlo = ("%all-reduce.1 = f32[64]{0} all-reduce(%x), "
           "replica_groups={{0}}, to_apply=%add")
    stats = parse_collectives(hlo, n_devices=4)
    assert stats.count == {"all-reduce": 1}
    assert stats.bytes_raw["all-reduce"] == 64 * 4
    assert stats.bytes_wire["all-reduce"] == 0.0
    assert stats.instrs[0].group_size == 1


def test_reduce_scatter_accounts_operand_not_result():
    # reduce-scatter's result is 1/g of the operand; the wire cost is the
    # operand's ring pass, so factor = g (g-1)/g over *result* bytes
    g = 4
    hlo = ("%reduce-scatter.1 = s32[64]{0} reduce-scatter(%p), "
           "replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add")
    stats = parse_collectives(hlo, n_devices=g)
    result_bytes = 64 * 4
    assert stats.bytes_raw["reduce-scatter"] == result_bytes
    assert stats.bytes_wire["reduce-scatter"] == pytest.approx(
        result_bytes * g * (g - 1) / g)


def test_unknown_dtype_raises_with_actionable_message():
    hlo = ("%all-reduce.1 = q77[128]{0} all-reduce(%x), "
           "replica_groups={{0,1}}, to_apply=%add")
    with pytest.raises(ValueError, match=r"q77.*DTYPE_BYTES"):
        parse_collectives(hlo, n_devices=2)


def test_unknown_dtype_on_non_collective_is_ignored():
    # only collective instructions are byte-accounted; exotic dtypes
    # elsewhere in the module must not abort the parse
    hlo = "%c = q77[128]{0} convert(%x)"
    stats = parse_collectives(hlo, n_devices=2)
    assert stats.total_count == 0


def test_token_and_narrow_dtype_accounting():
    assert DTYPE_BYTES["s4"] == 0.5
    hlo = ("%all-gather.1 = (u32[32]{0}, token[]) all-gather(%v, %tok), "
           "replica_groups={{0,1}}, dimensions={0}")
    stats = parse_collectives(hlo, n_devices=2)
    # token[] carries no payload; only the u32 result is accounted
    assert stats.bytes_raw["all-gather"] == 32 * 4
    assert stats.bytes_wire["all-gather"] == pytest.approx(32 * 4 * 1 / 2)


def test_collective_permute_full_payload():
    hlo = ("%collective-permute.1 = u32[16]{0} collective-permute(%v), "
           "source_target_pairs={{0,1},{1,0}}")
    stats = parse_collectives(hlo, n_devices=2)
    assert stats.count == {"collective-permute": 1}
    assert stats.bytes_wire["collective-permute"] == 16 * 4


def test_iota_replica_groups_format():
    hlo = ("%all-reduce.1 = f32[8]{0} all-reduce(%x), "
           "replica_groups=[2,4]<=[8], to_apply=%add")
    stats = parse_collectives(hlo, n_devices=8)
    assert stats.instrs[0].group_size == 4
