"""Word-sharded (tid-axis) execution mode: routing, placement, memory, and
bit-exact parity with the single-device backends (DESIGN.md §7).

The contract under test: the frontier bitmap is carried as ``P(None,
"data")`` (never fully replicated), each device intersects and popcounts its
word shard, supports are psum-reduced, survivor compaction stays shard-local
— and none of that is visible in the mined itemsets, for batch v1–v6 and for
streaming windows.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import EclatConfig, bruteforce_fim, mine
from repro.core import engine as eng
from repro.dist.compat import make_mesh
from repro.streaming import StreamConfig, StreamingMiner


def _mesh(n):
    return make_mesh((n,), ("data",), devices=jax.devices()[:n])


def make_db(seed=7, n_items=10, n_txn=150):
    rng = np.random.default_rng(seed)
    txns = []
    for _ in range(n_txn):
        t = set(rng.choice(n_items, size=rng.integers(3, 7), replace=False).tolist())
        if rng.random() < 0.5:
            t |= {0, 1, 2, 3}
        txns.append(sorted(t))
    return txns


DB = make_db()
ORACLE = bruteforce_fim(DB, min_sup=25)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_resolve_engine_routes_shard_modes():
    mesh = _mesh(4)
    assert eng.resolve_engine("pallas", mesh, shard="pairs").name == "sharded"
    assert eng.resolve_engine("pallas", mesh, shard="words").name == "tidsharded"
    assert eng.resolve_engine("tidsharded", mesh).name == "tidsharded"
    e = eng.resolve_engine("jnp", mesh, shard="words")
    assert e.name == "tidsharded" and e.inner == "jnp"
    # graceful degrade without a mesh, like the sharded backend
    assert eng.resolve_engine("tidsharded", None).name == "pallas"
    with pytest.raises(ValueError, match="shard mode"):
        eng.resolve_engine("pallas", mesh, shard="wordz")


def test_mine_config_shard_words_routes_to_tidsharded():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v4", p=4,
                                   shard="words"), mesh=_mesh(4))
    assert res.stats["backend"] == "tidsharded"
    assert res.stats["n_word_shards"] == 4
    assert res.support_map() == ORACLE


# ---------------------------------------------------------------------------
# batch parity: v1–v6 on the 4-device mesh, both inner executors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["v1", "v2", "v3", "v4", "v5", "v6"])
@pytest.mark.parametrize("inner", ["jnp", "pallas"])
def test_mine_tidsharded_matches_oracle(variant, inner):
    res = mine(DB, 10, EclatConfig(min_sup=25, variant=variant, p=3,
                                   use_diffsets=(variant == "v6"),
                                   backend=inner, shard="words",
                                   bucket_min=32), mesh=_mesh(4))
    assert res.stats["backend"] == "tidsharded"
    assert res.support_map() == ORACLE


def test_mine_tidsharded_no_trimatrix():
    res = mine(DB, 10, EclatConfig(min_sup=25, variant="v5", p=3,
                                   tri_matrix=False, shard="words",
                                   bucket_min=32), mesh=_mesh(4))
    assert res.support_map() == ORACLE


# ---------------------------------------------------------------------------
# placement: the frontier is word-sharded, not replicated
# ---------------------------------------------------------------------------

def test_frontier_is_word_sharded_not_replicated():
    rng = np.random.default_rng(0)
    bitmaps = rng.integers(0, 2**32, (32, 8), dtype=np.uint32)
    left = rng.integers(0, 32, 24).astype(np.int32)
    right = rng.integers(0, 32, 24).astype(np.int32)
    sup_left = np.zeros(24, np.int32)
    mesh = _mesh(4)
    e = eng.make_engine("tidsharded", mesh=mesh, bucket_min=8, inner="jnp")
    res = e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                   mode=eng.MODE_TIDSET, min_sup=1)
    sh = res.bitmaps.sharding
    assert isinstance(sh, NamedSharding)
    assert sh.spec == P(None, "data")
    # each device materializes exactly 1/4 of the frontier bytes
    assert res.bitmaps.addressable_shards[0].data.nbytes * 4 == res.bitmaps.nbytes
    # feeding the sharded frontier back in (the bottom-up loop) keeps it placed
    res2 = e.expand(res.bitmaps, np.zeros(4, np.int32),
                    np.ones(4, np.int32) % max(res.supports.shape[0], 1),
                    res.supports[:1].repeat(4).astype(np.int32),
                    mode=eng.MODE_TIDSET, min_sup=1)
    assert res2.bitmaps.sharding.spec == P(None, "data")


def test_per_device_bytes_shrink_with_mesh_size():
    """The point of the mode: per-device frontier memory ~ total/n_shards."""
    rng = np.random.default_rng(1)
    bitmaps = rng.integers(0, 2**32, (64, 16), dtype=np.uint32)
    left = rng.integers(0, 64, 32).astype(np.int32)
    right = rng.integers(0, 64, 32).astype(np.int32)
    sup_left = np.zeros(32, np.int32)
    per_dev = {}
    sups = {}
    for n in (1, 2, 4):
        e = eng.make_engine("tidsharded", mesh=_mesh(n), bucket_min=8,
                            inner="jnp")
        res = e.expand(jnp.asarray(bitmaps), left, right, sup_left,
                       mode=eng.MODE_TIDSET, min_sup=1)
        per_dev[n] = res.bitmaps.addressable_shards[0].data.nbytes
        sups[n] = res.supports.tolist()
    assert sups[1] == sups[2] == sups[4]          # unchanged output
    assert per_dev[2] == per_dev[1] // 2
    assert per_dev[4] == per_dev[1] // 4


def test_empty_frontier_and_single_item():
    """The edge shapes from test_engine, through the full tidsharded expand."""
    mesh = _mesh(4)
    e = eng.make_engine("tidsharded", mesh=mesh, bucket_min=8, inner="jnp")
    bm = jnp.asarray(np.random.default_rng(2).integers(
        0, 2**32, (1, 1), dtype=np.uint32))
    res = e.expand(bm, np.zeros(0, np.int32), np.zeros(0, np.int32),
                   np.zeros(0, np.int32), mode=eng.MODE_TIDSET, min_sup=1)
    assert res.mask.shape == (0,) and res.supports.shape == (0,)
    res = e.expand(bm, np.zeros(1, np.int32), np.zeros(1, np.int32),
                   np.zeros(1, np.int32), mode=eng.MODE_TIDSET, min_sup=1)
    assert res.mask.shape == (1,)


# ---------------------------------------------------------------------------
# streaming windows: sharded ring + tidsharded engine, bit-exact
# ---------------------------------------------------------------------------

def _batches(n_batches, batch_txns, seed=0, n_items=12):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        batch = []
        for _ in range(batch_txns):
            t = set(rng.choice(n_items, size=rng.integers(3, 7),
                               replace=False).tolist())
            if rng.random() < 0.5:
                t |= {0, 1, 2}
            batch.append(sorted(t))
        out.append(batch)
    return out


@pytest.mark.parametrize("route", ["shard_words", "backend_name"])
def test_streaming_tidsharded_matches_batch_mine(route):
    mesh = _mesh(4)
    if route == "shard_words":
        cfg = StreamConfig(min_sup=5, n_blocks=3, block_txns=32,
                           backend="pallas", shard="words", bucket_min=16)
    else:
        cfg = StreamConfig(min_sup=5, n_blocks=3, block_txns=32,
                           backend="tidsharded", bucket_min=16)
    miner = StreamingMiner(12, cfg, mesh=mesh)
    assert miner.engine.name == "tidsharded"
    # the window ring itself is word-sharded — the window never fully
    # materializes on one device
    assert miner.ring.device.sharding.spec == P(None, "data")
    for i, batch in enumerate(_batches(6, 28, seed=4)):
        res = miner.advance(batch)
        miner.ring.validate()
        window = miner.window_transactions()
        batch_res = mine(window, 12, EclatConfig(min_sup=5, variant="v4",
                                                 p=4, backend="jnp",
                                                 bucket_min=16))
        assert res.support_map() == batch_res.support_map(), f"slide {i}"


def test_streaming_tidsharded_empty_window():
    miner = StreamingMiner(12, StreamConfig(min_sup=2, n_blocks=2,
                                            block_txns=32, shard="words"),
                           mesh=_mesh(4))
    res = miner.mine_window()
    assert res.total == 0 and res.support_map() == {}
    res = miner.advance([])
    assert res.total == 0


def test_sharded_ring_pads_word_axis():
    """3 blocks x 1 word/block = 3 words on a 4-shard mesh -> device width 4,
    host mirror stays logical, pad words stay zero across slides."""
    miner = StreamingMiner(12, StreamConfig(min_sup=2, n_blocks=3,
                                            block_txns=32, shard="words"),
                           mesh=_mesh(4))
    assert miner.ring.n_words == 3 and miner.ring.n_words_dev == 4
    for batch in _batches(5, 20, seed=9):
        miner.advance(batch)
        miner.ring.validate()
    assert not np.asarray(miner.ring.device)[:, 3:].any()
