"""Sharded (multi-device) Eclat backend: exactness + balance accounting.
Runs in a 4-device subprocess (XLA device count is process-global)."""
import os
import subprocess
import sys

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, json
from repro.core import mine, EclatConfig, bruteforce_fim
from repro.dist.compat import make_mesh
mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(7)
txns = []
for _ in range(200):
    t = set(rng.choice(14, size=rng.integers(3, 8), replace=False).tolist())
    if rng.random() < 0.4: t |= {0, 1, 2, 3}
    txns.append(sorted(t))
oracle = bruteforce_fim(txns, min_sup=30)
effs = {}
for v in ("v1", "v4", "v5", "v6"):
    res = mine(txns, 14, EclatConfig(min_sup=30, variant=v, p=8), mesh=mesh)
    assert res.support_map() == oracle, v
    effs[v] = res.stats["device_balance"]["padding_efficiency"]
assert effs["v5"] >= effs["v4"] - 1e-9   # paper: reverse-hash balances better
assert effs["v6"] >= effs["v5"] - 1e-9   # beyond-paper greedy at least as good
print("SHARDED_OK", json.dumps(effs))
"""


def test_sharded_backend_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _SNIPPET], capture_output=True,
                       text=True, env=env, cwd=os.getcwd(), timeout=600)
    assert r.returncode == 0 and "SHARDED_OK" in r.stdout, r.stderr[-2000:]
