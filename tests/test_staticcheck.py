"""repro.staticcheck: AST rules, IR contracts, shape audit, and the CI gate.

Three properties are load-bearing:

  * every committed must-fail fixture still fails (a fixture that passes
    means the checker rotted — the gate's own acceptance criterion);
  * the merged repo is clean under every layer;
  * suppression comments work, so justified exceptions stay expressible.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
FIXTURE_DIR = os.path.join(ROOT, "src", "repro", "staticcheck", "fixtures")
GATE = os.path.join(ROOT, "scripts", "check_static.py")


# ---------------------------------------------------------------------------
# layer 1: AST lint
# ---------------------------------------------------------------------------

class TestAstLint:
    def test_every_rule_fixture_still_fails(self):
        from repro.staticcheck import rule_ids
        from repro.staticcheck.astlint import lint_file

        for rid in rule_ids():
            path = os.path.join(FIXTURE_DIR, f"{rid.lower()}_bad.py")
            found = lint_file(path, root=ROOT)
            assert any(f.rule == rid for f in found), (
                f"fixture {path} no longer triggers {rid}")

    def test_repo_strict_zones_lint_clean(self):
        from repro.staticcheck import iter_python_files, lint_paths

        files = iter_python_files(ROOT, [os.path.join("src", "repro"),
                                         "scripts"])
        assert len(files) > 50          # the walk actually found the repo
        findings = lint_paths(files, root=ROOT)
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_suppression_comment_silences_rule(self, tmp_path):
        from repro.staticcheck.astlint import lint_file

        src = textwrap.dedent("""\
            import numpy as np

            def group(q):
                slots = np.empty(q, np.int64)  # staticcheck: disable=RS002
                return slots
        """)
        p = tmp_path / "suppressed.py"
        p.write_text(src)
        assert lint_file(str(p)) == []
        # same file without the suppression must fail
        p.write_text(src.replace("  # staticcheck: disable=RS002", ""))
        found = lint_file(str(p))
        assert [f.rule for f in found] == ["RS002"]

    def test_suppression_on_preceding_line(self, tmp_path):
        from repro.staticcheck.astlint import lint_file

        p = tmp_path / "prev_line.py"
        p.write_text(textwrap.dedent("""\
            import numpy as np

            def group(q):
                # staticcheck: disable=RS002
                slots = np.empty(q, np.int64)
                return slots
        """))
        assert lint_file(str(p)) == []

    def test_rs003_allows_explicit_none_comparison(self, tmp_path):
        from repro.staticcheck.astlint import lint_file

        p = tmp_path / "ok003.py"
        p.write_text(textwrap.dedent("""\
            def depth(max_k):
                if max_k is not None and max_k < 3:
                    return max_k
                return 10
        """))
        assert lint_file(str(p)) == []

    def test_rs001_ignores_test_files(self, tmp_path):
        from repro.staticcheck.astlint import lint_file

        p = tmp_path / "test_something.py"
        p.write_text("def test_x():\n    assert 1 + 1 == 2\n")
        assert lint_file(str(p)) == []

    def test_rs005_only_fires_in_hot_functions(self, tmp_path):
        from repro.staticcheck.astlint import lint_file

        # no hot-path pragma, not a registered hot module -> jnp.asarray ok
        p = tmp_path / "cold.py"
        p.write_text(textwrap.dedent("""\
            import jax.numpy as jnp

            def setup(x):
                return jnp.asarray(x)
        """))
        assert lint_file(str(p)) == []

    def test_warn_severity_override(self):
        from repro.staticcheck.astlint import lint_file

        path = os.path.join(FIXTURE_DIR, "rs001_bad.py")
        found = lint_file(path, root=ROOT, severity="warning")
        assert found and all(f.severity == "warning" for f in found)

    def test_syntax_error_reported_not_raised(self, tmp_path):
        from repro.staticcheck.astlint import lint_file

        p = tmp_path / "broken.py"
        p.write_text("def broken(:\n")
        found = lint_file(str(p))
        assert [f.rule for f in found] == ["RS000"]


# ---------------------------------------------------------------------------
# layer 2: lowered-IR contracts
# ---------------------------------------------------------------------------

class TestIrContracts:
    def test_all_backends_match_declared_budgets(self, host_devices):
        from repro.staticcheck.contracts import check_all_contracts

        findings, summary = check_all_contracts()
        assert findings == [], "\n".join(f.format() for f in findings)
        got = {b: info["collectives"]
               for b, info in summary["backends"].items()}
        assert got == {
            "jnp": {}, "pallas": {}, "sharded": {},
            "tidsharded": {"all-reduce": 1}, "grid": {"all-reduce": 1},
        }
        # the word-sharded ring write must stay collective-free: a
        # dynamic_update_slice on the sharded axis lowers to a whole-ring
        # all-gather, which is exactly what this line would catch
        assert summary["ring_write"]["collectives"] == {}

    @pytest.mark.parametrize("name", ["extra_psum", "frontier_allgather",
                                      "fat_psum", "wrong_axis_psum"])
    def test_contract_fixtures_still_fail(self, host_devices, name):
        from repro.staticcheck.contracts import check_contract_fixture

        found = check_contract_fixture(name)
        assert found, f"IR fixture {name} no longer violates its contract"
        expected = {
            "extra_psum": "IR001", "frontier_allgather": "IR001",
            "fat_psum": "IR002", "wrong_axis_psum": "IR003",
        }[name]
        assert expected in {f.rule for f in found}


# ---------------------------------------------------------------------------
# layer 3: runtime-shape audit
# ---------------------------------------------------------------------------

class TestShapeAudit:
    def test_streaming_steady_state_is_shape_closed(self):
        from repro.staticcheck.shapes import audit_streaming

        findings, summary = audit_streaming(backend="pallas")
        assert findings == [], "\n".join(f.format() for f in findings)
        assert summary["audited_slides"] >= 5
        assert summary["itemsets_last_slide"] > 0

    def test_tidsharded_stream_clean_under_guard(self, host_devices):
        from repro.dist.compat import make_mesh
        from repro.staticcheck.shapes import audit_streaming

        mesh = make_mesh((4,), ("data",), devices=host_devices[:4])
        findings, summary = audit_streaming(backend="tidsharded",
                                            shard="words", mesh=mesh)
        assert findings == [], "\n".join(f.format() for f in findings)
        assert summary["audited_slides"] >= 5

    def test_warm_mine_run_is_clean_and_deep(self):
        from repro.staticcheck.shapes import audit_mine

        findings, summary = audit_mine()
        assert findings == [], "\n".join(f.format() for f in findings)
        assert summary["levels"] >= 3

    def test_shape_fixture_still_fails_all_three_rules(self):
        from repro.staticcheck.shapes import check_shape_fixture

        found = check_shape_fixture()
        assert {"SH001", "SH002", "SH003"} <= {f.rule for f in found}


# ---------------------------------------------------------------------------
# the gate script (subprocess: exit codes are the CI contract)
# ---------------------------------------------------------------------------

def _run_gate(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, GATE, *args],
                          capture_output=True, text=True, env=env, cwd=ROOT)


class TestGateScript:
    def test_lint_target_fixture_exits_one(self):
        proc = _run_gate("--lint-target",
                         os.path.join(FIXTURE_DIR, "rs004_bad.py"))
        assert proc.returncode == 1, proc.stderr
        assert "RS004" in proc.stderr

    def test_lint_target_clean_file_exits_zero(self):
        proc = _run_gate("--lint-target",
                         os.path.join(ROOT, "scripts", "check_docs.py"))
        assert proc.returncode == 0, proc.stderr

    def test_contract_fixture_exits_one(self):
        proc = _run_gate("--contract-fixture", "extra_psum")
        assert proc.returncode == 1, proc.stderr
        assert "IR001" in proc.stderr

    def test_shape_fixture_exits_one(self):
        proc = _run_gate("--shape-fixture")
        assert proc.returncode == 1, proc.stderr
        assert "SH001" in proc.stderr

    def test_full_gate_passes_on_merged_repo(self, tmp_path):
        report = tmp_path / "findings.json"
        proc = _run_gate("--report", str(report))
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "static: OK" in proc.stdout
        assert report.exists()
        import json
        data = json.loads(report.read_text())
        assert data["n_errors"] == 0
        assert data["summary"]["lint_fixtures"]["rotted"] == 0
        assert data["summary"]["ir_fixtures"]["rotted"] == 0
        assert data["summary"]["shape_fixture"]["rotted"] == 0
