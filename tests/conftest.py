"""Shared test fixtures: a 4-device host platform + deterministic RNGs.

The XLA flag must be set before jax initializes its backend, i.e. at conftest
import time — pytest imports conftest before any test module, so in-process
tests can build 4-device meshes (``make_mesh_named("tiny")``,
test_dist_sharding) without a subprocess.  Subprocess-based tests set their
own XLA_FLAGS and are unaffected (the child overrides the inherited value).
"""
import os
import random

_FLAG = "--xla_force_host_platform_device_count=4"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def host_devices():
    """The 4 host devices the XLA flag above pins (session-wide invariant)."""
    import jax
    devices = jax.devices()
    assert len(devices) >= 4, (
        "conftest must set --xla_force_host_platform_device_count=4 before "
        f"jax initializes; got {len(devices)} device(s)")
    return devices


@pytest.fixture(autouse=True)
def _seed_rngs():
    """Seed the global NumPy/stdlib RNGs per test; JAX randomness is keyed
    explicitly (PRNGKey) so per-test isolation needs no global state."""
    np.random.seed(0)
    random.seed(0)
    yield
