"""The unified serving path (DESIGN.md §11): version stamping, version-keyed
caches, async admission, backpressure, liveness, and serving restore.

The load-bearing claims under test:

* equal ``window_version`` ⟹ identical window contents — stamped once per
  completed slide, crash-consistent (a killed slide publishes no version),
  and round-tripped through ``MinerState``;
* every batched answer is bit-identical to the same query answered
  synchronously at its stamped version, including while concurrent readers
  race live ``ingest`` calls and under the bounded-queue shed path;
* a full admission queue sheds or blocks per policy, a stopped frontend
  fails its pending tickets, and a stalled writer is *reported*
  (``WriterStalledError``) — readers never hang;
* the query packer's work model is parameter-sensitive (a ``k=1`` probe is
  not a ``k=10_000`` scan) and packs real work better than a flat model;
* a frontend restored from a crashed run's checkpoint answers bit-exactly
  like one that never crashed (reusing the §10 fault-injection harness).
"""
import threading
import time

import numpy as np
import pytest

from faultinject import crashed_run, make_batches
from repro.serving import (AdmissionConfig, ItemsetQuery, QueryShed,
                           ServingFrontend, StreamQueryService, Ticket,
                           VersionedCache, answer_query, pack_queries,
                           query_mix, query_work, run_storm, verify_storm)
from repro.streaming import StreamConfig, StreamingMiner
from repro.training import Heartbeat, HeartbeatMonitor, WriterStalledError

N_ITEMS = 12
CFG = dict(min_sup=5, n_blocks=3, block_txns=32, bucket_min=16,
           backend="jnp")


def _miner():
    return StreamingMiner(N_ITEMS, StreamConfig(**CFG),
                          keep_transactions=False)


def _batches(n, seed=0):
    return make_batches(n, 24, seed=seed, n_items=N_ITEMS)


# ---------------------------------------------------------------------------
# version stamping
# ---------------------------------------------------------------------------

def test_window_version_monotonic_per_slide():
    miner = _miner()
    assert miner.window_version == 0
    versions = []
    for b in _batches(4):
        res = miner.advance(b)
        versions.append(res.version)
    assert versions == [1, 2, 3, 4]
    # a re-mine without a slide shares the version: same window contents
    assert miner.mine_window().version == 4
    assert miner.mine_window().stats["window_version"] == 4


def test_crashed_slide_publishes_no_version():
    from faultinject import crash_at
    from repro.faults import InjectedFault

    miner = _miner()
    batches = _batches(3)
    for b in batches[:2]:
        miner.advance(b)
    assert miner.window_version == 2
    with crash_at("miner:mid_append"):
        with pytest.raises(InjectedFault):
            miner.advance(batches[2])
    # the half-applied slide must not have minted a version
    assert miner.window_version == 2


def test_window_version_roundtrips_through_miner_state():
    miner = _miner()
    for b in _batches(3):
        miner.advance(b)
    state = miner.snapshot_state()
    restored = StreamingMiner.from_state(state, keep_transactions=False)
    assert restored.window_version == 3
    # and keeps counting from there
    res = restored.advance(_batches(1, seed=7)[0])
    assert res.version == 4


# ---------------------------------------------------------------------------
# version-keyed cache
# ---------------------------------------------------------------------------

def test_cache_hit_miss_stale_counters():
    c = VersionedCache()
    found, _ = c.lookup(1, "a")
    assert not found
    c.insert(1, "a", [1, 2])
    found, val = c.lookup(1, "a")
    assert found and val == [1, 2]
    # same key, newer version: stale (counted and evicted), not a plain miss
    found, _ = c.lookup(2, "a")
    assert not found
    assert (c.hits, c.misses, c.stale) == (1, 1, 1)
    assert len(c) == 0


def test_cache_advance_evicts_exactly_old_versions():
    c = VersionedCache()
    c.insert(1, "old1", 1)
    c.insert(1, "old2", 2)
    c.insert(2, "new", 3)
    assert c.advance(2) == 2
    assert len(c) == 1
    assert c.lookup(2, "new") == (True, 3)
    assert c.stats()["stale_evicted"] == 2


def test_cached_answer_reused_between_slides_and_invalidated_after():
    service = StreamQueryService(_miner())
    service.ingest(_batches(1)[0])
    a = service.rules(0.7)
    b = service.rules(0.7)
    assert a is b                      # k=None hit: the identical object
    # topk slices the cached full ranking: a hit, even across different k
    service.top_k_itemsets(5, min_len=1)
    hits_before = service.cache.stats()["hits"]
    assert service.top_k_itemsets(3, min_len=1) == \
        service.top_k_itemsets(5, min_len=1)[:3]
    assert service.cache.stats()["hits"] >= hits_before + 2
    service.ingest(_batches(1, seed=3)[0])
    c = service.rules(0.7)
    assert c is not a                  # the slide invalidated it


# ---------------------------------------------------------------------------
# query packer work model (the k/min_conf regression)
# ---------------------------------------------------------------------------

def test_query_work_is_parameter_sensitive():
    n = 10_000
    probe = ItemsetQuery(qid=0, kind="topk", k=1)
    scan = ItemsetQuery(qid=1, kind="topk", k=10_000)
    assert query_work(probe, n) < query_work(scan, n)
    tight = ItemsetQuery(qid=2, kind="rules", k=5, min_conf=0.9)
    loose = ItemsetQuery(qid=3, kind="rules", k=5, min_conf=0.5)
    assert query_work(tight, n) < query_work(loose, n)
    # rules dominate a same-k topk (antecedent enumeration)
    assert query_work(ItemsetQuery(qid=4, kind="rules", k=5, min_conf=0.8), n) \
        > query_work(ItemsetQuery(qid=5, kind="topk", k=5), n)


def test_pack_queries_balances_true_work_better_than_flat_model():
    n_itemsets, n_slots = 2000, 4
    # pathological under a flat model: heavy and light queries alternate, so
    # count-balanced slots are maximally work-imbalanced
    queries = []
    for i in range(16):
        if i % 2 == 0:
            queries.append(ItemsetQuery(qid=i, kind="rules", k=2000,
                                        min_conf=0.5))
        else:
            queries.append(ItemsetQuery(qid=i, kind="topk", k=1))
    true_work = np.array([query_work(q, n_itemsets) for q in queries])

    def slot_loads(assign):
        return np.array([true_work[assign == s].sum()
                         for s in range(n_slots)])

    assign, stats = pack_queries(queries, n_slots, n_itemsets)
    from repro.core.partitioners import pack_items
    flat_assign, _ = pack_items(np.ones(len(queries)), n_slots)

    packed, flat = slot_loads(assign), slot_loads(flat_assign)
    assert packed.max() < flat.max()   # strictly better balance on real work
    # near-perfect: max slot within 5% of the ideal equal split
    assert packed.max() <= true_work.sum() / n_slots * 1.05
    assert stats["padding_efficiency"] >= 0.95


def test_answer_batch_stats_reflect_executed_packing():
    service = StreamQueryService(_miner())
    service.ingest(_batches(1)[0])
    queries = query_mix(12, seed=1)
    answers, stats = service.answer_batch(queries, n_batches=3)
    assert sorted(answers) == sorted(q.qid for q in queries)
    assert sum(stats["queries_per_slot"]) == len(queries)
    assert stats["window_version"] == service.window_version


# ---------------------------------------------------------------------------
# heartbeat / stall detection
# ---------------------------------------------------------------------------

def test_heartbeat_monitor_latches_and_reports():
    t = {"now": 0.0}
    hb = Heartbeat(clock=lambda: t["now"])
    fired = []
    mon = HeartbeatMonitor(hb, timeout_s=1.0, on_stall=fired.append,
                           name="w")
    t["now"] = 0.9
    assert not mon.check()
    hb.beat(step=3)
    t["now"] = 1.8
    assert not mon.check()             # the beat reset the age
    t["now"] = 3.0
    assert mon.check()
    with pytest.raises(WriterStalledError, match="no heartbeat"):
        mon.assert_alive()
    hb.beat(step=4)
    assert mon.check()                 # latched: a late beat does not unstall
    assert len(fired) == 1 and fired[0]["last_step"] == 3


def test_wait_for_version_reports_stalled_writer():
    frontend = ServingFrontend(
        _miner(), AdmissionConfig(stall_timeout_s=0.05))
    try:
        with pytest.raises(WriterStalledError):
            frontend.wait_for_version(1, timeout=5.0, poll_s=0.01)
        assert frontend.writer_stalled
        assert frontend.metrics.summary()["n_stalls"] >= 1
    finally:
        frontend.stop()


# ---------------------------------------------------------------------------
# admission: batched answers vs sync, backpressure, lifecycle
# ---------------------------------------------------------------------------

def test_frontend_matches_synchronous_answers():
    miner = _miner()
    frontend = ServingFrontend(miner, AdmissionConfig())
    try:
        frontend.ingest(_batches(1)[0])
        queries = query_mix(20, seed=2)
        tickets = frontend.submit_many(queries)
        for q, ticket in zip(queries, tickets):
            answer, version = ticket.result(timeout=30.0)
            assert version == frontend.window_version
            direct, _ = answer_query(frontend.snapshot_at(version), q,
                                     cache=None)
            assert answer == direct
    finally:
        frontend.stop()


def test_shed_policy_sheds_and_queued_queries_stay_consistent():
    frontend = ServingFrontend(
        _miner(), AdmissionConfig(max_queue=4, policy="shed"),
        auto_start=False)                   # nothing drains: queue must fill
    frontend.ingest(_batches(1)[0])
    queries = query_mix(6, seed=3)
    frontend._running = True                 # admit without a drain worker
    admitted = []
    shed = 0
    for q in queries:
        try:
            admitted.append(frontend.submit(q))
        except QueryShed:
            shed += 1
    assert len(admitted) == 4 and shed == 2
    assert frontend.metrics.summary()["n_shed"] == 2
    # the queue drains once the worker starts; every survivor sees exactly
    # one consistent version and a bit-identical answer
    frontend._running = False
    frontend.start()
    try:
        for t in admitted:
            answer, version = t.result(timeout=30.0)
            direct, _ = answer_query(frontend.snapshot_at(version), t.query,
                                     cache=None)
            assert answer == direct
    finally:
        frontend.stop()


def test_block_policy_bounded_wait_then_shed():
    frontend = ServingFrontend(
        _miner(), AdmissionConfig(max_queue=1, policy="block",
                                  block_timeout_s=0.1),
        auto_start=False)
    frontend._running = True                 # admit without a drain worker
    frontend.submit(ItemsetQuery(qid=0))
    t0 = time.perf_counter()
    with pytest.raises(QueryShed, match="timed out"):
        frontend.submit(ItemsetQuery(qid=1))
    assert time.perf_counter() - t0 >= 0.1   # it genuinely waited
    frontend._running = False


def test_stop_fails_pending_tickets_instead_of_hanging():
    frontend = ServingFrontend(_miner(), AdmissionConfig(), auto_start=False)
    frontend._running = True
    ticket = frontend.submit(ItemsetQuery(qid=0))
    frontend.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        ticket.result(timeout=1.0)
    with pytest.raises(RuntimeError, match="not running"):
        frontend.submit(ItemsetQuery(qid=1))


def test_submit_rejected_when_never_started():
    frontend = ServingFrontend(_miner(), auto_start=False)
    with pytest.raises(RuntimeError, match="not running"):
        frontend.submit(ItemsetQuery(qid=0))


# ---------------------------------------------------------------------------
# readers racing the writer (satellite: interleaving coverage)
# ---------------------------------------------------------------------------

def test_concurrent_readers_each_see_one_consistent_version():
    miner = _miner()
    frontend = ServingFrontend(
        miner, AdmissionConfig(keep_versions=16, max_wait_s=0.001))
    batches = _batches(8, seed=11)
    frontend.ingest(batches[0])
    try:
        def writer():
            for b in batches[1:]:
                frontend.ingest(b)
        wt = threading.Thread(target=writer, daemon=True)
        queries = query_mix(60, seed=4)
        wt.start()
        outcome = run_storm(frontend, queries, n_clients=4, timeout_s=60.0)
        wt.join(timeout=60.0)
        assert not wt.is_alive()
        assert outcome["errors"] == {}
        assert not outcome["shed"]
        assert sorted(outcome["answers"]) == [q.qid for q in queries]
        versions = {v for _, v in outcome["answers"].values()}
        assert versions <= set(range(1, 9))
        # the interleaving actually happened: answers span multiple windows
        assert frontend.window_version == 8
        # bit-identity of every answer at its stamped version; raises on
        # any divergence (torn read / wrong-version answer)
        ver = verify_storm(frontend, queries, outcome)
        assert ver["verified"] == len(queries)
        assert not ver["unverifiable"]
    finally:
        frontend.stop()


def test_interleaving_consistency_under_shed_pressure():
    """The bounded-queue shed path must not corrupt surviving answers."""
    miner = _miner()
    frontend = ServingFrontend(
        miner, AdmissionConfig(max_queue=2, policy="shed", max_wait_s=0.02,
                               keep_versions=16))
    batches = _batches(5, seed=13)
    frontend.ingest(batches[0])
    try:
        def writer():
            for b in batches[1:]:
                frontend.ingest(b)
        wt = threading.Thread(target=writer, daemon=True)
        wt.start()
        queries = query_mix(80, seed=5)
        outcome = run_storm(frontend, queries, n_clients=8, timeout_s=60.0)
        wt.join(timeout=60.0)
        assert outcome["errors"] == {}
        answered = set(outcome["answers"]) | set(outcome["shed"])
        assert answered == {q.qid for q in queries}   # shed XOR answered
        ver = verify_storm(frontend, queries, outcome)
        assert ver["verified"] == len(outcome["answers"])
    finally:
        frontend.stop()


# ---------------------------------------------------------------------------
# serving restore (satellite: kill-and-restore through the frontend)
# ---------------------------------------------------------------------------

def test_frontend_restores_from_crashed_run_and_serves_identically(tmp_path):
    cfg = StreamConfig(**CFG)
    batches = _batches(4, seed=42)
    step = crashed_run(N_ITEMS, cfg, batches, str(tmp_path),
                       "miner:mid_append", kill_slide=2)
    assert step == 2

    # the reference server never crashed
    ref = StreamQueryService(StreamingMiner(N_ITEMS, cfg,
                                            keep_transactions=False))
    for b in batches:
        ref.ingest(b)

    frontend, completed = ServingFrontend.from_checkpoint(
        str(tmp_path), config=AdmissionConfig(keep_versions=16))
    try:
        assert completed == 2
        # a restored server answers immediately, before any live slide —
        # from the restored window at the restored version
        assert frontend.window_version == 2
        assert len(frontend.snapshot.itemsets) > 0
        # replay the tail through the frontend, then interrogate both
        for b in batches[completed:]:
            frontend.ingest(b)
        assert frontend.window_version == ref.window_version == 4
        queries = query_mix(24, seed=6)
        tickets = frontend.submit_many(queries)
        for q, t in zip(queries, tickets):
            answer, version = t.result(timeout=30.0)
            assert version == 4
            direct, _ = answer_query(ref.snapshot, q, cache=None)
            assert answer == direct     # bit-exact with the uncrashed server
    finally:
        frontend.stop()
