"""Streaming re-mine latency: incremental window slides vs full re-mining.

    python benchmarks/streaming_bench.py [--smoke]   # or benchmarks/run.py

For each window size, a T10-style micro-batch stream fills the window, then
steady-state slides are timed two ways over the *same* window contents:

  incremental  ``StreamingMiner.advance`` — block-delta state update + active
               class re-expansion (the repro.streaming path)
  full         batch ``mine()`` from the raw window transactions (repack,
               full supports, full tri-matrix — what a non-incremental
               deployment re-runs per slide)

Both run the same engine backend with warmed jit/bucket caches, and every
timed slide asserts the two support maps are identical, so the speedup is a
like-for-like measure of the incremental state maintenance (DESIGN.md §5).
Writes ``BENCH_streaming.json`` for the cross-PR trajectory.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import List

import numpy as np

if __name__ == "__main__":      # standalone run: make `repro` importable
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import EclatConfig, mine
from repro.data import stream_spec, transaction_stream
from repro.streaming import StreamConfig, StreamingMiner

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_streaming.json")
DATASET = "T10I4D100K"


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


def _measure_window(n_blocks: int, block_txns: int, min_sup: float,
                    backend: str, n_slides: int) -> dict:
    spec = stream_spec(DATASET)
    cfg = StreamConfig(min_sup=min_sup, n_blocks=n_blocks,
                       block_txns=block_txns, backend=backend)
    miner = StreamingMiner(spec.n_items, cfg)
    batches = list(transaction_stream(DATASET, block_txns,
                                      n_blocks + 2 + n_slides, seed=1))
    for b in batches[:n_blocks]:          # fill the window
        miner.advance(b)
    bcfg = EclatConfig(min_sup=min_sup, variant="v4", backend=backend)
    # warm both paths (jit caches, bucket ladders) on two live slides
    for b in batches[n_blocks: n_blocks + 2]:
        miner.advance(b)
        mine(miner.window_transactions(), spec.n_items, bcfg)

    t_inc: List[float] = []
    t_full: List[float] = []
    itemsets = 0
    for b in batches[n_blocks + 2:]:
        t0 = time.perf_counter()
        inc_res = miner.advance(b)
        t_inc.append(time.perf_counter() - t0)
        window = miner.window_transactions()
        t0 = time.perf_counter()
        full_res = mine(window, spec.n_items, bcfg)
        t_full.append(time.perf_counter() - t0)
        if inc_res.support_map() != full_res.support_map():
            raise RuntimeError("incremental/full divergence — bench aborted")
        itemsets = inc_res.total
    inc_ms = float(np.mean(t_inc) * 1e3)
    full_ms = float(np.mean(t_full) * 1e3)
    return {
        "n_blocks": n_blocks,
        "block_txns": block_txns,
        "window_txns": miner.ring.n_txn,
        "n_slides": len(t_inc),
        "itemsets": itemsets,
        "incremental_ms": inc_ms,
        "full_ms": full_ms,
        "speedup": full_ms / inc_ms if inc_ms > 0 else 0.0,
        "results_identical": True,
    }


def streaming_bench(out: List[str], smoke: bool = False) -> dict:
    import jax

    block_txns = 512
    windows = (4, 8) if smoke else (4, 8, 16, 32)
    n_slides = 3 if smoke else 6
    min_sup = 0.01
    report: dict = {
        "dataset": DATASET, "min_sup": min_sup, "smoke": bool(smoke),
        "backend": "pallas", "jax_backend": jax.default_backend(),
        "windows": [],
    }
    for n_blocks in windows:
        entry = _measure_window(n_blocks, block_txns, min_sup,
                                backend="pallas", n_slides=n_slides)
        report["windows"].append(entry)
        out.append(_row(
            f"streaming/w{entry['window_txns']}/incremental",
            entry["incremental_ms"] / 1e3,
            f"full_ms={entry['full_ms']:.1f};speedup=x{entry['speedup']:.2f};"
            f"itemsets={entry['itemsets']}"))
    report["min_speedup"] = min(w["speedup"] for w in report["windows"])
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    out.append(_row("streaming/min_speedup", 0.0,
                    f"x{report['min_speedup']:.2f};json={os.path.basename(BENCH_PATH)}"))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized window sweep (still writes BENCH_streaming.json)")
    args = ap.parse_args()
    rows: List[str] = ["name,us_per_call,derived"]
    streaming_bench(rows, smoke=args.smoke)
    print("\n".join(rows))
