"""Grid-sharded (pairs x words) scaling: parity + per-axis work/memory.

    python benchmarks/gridscale_bench.py [--smoke]   # or benchmarks/run.py

The grid engine (DESIGN.md §8) runs on a 2D ("class", "data") mesh:
candidate pairs split over the class axis, the frontier's packed word axis
over the data axis, frontier carried ``P(None, "data")``.  The 1D modes
each scale one axis and replicate the other — ``shard="pairs"`` replicates
the frontier on every device, ``shard="words"`` replicates the pair work on
every shard.  This bench demonstrates, on the forced 4-device CPU host (a
subprocess, because the XLA device count is process-global):

  parity     batch ``mine()`` v1–v6 and >= 9 streaming window slides are
             bit-identical between the 2x2 grid engine and the jnp backend;
  placement  the same level expansion through the pairs / words / grid
             engines keeps the supports identical while the grid cuts
             per-device frontier bytes ~1/n_data vs "pairs" AND per-device
             pair work ~1/n_class vs "words".

Writes ``BENCH_gridscale.json`` for the cross-PR trajectory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(ROOT, "BENCH_gridscale.json")
DATASET = "T10I4D100K"
VARIANTS = ["v1", "v2", "v3", "v4", "v5", "v6"]
N_STREAM_SLIDES = 9           # acceptance: >= 9 bit-identical window slides


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


# ---------------------------------------------------------------------------
# child: runs under --xla_force_host_platform_device_count=4
# ---------------------------------------------------------------------------

def _child(smoke: bool) -> None:
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import EclatConfig, mine
    from repro.core import engine as eng
    from repro.core.eclat import resolve_min_sup
    from repro.core.vertical import build_vertical
    from repro.data import generate, stream_spec, transaction_stream
    from repro.dist.compat import make_mesh

    if len(jax.devices()) < 4:
        raise SystemExit("child needs 4 forced host devices (XLA_FLAGS)")

    scale = 0.02 if smoke else float(os.environ.get("BENCH_SCALE", "0.08"))
    txns, spec = generate(DATASET, scale=scale, seed=1)
    ms = spec.min_sups[len(spec.min_sups) // 2]
    n_class, n_data = 2, 2
    grid_mesh = make_mesh((n_class, n_data), ("class", "data"),
                          devices=jax.devices()[:4])
    mesh4 = make_mesh((4,), ("data",))
    report: dict = {
        "dataset": DATASET, "scale": scale, "min_sup": float(ms),
        "n_txn": len(txns), "smoke": bool(smoke),
        "jax_backend": jax.default_backend(),
        "grid": [n_class, n_data],
        "parity": {}, "placement": {}, "parity_ok": True,
    }

    # ---- (a) batch parity: v1-v6, 2x2 grid vs jnp -------------------------
    for variant in VARIANTS:
        maps = {}
        walls = {}
        for label, kw, mesh in (
            ("jnp", dict(backend="jnp"), None),
            ("grid", dict(backend="pallas", shard="grid"), grid_mesh),
        ):
            cfg = EclatConfig(min_sup=ms, variant=variant, p=10,
                              use_diffsets=(variant == "v6"), **kw)
            t0 = time.perf_counter()
            res = mine(txns, spec.n_items, cfg, mesh=mesh)
            walls[label] = time.perf_counter() - t0
            maps[label] = res.support_map()
        identical = maps["jnp"] == maps["grid"]
        report["parity"][variant] = {
            "itemsets": len(maps["jnp"]),
            "identical": bool(identical),
            "wall_s": {k: round(v, 4) for k, v in walls.items()},
        }
        report["parity_ok"] &= bool(identical)

    # ---- (a') streaming parity: grid-placed ring, >= 9 slides -------------
    from repro.streaming import StreamConfig, StreamingMiner

    sspec = stream_spec(DATASET)
    block_txns, n_blocks = (128, 2) if smoke else (512, 4)
    miner = StreamingMiner(sspec.n_items,
                           StreamConfig(min_sup=0.01, n_blocks=n_blocks,
                                        block_txns=block_txns,
                                        backend="pallas", shard="grid"),
                           mesh=grid_mesh)
    stream_ok = True
    slides = 0
    for batch in transaction_stream(DATASET, block_txns,
                                    N_STREAM_SLIDES, seed=1):
        res = miner.advance(batch)
        full = mine(miner.window_transactions(), sspec.n_items,
                    EclatConfig(min_sup=0.01, variant="v4", backend="jnp"))
        stream_ok &= res.support_map() == full.support_map()
        slides += 1
    report["parity"]["streaming"] = {
        "engine": miner.engine.name,
        "slides": slides,
        "ring_spec": str(miner.ring.device.sharding.spec),
        "ring_bytes_per_device":
            int(miner.ring.device.addressable_shards[0].data.nbytes),
        "ring_bytes_total": int(miner.ring.device.nbytes),
        "identical": bool(stream_ok),
    }
    report["parity_ok"] &= bool(stream_ok)

    # ---- (b) per-device frontier bytes + pair work: pairs vs words vs grid
    # The same level-2 expansion, three mesh mappings.  Frontier bytes are
    # measured on the placement each backend's shard_map in_spec commits
    # (replicated for pairs; P(None, "data") for words/grid); pair work is
    # the per-device pair count the engine actually grouped/replicated.
    abs_ms = resolve_min_sup(ms, len(txns))
    db = build_vertical(txns, spec.n_items, abs_ms, order="support_asc")
    n1 = db.n_items
    iu, ju = np.triu_indices(n1, k=1)
    q = min(int(iu.shape[0]), 4096)
    iu, ju = iu[:q].astype(np.int32), ju[:q].astype(np.int32)
    sup1 = db.supports.astype(np.int32)
    bitmaps = jnp.asarray(db.bitmaps)
    checksums = set()

    def _entry(label, engine, frontier_per_dev, pairs_per_dev, res):
        checksums.add(int(np.asarray(res.supports).sum()))
        return {
            "engine": engine.name,
            "db_rows": int(n1),
            "n_pairs": int(q),
            "frontier_bytes_total": int(bitmaps.nbytes),
            "frontier_bytes_per_device": int(frontier_per_dev),
            "pairs_per_device": int(pairs_per_dev),
            "survivors": int(res.supports.shape[0]),
            "supports_checksum": int(np.asarray(res.supports).sum()),
        }

    # pairs: 4-way pair split, frontier replicated on every device
    ep = eng.make_engine("sharded", mesh=mesh4, inner="jnp")
    resp = ep.expand(bitmaps, iu, ju, sup1[iu], mode=eng.MODE_TIDSET,
                     min_sup=abs_ms, device_of_pair=iu.astype(np.int64) % 4)
    repl = jax.device_put(bitmaps, NamedSharding(mesh4, P()))
    report["placement"]["pairs"] = _entry(
        "pairs", ep, repl.addressable_shards[0].data.nbytes,
        int(np.max(ep.device_pair_counts[-1])), resp)

    # words: 4-way word split, every shard executes all pairs
    ew = eng.make_engine("tidsharded", mesh=mesh4, inner="jnp")
    fw = ew.prepare_frontier(bitmaps)
    resw = ew.expand(bitmaps, iu, ju, sup1[iu], mode=eng.MODE_TIDSET,
                     min_sup=abs_ms)
    report["placement"]["words"] = _entry(
        "words", ew, fw.addressable_shards[0].data.nbytes, q, resw)

    # grid 2x2: pairs split n_class ways AND words split n_data ways
    eg = eng.make_engine("grid", mesh=grid_mesh, inner="jnp")
    fg = eg.prepare_frontier(bitmaps)
    resg = eg.expand(bitmaps, iu, ju, sup1[iu], mode=eng.MODE_TIDSET,
                     min_sup=abs_ms,
                     device_of_pair=iu.astype(np.int64) % n_class)
    report["placement"]["grid"] = _entry(
        "grid", eg, fg.addressable_shards[0].data.nbytes,
        int(np.max(eg.device_pair_counts[-1])), resg)

    report["placement_supports_identical"] = len(checksums) == 1
    p_ = report["placement"]
    report["frontier_reduction_vs_pairs"] = (
        p_["pairs"]["frontier_bytes_per_device"]
        / p_["grid"]["frontier_bytes_per_device"])
    report["pairwork_reduction_vs_words"] = (
        p_["words"]["pairs_per_device"] / p_["grid"]["pairs_per_device"])
    print(json.dumps(report))


# ---------------------------------------------------------------------------
# parent harness entry
# ---------------------------------------------------------------------------

def gridscale_bench(out: List[str], smoke: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"gridscale child failed:\n{proc.stderr[-2000:]}")
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    # parity is the acceptance-critical claim — a regression must fail the
    # harness (and CI), not just flip a flag inside the JSON artifact
    if not report["parity_ok"]:
        bad = [k for k, v in report["parity"].items() if not v["identical"]]
        raise RuntimeError(f"gridscale parity regression: {bad} not "
                           f"bit-identical (see {BENCH_PATH})")
    if not report["placement_supports_identical"]:
        raise RuntimeError("gridscale placement supports diverged across "
                           f"pairs/words/grid (see {BENCH_PATH})")
    for variant in VARIANTS:
        p = report["parity"][variant]
        out.append(_row(f"gridscale/parity/{variant}",
                        p["wall_s"]["grid"],
                        f"itemsets={p['itemsets']};identical={p['identical']}"))
    s = report["parity"]["streaming"]
    out.append(_row("gridscale/parity/streaming", 0.0,
                    f"slides={s['slides']};identical={s['identical']};"
                    f"ring_per_dev={s['ring_bytes_per_device']}"))
    for mode in ("pairs", "words", "grid"):
        m = report["placement"][mode]
        out.append(_row(f"gridscale/placement/{mode}", 0.0,
                        f"frontier_per_dev={m['frontier_bytes_per_device']};"
                        f"pairs_per_dev={m['pairs_per_device']};"
                        f"checksum={m['supports_checksum']}"))
    out.append(_row("gridscale/reduction", 0.0,
                    f"frontier_vs_pairs=x"
                    f"{report['frontier_reduction_vs_pairs']:.2f};"
                    f"pairwork_vs_words=x"
                    f"{report['pairwork_reduction_vs_words']:.2f};"
                    f"json={os.path.basename(BENCH_PATH)}"))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still writes BENCH_gridscale.json)")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        sys.path.insert(0, os.path.join(ROOT, "src"))
        _child(smoke=args.smoke)
    else:
        rows: List[str] = ["name,us_per_call,derived"]
        gridscale_bench(rows, smoke=args.smoke)
        print("\n".join(rows))
