"""FIM benchmarks reproducing the paper's tables/figures.

Figs 8-14 : execution time of EclatV1..V5 (+V6) vs Spark-Apriori across
            min_sup sweeps on the seven Table-2 datasets -> fim_minsup.
Fig 15    : execution time vs executor cores               -> fim_cores
            (subprocess per core count; --xla_force_host_platform_device_count).
Fig 16    : execution time vs dataset size (T10I4 doubling) -> fim_scale.
(ext.)    : partitioner balance (padding efficiency)        -> partitioner_balance.

Datasets are generated at a CPU-budget scale by default (same statistical
shape as Table 2, see repro.data.synthetic); BENCH_SCALE / BENCH_FULL env
vars raise it.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List

import numpy as np

from repro.core import EclatConfig, apriori_mine, mine
from repro.data import PAPER_DATASETS, generate

SCALE = float(os.environ.get("BENCH_SCALE", "0.08"))
FULL = os.environ.get("BENCH_FULL", "") == "1"

# paper-benchmarked variants; v6 is the beyond-paper greedy/LPT variant
VARIANTS = ["v1", "v2", "v3", "v4", "v5", "v6"]
DEFAULT_DATASETS = list(PAPER_DATASETS) if FULL else [
    "chess", "mushroom", "T10I4D100K", "BMS_WebView_1"]


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


def fim_minsup(out: List[str], datasets=None, n_minsups=None) -> None:
    datasets = datasets or DEFAULT_DATASETS
    for ds in datasets:
        txns, spec = generate(ds, scale=SCALE if spec_scale(ds) else 1.0, seed=1)
        sups = spec.min_sups if FULL else spec.min_sups[:: 2]
        if n_minsups:
            sups = sups[:n_minsups]
        # warm jit paths once (compile time is not part of the paper's claim)
        mine(txns, spec.n_items,
             EclatConfig(min_sup=sups[0], variant="v3", p=10,
                         tri_matrix=spec.tri_matrix or None))
        apriori_mine(txns, spec.n_items, sups[0])
        for ms in sups:
            for variant in (VARIANTS if FULL else ["v1", "v3", "v5", "v6"]):
                cfg = EclatConfig(min_sup=ms, variant=variant, p=10,
                                  tri_matrix=spec.tri_matrix or None)
                t0 = time.perf_counter()
                res = mine(txns, spec.n_items, cfg)
                dt = time.perf_counter() - t0
                out.append(_row(f"fim_minsup/{ds}/ms{ms}/{variant}", dt,
                                f"itemsets={res.total}"))
            t0 = time.perf_counter()
            ap = apriori_mine(txns, spec.n_items, ms)
            dt = time.perf_counter() - t0
            out.append(_row(f"fim_minsup/{ds}/ms{ms}/apriori", dt,
                            f"itemsets={ap.total}"))


def spec_scale(ds: str) -> bool:
    return PAPER_DATASETS[ds].n_txn > 4000


def fim_scale(out: List[str]) -> None:
    """Fig 16: dataset doubling at fixed min_sup (paper: T10I4, 0.05)."""
    scales = [SCALE, 2 * SCALE, 4 * SCALE, 8 * SCALE]
    for sc in scales:
        txns, spec = generate("T10I4D100K", scale=sc, seed=1)
        cfg = EclatConfig(min_sup=0.05, variant="v4", p=10)
        t0 = time.perf_counter()
        res = mine(txns, spec.n_items, cfg)
        dt = time.perf_counter() - t0
        out.append(_row(f"fim_scale/T10I4D100K/x{sc/SCALE:.0f}", dt,
                        f"n_txn={len(txns)};itemsets={res.total}"))


_CORES_SNIPPET = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
from repro.core import EclatConfig, mine
from repro.data import generate
from repro.dist.compat import make_mesh
txns, spec = generate("T10I4D100K", scale=%f, seed=1)
mesh = make_mesh((%d,), ("data",))
cfg = EclatConfig(min_sup=0.02, variant="%s", p=10, backend="sharded")
t0 = time.perf_counter()
res = mine(txns, spec.n_items, cfg, mesh=mesh)
print(json.dumps({"s": time.perf_counter() - t0, "total": res.total,
                  "eff": res.stats.get("device_balance", {}).get("padding_efficiency")}))
"""


def fim_cores(out: List[str]) -> None:
    """Fig 15: scaling with executor cores (device count via subprocess)."""
    env = dict(os.environ, PYTHONPATH="src")
    for cores in ([2, 4, 6, 8, 10] if FULL else [2, 4, 8]):
        for variant in ["v4", "v5"]:
            proc = subprocess.run(
                [sys.executable, "-c", _CORES_SNIPPET % (cores, SCALE, cores, variant)],
                capture_output=True, text=True, env=env, cwd=os.getcwd())
            if proc.returncode != 0:
                out.append(_row(f"fim_cores/{cores}/{variant}", 0.0,
                                f"ERROR={proc.stderr.strip()[-80:]}"))
                continue
            data = json.loads(proc.stdout.strip().splitlines()[-1])
            out.append(_row(f"fim_cores/{cores}/{variant}", data["s"],
                            f"itemsets={data['total']};pad_eff={data['eff']:.3f}"))


def partitioner_balance(out: List[str]) -> None:
    """Extension table: per-partitioner padding efficiency per dataset."""
    from repro.core import assign_partitions, build_vertical, partition_stats
    from repro.core.equivalence import pair_work
    for ds in DEFAULT_DATASETS:
        txns, spec = generate(ds, scale=SCALE if spec_scale(ds) else 1.0, seed=1)
        ms = spec.min_sups[len(spec.min_sups) // 2]
        db = build_vertical(txns, spec.n_items, max(2, int(ms * len(txns))))
        n = db.n_items
        if n < 3:
            continue
        sizes = (n - 1 - np.arange(n - 1)).clip(min=0)
        work = pair_work(sizes + 1, db.n_words)
        t0 = time.perf_counter()
        for name in ("default", "hash", "reverse_hash", "greedy"):
            a = assign_partitions(n - 1, name, 10, work=work)
            eff = partition_stats(a, work, 10)["padding_efficiency"]
            out.append(_row(f"partitioner_balance/{ds}/{name}",
                            time.perf_counter() - t0, f"pad_eff={eff:.3f}"))
