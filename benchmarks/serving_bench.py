"""Serving-path latency: query storms against the async admission front end.

    python benchmarks/serving_bench.py [--smoke]   # or benchmarks/run.py

The unified serving path (DESIGN.md §11) is only worth its queue if it is
both *fast* (batch + version-keyed cache amortization) and *right*
(bit-identical with synchronous answering).  This bench fires a seeded
heterogeneous query storm from concurrent clients at :class:`ServingFrontend`
while a writer thread slides windows underneath, then:

  storm     p50/p99 enqueue->answer latency, QPS, cache hit rate, batch
            sizes — measured under live invalidation (every slide bumps
            ``window_version`` and evicts the cache);
  verify    every served answer replayed synchronously (no batching, no
            cache) against the retained snapshot of its stamped version —
            any checksum divergence raises, it is not a data point;
  direct    the same query mix answered one-by-one with the cache off, for
            the amortization ratio (served answer ms vs direct ms).

Writes ``BENCH_serving.json`` for the cross-PR trajectory.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import List

if __name__ == "__main__":      # standalone run: make `repro` importable
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import stream_spec, transaction_stream
from repro.serving import (AdmissionConfig, ServingFrontend, answer_query,
                           query_mix, run_storm, verify_storm)
from repro.serving.metrics import percentiles
from repro.streaming import StreamConfig, StreamingMiner

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")
DATASET = "T10I4D100K"


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


def _measure(n_slides: int, n_queries: int, n_clients: int, block_txns: int,
             n_blocks: int, min_sup: float, backend: str) -> dict:
    spec = stream_spec(DATASET)
    cfg = StreamConfig(min_sup=min_sup, n_blocks=n_blocks,
                       block_txns=block_txns, backend=backend)
    miner = StreamingMiner(spec.n_items, cfg, keep_transactions=False)
    acfg = AdmissionConfig(keep_versions=n_slides + 2)
    frontend = ServingFrontend(miner, acfg)
    batches = list(transaction_stream(DATASET, block_txns, n_slides, seed=1))
    frontend.ingest(batches[0])          # storm starts on a non-empty window

    def slide():
        for b in batches[1:]:
            frontend.ingest(b)
            time.sleep(0.002)
    writer = threading.Thread(target=slide, daemon=True)

    queries = query_mix(n_queries, seed=0)
    writer.start()
    t0 = time.perf_counter()
    outcome = run_storm(frontend, queries, n_clients=n_clients)
    storm_s = time.perf_counter() - t0
    writer.join()
    if outcome["errors"]:
        raise RuntimeError(f"storm errors: {outcome['errors']}")

    # the bit-identity gate: replay every answer synchronously at its
    # stamped window_version; divergence raises inside verify_storm
    ver = verify_storm(frontend, queries, outcome)

    # direct baseline: same mix, one-by-one, cache off, final window
    snap = frontend.snapshot
    t_direct: List[float] = []
    for q in queries:
        t0 = time.perf_counter()
        answer_query(snap, q, cache=None)
        t_direct.append(time.perf_counter() - t0)
    direct = percentiles(t_direct)
    direct_mean_ms = sum(t_direct) / len(t_direct) * 1e3

    m = frontend.metrics.summary()
    c = frontend.cache.stats()
    frontend.stop()
    served_ms = m["answer_ms"]["p50"]
    return {
        "block_txns": block_txns, "n_blocks": n_blocks,
        "window_txns": frontend.snapshot.n_txn,
        "itemsets": len(frontend.snapshot.itemsets),
        "slides": n_slides, "final_version": frontend.window_version,
        "n_queries": n_queries, "n_clients": n_clients,
        "storm_s": round(storm_s, 4),
        "answered": m["n_answered"], "shed": m["n_shed"],
        "errors": m["n_errors"],
        "p50_ms": m["latency_ms"]["p50"], "p99_ms": m["latency_ms"]["p99"],
        "answer_p50_ms": served_ms,
        "qps": m["qps"], "mean_batch": m["mean_batch"],
        "cache_hit_rate": c["hit_rate"], "stale_evicted": c["stale_evicted"],
        "direct_p50_ms": direct["p50"], "direct_p99_ms": direct["p99"],
        "direct_mean_ms": round(direct_mean_ms, 4),
        "amortization": (round(direct["p50"] / served_ms, 2)
                         if served_ms > 0 else 0.0),
        "verified": ver["verified"], "unverifiable": len(ver["unverifiable"]),
        "checksum": ver["checksum"], "identical": ver["identical"],
    }


def serving_bench(out: List[str], smoke: bool = False) -> dict:
    import jax

    min_sup = 0.01
    scenarios = ([(4, 80, 4, 128, 4)] if smoke
                 else [(6, 300, 4, 256, 4), (8, 500, 8, 256, 8)])
    report: dict = {
        "dataset": DATASET, "min_sup": min_sup, "smoke": bool(smoke),
        "backend": "pallas", "jax_backend": jax.default_backend(),
        "storms": [],
    }
    for n_slides, n_queries, n_clients, block_txns, n_blocks in scenarios:
        entry = _measure(n_slides, n_queries, n_clients, block_txns,
                         n_blocks, min_sup, backend="pallas")
        report["storms"].append(entry)
        out.append(_row(
            f"serving/q{n_queries}c{n_clients}s{n_slides}",
            entry["p50_ms"] / 1e3,
            f"p99_ms={entry['p99_ms']:.2f};qps={entry['qps']:.0f};"
            f"hit_rate={entry['cache_hit_rate']:.3f};"
            f"verified={entry['verified']}/{entry['answered']}"))
    report["all_identical"] = all(s["identical"] for s in report["storms"])
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    out.append(_row("serving/identical", 0.0,
                    f"{report['all_identical']};"
                    f"json={os.path.basename(BENCH_PATH)}"))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized storm (still writes BENCH_serving.json)")
    args = ap.parse_args()
    rows: List[str] = ["name,us_per_call,derived"]
    serving_bench(rows, smoke=args.smoke)
    print("\n".join(rows))
