"""The paper's headline: RDD-Eclat vs Spark-Apriori, across scale and mesh.

    python benchmarks/headline_bench.py [--smoke]    # or benchmarks/run.py

Reproduces the comparison protocol of the source paper (arXiv:1912.06415)
and its companion Apriori study (arXiv:1908.01338): the same datasets, the
same min_sup, Apriori vs every Eclat variant v1–v6, varied over dataset
scale (>= 2 sizes) and over mesh size (1 device vs a forced 4-device host
mesh — the executor-core axis of Fig 15).  Every cell's full
(itemset, support) map is checksummed; ``apriori_mine`` is the
differential oracle, so ANY divergence between it and any engine backend
fails the bench (and CI), not just a wall-clock regression.

Runs in a subprocess because the forced XLA host-device count is
process-global.  Writes ``BENCH_headline.json``; ``analysis/report.py``
renders it as the EXPERIMENTS.md "Headline" table.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(ROOT, "BENCH_headline.json")
DATASET = "T10I4D100K"
VARIANTS = ["v1", "v2", "v3", "v4", "v5", "v6"]
MESH_SIZES = (1, 4)          # 1 device vs the forced 4-device host mesh


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


def support_checksum(support_map: dict) -> str:
    """Stable digest of a full (itemset, support) map — identical mining
    output <=> identical checksum, independent of dict/iteration order."""
    lines = sorted(f"{','.join(map(str, k))}:{int(v)}"
                   for k, v in support_map.items())
    return hashlib.sha1("\n".join(lines).encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# child: runs under --xla_force_host_platform_device_count=4
# ---------------------------------------------------------------------------

def _child(smoke: bool) -> None:
    import time

    import jax

    from repro.core import EclatConfig, apriori_mine, mine
    from repro.data import generate
    from repro.dist.compat import make_mesh

    if len(jax.devices()) < max(MESH_SIZES):
        raise SystemExit("child needs 4 forced host devices (XLA_FLAGS)")

    scales = ((0.01, 0.02) if smoke
              else tuple(float(s) for s in os.environ.get(
                  "BENCH_HEADLINE_SCALES", "0.04,0.08").split(",")))
    spec0 = None
    report: dict = {
        "dataset": DATASET, "smoke": bool(smoke),
        "jax_backend": jax.default_backend(),
        "variants": VARIANTS, "mesh_sizes": list(MESH_SIZES),
        "scales": [], "checksums_identical": True,
    }

    def timed(fn):
        fn()                                   # warm jit/bucket caches
        t0 = time.perf_counter()
        out = fn()
        return out, time.perf_counter() - t0

    speedups: List[float] = []
    for scale in scales:
        txns, spec = generate(DATASET, scale=scale, seed=1)
        spec0 = spec
        ms = spec.min_sups[len(spec.min_sups) // 2]

        ap, ap_wall = timed(lambda: apriori_mine(txns, spec.n_items, ms))
        ap_sum = support_checksum(ap.support_map)
        entry = {
            "scale": scale, "n_txn": len(txns), "min_sup": float(ms),
            "apriori": {"wall_s": round(ap_wall, 4),
                        "itemsets": ap.total, "levels": ap.counts,
                        "checksum": ap_sum},
            "eclat": {},
        }

        best = None
        for n_dev in MESH_SIZES:
            if n_dev == 1:
                mesh, kw = None, dict(backend="pallas")
            else:
                mesh = make_mesh((n_dev,), ("data",),
                                 devices=jax.devices()[:n_dev])
                kw = dict(backend="tidsharded", shard="words")
            cell: dict = {}
            for variant in VARIANTS:
                cfg = EclatConfig(min_sup=ms, variant=variant, p=10,
                                  use_diffsets=(variant == "v6"), **kw)
                res, wall = timed(lambda: mine(txns, spec.n_items, cfg,
                                               mesh=mesh))
                ck = support_checksum(res.support_map())
                identical = ck == ap_sum
                report["checksums_identical"] &= identical
                sp = ap_wall / wall if wall > 0 else 0.0
                cell[variant] = {"wall_s": round(wall, 4), "checksum": ck,
                                 "identical": identical,
                                 "itemsets": res.total,
                                 "speedup_vs_apriori": round(sp, 3)}
                speedups.append(sp)
                if best is None or sp > best["speedup"]:
                    best = {"variant": variant, "mesh": n_dev,
                            "speedup": round(sp, 3)}
            entry["eclat"][str(n_dev)] = cell
        entry["best"] = best
        report["scales"].append(entry)

    report["min_sup"] = report["scales"][0]["min_sup"]
    report["n_items"] = spec0.n_items
    report["speedup_min"] = round(min(speedups), 3)
    report["speedup_max"] = round(max(speedups), 3)
    print(json.dumps(report))


# ---------------------------------------------------------------------------
# parent harness entry
# ---------------------------------------------------------------------------

def headline_bench(out: List[str], smoke: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"headline child failed:\n{proc.stderr[-2000:]}")
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    # the differential-oracle contract is the acceptance-critical claim: a
    # checksum divergence between Apriori and ANY engine cell fails the
    # harness (and CI), not just a flag inside the JSON artifact
    if not report["checksums_identical"]:
        bad = [f"x{s['scale']}/{mesh}dev/{v}"
               for s in report["scales"]
               for mesh, cell in s["eclat"].items()
               for v, c in cell.items() if not c["identical"]]
        raise RuntimeError(f"headline checksum divergence vs Apriori: {bad} "
                           f"(see {BENCH_PATH})")
    for s in report["scales"]:
        out.append(_row(f"headline/x{s['scale']}/apriori",
                        s["apriori"]["wall_s"],
                        f"itemsets={s['apriori']['itemsets']};"
                        f"checksum={s['apriori']['checksum']}"))
        for mesh, cell in sorted(s["eclat"].items()):
            for v, c in cell.items():
                out.append(_row(f"headline/x{s['scale']}/{mesh}dev/{v}",
                                c["wall_s"],
                                f"speedup={c['speedup_vs_apriori']};"
                                f"identical={c['identical']}"))
    out.append(_row("headline/summary", 0.0,
                    f"speedup_min=x{report['speedup_min']};"
                    f"speedup_max=x{report['speedup_max']};"
                    f"json={os.path.basename(BENCH_PATH)}"))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still writes BENCH_headline.json)")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        sys.path.insert(0, os.path.join(ROOT, "src"))
        _child(smoke=args.smoke)
    else:
        rows: List[str] = ["name,us_per_call,derived"]
        headline_bench(rows, smoke=args.smoke)
        print("\n".join(rows))
