"""Engine backend benchmark — the perf trajectory for the mining hot loop.

Times the jnp reference backend against the fused pallas backend (and the
sharded backend when run under a mesh-capable subprocess is not needed —
single-process here) on the synthetic T10-style dataset, then writes
``BENCH_engine.json`` so future PRs have per-backend wall time,
intersections/sec, and padding efficiency to compare against.

Two measurements per backend:
  mine   end-to-end ``mine()`` wall time (jit warmed by a first run)
  micro  steady-state ``engine.expand`` throughput on a fixed (Q, W) batch
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import EclatConfig, mine
from repro.core import engine as eng
from repro.data import generate

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
BACKENDS = ("jnp", "pallas")


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


def _micro_pairs_per_s(backend: str, q: int = 4096, w: int = 128,
                       reps: int = 5, **engine_kw) -> float:
    """Steady-state expand() throughput.  Timing hygiene: the first call
    (trace+compile) runs outside the timed region, and every timed rep is
    blocked to completion — engine.expand already syncs on the host mask
    read, but the survivor block is the last async value, so block on it
    per rep rather than once at the end."""
    rng = np.random.default_rng(0)
    bitmaps = jnp.asarray(rng.integers(0, 2**32, (512, w), dtype=np.uint32))
    left = rng.integers(0, 512, q).astype(np.int32)
    right = rng.integers(0, 512, q).astype(np.int32)
    supl = np.zeros(q, np.int32)
    e = eng.make_engine(backend, **engine_kw)

    def call():
        res = e.expand(bitmaps, left, right, supl, mode=eng.MODE_TIDSET,
                       min_sup=w * 8)
        jax.block_until_ready(res.bitmaps)

    call()  # trace + compile, not timed
    call()  # steady-state warm-up
    t0 = time.perf_counter()
    for _ in range(reps):
        call()
    return q * reps / (time.perf_counter() - t0)


def engine_bench(out: List[str], smoke: bool = False) -> dict:
    scale = 0.02 if smoke else float(os.environ.get("BENCH_SCALE", "0.08"))
    txns, spec = generate("T10I4D100K", scale=scale, seed=1)
    ms = spec.min_sups[len(spec.min_sups) // 2]
    report: dict = {
        "dataset": "T10I4D100K", "scale": scale, "n_txn": len(txns),
        "n_items": spec.n_items, "min_sup": float(ms), "smoke": bool(smoke),
        "jax_backend": jax.default_backend(), "backends": {},
    }
    on_tpu = jax.default_backend() == "tpu"
    for backend in BACKENDS:
        cfg = EclatConfig(min_sup=ms, variant="v4", p=10, backend=backend)
        t0 = time.perf_counter()
        mine(txns, spec.n_items, cfg)  # warm the jit/bucket caches
        cold_wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = mine(txns, spec.n_items, cfg)
        wall = time.perf_counter() - t0
        n_int = res.stats["n_intersections"]
        n_pad = res.stats["n_padded"]
        micro = _micro_pairs_per_s(backend)
        # off-TPU the pallas backend dispatches to the fused jnp ref, so the
        # jnp-vs-pallas delta there measures the fused call pattern (fewer
        # host transfers), not the Mosaic kernel — record which path ran
        entry = {
            "executed_path": ("pallas-kernel" if on_tpu else "fused-xla-ref")
            if backend == "pallas" else "xla-ref",
            "mine_wall_s": wall,
            "mine_cold_wall_s": cold_wall,   # trace+compile-inclusive first run
            "itemsets": res.total,
            "n_intersections": n_int,
            "intersections_per_s": n_int / wall if wall > 0 else 0.0,
            "padding_efficiency": n_int / (n_int + n_pad) if n_int + n_pad else 1.0,
            "pair_padding": res.stats.get("pair_padding"),
            "micro_pairs_per_s": micro,
        }
        report["backends"][backend] = entry
        out.append(_row(f"engine/{backend}/mine", wall,
                        f"itemsets={res.total};ips={entry['intersections_per_s']:.0f};"
                        f"pad_eff={entry['padding_efficiency']:.3f}"))
        out.append(_row(f"engine/{backend}/micro", 1.0 / micro,
                        f"pairs_per_s={micro:.0f}"))
    jw = report["backends"]["jnp"]["mine_wall_s"]
    pw = report["backends"]["pallas"]["mine_wall_s"]
    report["fused_speedup_vs_jnp"] = jw / pw if pw > 0 else 0.0
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    out.append(_row("engine/fused_speedup", 0.0,
                    f"x{report['fused_speedup_vs_jnp']:.2f};json={os.path.basename(BENCH_PATH)}"))
    return report
