"""Kernel-tune benchmark: autotune sweep + measured backend-crossover table.

Three artifacts in one ``BENCH_kerneltune.json``:

``shapes``
    The autotune sweep — per (Q, W, mode) shape class, every candidate tile
    width's steady-state seconds (compile excluded), the tuned winner, and
    whether the roofline cost model's prediction agreed.  Off-TPU the fused
    path is the XLA ref with no tile knob, so the sweep collapses to one
    honest candidate per shape (see ``kernels.autotune``); winners persist
    in the autotune cache so subsequent runs start tuned.

``tuned_vs_default``
    mine() end-to-end with the tuned configuration vs the hard-coded
    ``block_w=512`` default on the largest bench shape — the accept gate
    for this PR's raw-speed pass.  The itemset checksum of the two runs
    MUST be bit-identical; a divergence raises (and fails CI): a tuner
    that changes answers is a bug, not a speedup.

``crossover``
    The measured dispatch table behind ``resolve_engine("auto")`` /
    DESIGN.md §6: steady-state expand() throughput of the jnp and pallas
    backends per (Q, W) cell — plus the mesh backends (sharded /
    tidsharded / grid) measured in a 4-device subprocess — and the winner
    of each cell.  ``core.engine.DispatchPolicy`` loads exactly this list.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import EclatConfig, mine
from repro.core import engine as eng
from repro.data import generate
from repro.kernels import autotune

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kerneltune.json")

# log-spaced (Q, W) grid: small/medium/large pair batches x narrow/wide rows
SWEEP_SHAPES = [(1024, 32), (1024, 512), (8192, 128), (8192, 2048),
                (32768, 512)]
SWEEP_SHAPES_SMOKE = [(512, 32), (2048, 128)]
CROSSOVER_CELLS = [(256, 32), (1024, 128), (4096, 512), (16384, 128),
                   (16384, 1024)]
CROSSOVER_CELLS_SMOKE = [(256, 32), (2048, 128)]


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


def itemset_checksum(res) -> str:
    """Order-independent digest of (itemset, support) pairs — the
    bit-identical-answers gate for tuned-vs-default runs."""
    h = hashlib.sha256()
    for items, sup in sorted(res.store.support_map().items()):
        h.update(repr((items, int(sup))).encode())
    return h.hexdigest()[:16]


def _steady_expand_s(e, q: int, w: int, reps: int = 3) -> float:
    """Steady-state seconds per expand() on a synthetic (q, w) batch:
    compile excluded, every rep blocked to completion."""
    rng = np.random.default_rng(0)
    p = min(max(q, 2), 1024)
    bitmaps = e.prepare_frontier(
        jnp.asarray(rng.integers(0, 2 ** 32, (p, w), dtype=np.uint32)))
    left = rng.integers(0, p, q).astype(np.int32)
    right = rng.integers(0, p, q).astype(np.int32)
    supl = np.full(q, w * 32, np.int32)
    dev = (np.arange(q) % e.n_devices) if e.n_devices > 1 else None

    def call():
        res = e.expand(bitmaps, left, right, supl, mode=eng.MODE_TIDSET,
                       min_sup=w * 16, device_of_pair=dev)
        jax.block_until_ready(res.bitmaps)

    call()  # trace + compile, not timed
    call()  # steady-state warm-up
    t0 = time.perf_counter()
    for _ in range(reps):
        call()
    return (time.perf_counter() - t0) / reps


_MESH_PROBE = r"""
import json, sys
import numpy as np, jax
from jax.sharding import Mesh
sys.path.insert(0, {src!r})
from repro.core import engine as eng
from benchmarks.kerneltune_bench import _steady_expand_s
cells = json.loads(sys.argv[1])
devs = np.array(jax.devices())
mesh = Mesh(devs.reshape(-1), ("data",))
grid = Mesh(devs.reshape(2, -1), ("class", "data"))
out = []
for q, w in cells:
    row = {{"q": q, "w": w}}
    for name, e in (
        ("sharded", eng.make_engine("sharded", mesh=mesh, inner="jnp")),
        ("tidsharded", eng.make_engine("tidsharded", mesh=mesh, inner="jnp")),
        ("grid", eng.make_engine("grid", mesh=grid, inner="jnp")),
    ):
        row[name] = _steady_expand_s(e, q, w)
    out.append(row)
print(json.dumps(out))
"""


def _mesh_crossover(cells, n_devices: int = 4) -> Optional[dict]:
    """Measure the mesh backends per cell in a forced-multi-device
    subprocess (the parent process has already initialized jax with one
    device).  Returns {(q, w): {backend: steady_s}} or None if the probe
    fails — the crossover table then records single-device winners only."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"), env.get("PYTHONPATH", "")])
    script = _MESH_PROBE.format(src=os.path.join(root, "src"))
    try:
        proc = subprocess.run(
            [sys.executable, "-c", script, json.dumps(list(cells))],
            capture_output=True, text=True, timeout=1800, env=env, cwd=root)
        if proc.returncode != 0:
            return None
        rows = json.loads(proc.stdout.strip().splitlines()[-1])
    except (OSError, ValueError, subprocess.SubprocessError):
        return None
    return {(r["q"], r["w"]): {k: v for k, v in r.items()
                               if k not in ("q", "w")} for r in rows}


def kerneltune_bench(out: List[str], smoke: bool = False) -> dict:
    report: dict = {
        "smoke": bool(smoke),
        "jax_backend": jax.default_backend(),
        "autotune_cache": autotune.table_path(),
        "shapes": [], "crossover": [],
    }

    # ---- 1. autotune sweep ------------------------------------------------
    shapes = SWEEP_SHAPES_SMOKE if smoke else SWEEP_SHAPES
    reps = 2 if smoke else 5
    for q, w in shapes:
        rec = autotune.tune_shape(q, w, mode=eng.MODE_TIDSET, reps=reps)
        report["shapes"].append(rec)
        out.append(_row(f"kerneltune/sweep/q{q}_w{w}", rec["steady_s"],
                        f"block_w={rec['tuned_block_w']};"
                        f"model_agrees={rec['model_agrees']};"
                        f"candidates={len(rec['candidates'])}"))

    # ---- 2. tuned vs default on the largest bench shape -------------------
    scale = 0.02 if smoke else float(os.environ.get("BENCH_SCALE", "0.08"))
    txns, spec = generate("T10I4D100K", scale=scale, seed=1)
    ms = spec.min_sups[len(spec.min_sups) // 2]
    # "default" reproduces the pre-tuning configuration exactly: hard-coded
    # block_w=512 and the legacy two-dispatch compaction; "tuned" is the
    # autotuned tile width with the fused survivor-compaction epilogue
    arms = {
        "default": EclatConfig(min_sup=ms, variant="v4", backend="pallas",
                               block_w=autotune.DEFAULT_BLOCK_W,
                               autotune=False, compact=False),
        "tuned": EclatConfig(min_sup=ms, variant="v4", backend="pallas",
                             block_w=None, autotune=True, compact=True),
    }
    walls, sums = {}, {}
    for label, cfg in arms.items():   # warm trace/compile caches (and, for
        # the tuned arm, run any tune-on-miss sweeps outside the clock)
        sums[label] = itemset_checksum(mine(txns, spec.n_items, cfg))
        walls[label] = float("inf")
    for _ in range(1 if smoke else 5):
        # interleave the arms so load drift on a shared host hits both;
        # min-of-N per arm is then robust to both drift and timer noise
        for label, cfg in arms.items():
            t0 = time.perf_counter()
            mine(txns, spec.n_items, cfg)
            walls[label] = min(walls[label], time.perf_counter() - t0)
    if sums["default"] != sums["tuned"]:
        raise RuntimeError(
            f"tuned-vs-default itemset checksum divergence: "
            f"default={sums['default']} tuned={sums['tuned']} — the tuner "
            f"changed the mined answer, refusing to publish a dispatch table")
    report["tuned_vs_default"] = {
        "dataset": "T10I4D100K", "scale": scale, "n_txn": len(txns),
        "default_wall_s": walls["default"], "tuned_wall_s": walls["tuned"],
        "speedup": (walls["default"] / walls["tuned"]
                    if walls["tuned"] > 0 else 0.0),
        "itemset_checksum": sums["tuned"], "checksums_match": True,
    }
    out.append(_row("kerneltune/tuned_vs_default", walls["tuned"],
                    f"x{report['tuned_vs_default']['speedup']:.2f};"
                    f"checksum={sums['tuned']}"))

    # ---- 3. backend crossover sweep ---------------------------------------
    cells = CROSSOVER_CELLS_SMOKE if smoke else CROSSOVER_CELLS
    mesh_rows = None if smoke else _mesh_crossover(cells)
    if not smoke and mesh_rows is None:
        out.append(_row("kerneltune/mesh_probe_failed", 0.0,
                        "crossover=single-device-only"))
    mesh_backend_of = {"sharded": "sharded", "tidsharded": "tidsharded",
                       "grid": "grid"}
    for q, w in cells:
        cell = {"q": q, "w": w, "steady_s": {}}
        for backend in ("jnp", "pallas"):
            e = eng.make_engine(backend)
            cell["steady_s"][backend] = _steady_expand_s(e, q, w)
        if mesh_rows and (q, w) in mesh_rows:
            cell["steady_s"].update(mesh_rows[(q, w)])
        singles = {b: s for b, s in cell["steady_s"].items()
                   if b in ("jnp", "pallas")}
        meshes = {b: s for b, s in cell["steady_s"].items()
                  if b in mesh_backend_of}
        cell["best_single"] = min(singles, key=singles.get)
        cell["best_mesh"] = (min(meshes, key=meshes.get) if meshes else None)
        cell["speedup_fused_vs_jnp"] = (
            cell["steady_s"]["jnp"] / cell["steady_s"]["pallas"]
            if cell["steady_s"]["pallas"] > 0 else 0.0)
        report["crossover"].append(cell)
        out.append(_row(f"kerneltune/crossover/q{q}_w{w}",
                        cell["steady_s"][cell["best_single"]],
                        f"best={cell['best_single']};"
                        f"best_mesh={cell['best_mesh']};"
                        f"fused_vs_jnp=x{cell['speedup_fused_vs_jnp']:.2f}"))

    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    out.append(_row("kerneltune/json", 0.0,
                    f"json={os.path.basename(BENCH_PATH)};"
                    f"cells={len(report['crossover'])}"))
    return report
