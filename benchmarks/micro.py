"""Micro-benchmarks: kernel inner loops + MoE placement balance."""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp


def _time(fn, *args, reps=5) -> float:
    """Steady-state seconds per call: the first (trace+compile) call is
    excluded from the timed region, and every timed rep is blocked to
    completion — without the per-rep block, async dispatch lets reps queue
    and the 'average' mostly measures dispatch, not the kernel."""
    jax.block_until_ready(fn(*args))  # trace + compile, not timed
    jax.block_until_ready(fn(*args))  # steady-state warm-up
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def kernel_microbench(out: List[str]) -> None:
    """Support-counting inner loops: AND+popcount (Eclat) vs horizontal
    containment matmul (Apriori) vs trimatrix co-occurrence — the per-op
    costs behind Figs 8-14's algorithmic gap."""
    rng = np.random.default_rng(0)
    from repro.kernels.popcount_support import popcount_support_ref
    from repro.core.triangular import cooccurrence_counts

    for (m, w) in [(4096, 128), (4096, 3125), (65536, 313)]:
        a = jnp.asarray(rng.integers(0, 2**32, (m, w), dtype=np.uint32))
        b = jnp.asarray(rng.integers(0, 2**32, (m, w), dtype=np.uint32))
        f = jax.jit(lambda x, y: popcount_support_ref(x, y)[1])
        dt = _time(f, a, b)
        word_ops = m * w
        out.append(f"kernel_microbench/popcount/{m}x{w},{dt*1e6:.0f},"
                   f"gwordops={word_ops/dt/1e9:.2f}")

    for (n, w) in [(256, 313), (1024, 313)]:
        bm = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
        dt = _time(lambda x: cooccurrence_counts(x), bm)
        out.append(f"kernel_microbench/trimatrix/{n}x{w},{dt*1e6:.0f},"
                   f"pairs_per_s={(n*n/2)/dt:.0f}")

    # Apriori containment: (n_txn, n_items) @ (n_items, Q)
    for (t, n, q) in [(10000, 256, 4096)]:
        txn = jnp.asarray(rng.random((t, n)) < 0.1, jnp.float32)
        cand = jnp.asarray(rng.random((q, n)) < 0.02, jnp.float32)
        f = jax.jit(lambda a_, b_: ((a_ @ b_.T) >= 3).astype(jnp.int32).sum(0))
        dt = _time(f, txn, cand)
        out.append(f"kernel_microbench/apriori_containment/{t}x{n}x{q},"
                   f"{dt*1e6:.0f},gflops={2*t*n*q/dt/1e9:.1f}")


def moe_balance(out: List[str]) -> None:
    """Eclat-style greedy expert placement vs default under a Zipf load —
    drop-rate at fixed capacity (DESIGN.md §4, paper-technique transfer)."""
    from repro.core.partitioners import greedy_partitioner, partition_stats

    rng = np.random.default_rng(1)
    e, shards = 128, 16
    load = rng.zipf(1.5, size=e).astype(np.float64)
    load = np.clip(load, None, 20 * np.median(load))   # cap head outliers
    t0 = time.perf_counter()
    for name in ("default", "greedy"):
        if name == "default":
            assign = np.arange(e) % shards
        else:
            assign = greedy_partitioner(np.arange(e), shards, work=load)
        eff = partition_stats(assign, load, shards)["padding_efficiency"]
        out.append(f"moe_balance/{name},{(time.perf_counter()-t0)*1e6:.0f},"
                   f"pad_eff={eff:.3f}")
