"""Word-sharded frontier scaling: parity + per-device memory vs mesh size.

    python benchmarks/shardscale_bench.py [--smoke]   # or benchmarks/run.py

The tid-sharded engine (DESIGN.md §7) carries the frontier bitmap as
``P(None, "data")`` so per-device bitmap memory is total/n_shards — the mode
that lets a database bigger than one device's memory stay minable.  This
bench demonstrates the two halves of that claim on the forced 4-device CPU
host (a subprocess, because the XLA device count is process-global):

  parity   batch ``mine()`` v1–v6 and the streaming sliding-window miner are
           bit-exact across jnp / pallas / tidsharded;
  memory   the same expansion on 1-, 2- and 4-device meshes keeps the mined
           supports identical while per-device frontier bytes drop ~1/n.

Writes ``BENCH_shardscale.json`` for the cross-PR trajectory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(ROOT, "BENCH_shardscale.json")
DATASET = "T10I4D100K"
VARIANTS = ["v1", "v2", "v3", "v4", "v5", "v6"]


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


# ---------------------------------------------------------------------------
# child: runs under --xla_force_host_platform_device_count=4
# ---------------------------------------------------------------------------

def _child(smoke: bool) -> None:
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import EclatConfig, mine
    from repro.core import engine as eng
    from repro.core.eclat import resolve_min_sup
    from repro.core.vertical import build_vertical
    from repro.data import generate, stream_spec, transaction_stream
    from repro.dist.compat import make_mesh

    if len(jax.devices()) < 4:
        raise SystemExit("child needs 4 forced host devices (XLA_FLAGS)")

    scale = 0.02 if smoke else float(os.environ.get("BENCH_SCALE", "0.08"))
    txns, spec = generate(DATASET, scale=scale, seed=1)
    ms = spec.min_sups[len(spec.min_sups) // 2]
    mesh4 = make_mesh((4,), ("data",))
    report: dict = {
        "dataset": DATASET, "scale": scale, "min_sup": float(ms),
        "n_txn": len(txns), "smoke": bool(smoke),
        "jax_backend": jax.default_backend(),
        "parity": {}, "memory": [], "parity_ok": True,
    }

    # ---- (a) batch parity: v1-v6, tidsharded vs jnp vs pallas -------------
    for variant in VARIANTS:
        maps = {}
        walls = {}
        for label, kw in (
            ("jnp", dict(backend="jnp")),
            ("pallas", dict(backend="pallas")),
            ("tidsharded", dict(backend="pallas", shard="words")),
        ):
            cfg = EclatConfig(min_sup=ms, variant=variant, p=10,
                              use_diffsets=(variant == "v6"), **kw)
            mesh = mesh4 if label == "tidsharded" else None
            t0 = time.perf_counter()
            res = mine(txns, spec.n_items, cfg, mesh=mesh)
            walls[label] = time.perf_counter() - t0
            maps[label] = res.support_map()
        identical = maps["jnp"] == maps["pallas"] == maps["tidsharded"]
        report["parity"][variant] = {
            "itemsets": len(maps["jnp"]),
            "identical": bool(identical),
            "wall_s": {k: round(v, 4) for k, v in walls.items()},
        }
        report["parity_ok"] &= bool(identical)

    # ---- (a') streaming parity: word-sharded ring vs batch re-mine --------
    from repro.streaming import StreamConfig, StreamingMiner

    sspec = stream_spec(DATASET)
    block_txns, n_blocks = (128, 2) if smoke else (512, 4)
    n_slides = 3 if smoke else 5
    miner = StreamingMiner(sspec.n_items,
                           StreamConfig(min_sup=0.01, n_blocks=n_blocks,
                                        block_txns=block_txns,
                                        backend="pallas", shard="words"),
                           mesh=mesh4)
    stream_ok = True
    slides = 0
    for batch in transaction_stream(DATASET, block_txns,
                                    n_blocks + n_slides, seed=1):
        res = miner.advance(batch)
        full = mine(miner.window_transactions(), sspec.n_items,
                    EclatConfig(min_sup=0.01, variant="v4", backend="jnp"))
        stream_ok &= res.support_map() == full.support_map()
        slides += 1
    report["parity"]["streaming"] = {
        "engine": miner.engine.name,
        "slides": slides,
        "ring_spec": str(miner.ring.device.sharding.spec),
        "ring_bytes_per_device":
            int(miner.ring.device.addressable_shards[0].data.nbytes),
        "ring_bytes_total": int(miner.ring.device.nbytes),
        "identical": bool(stream_ok),
    }
    report["parity_ok"] &= bool(stream_ok)

    # ---- (b) per-device frontier bytes vs mesh size -----------------------
    abs_ms = resolve_min_sup(ms, len(txns))
    db = build_vertical(txns, spec.n_items, abs_ms, order="support_asc")
    n1 = db.n_items
    iu, ju = np.triu_indices(n1, k=1)
    q = min(int(iu.shape[0]), 4096)
    iu, ju = iu[:q].astype(np.int32), ju[:q].astype(np.int32)
    sup1 = db.supports.astype(np.int32)
    checksums = set()
    for n in (1, 2, 4):
        mesh = make_mesh((n,), ("data",), devices=jax.devices()[:n])
        e = eng.make_engine("tidsharded", mesh=mesh, inner="jnp")
        frontier = e._ensure_sharded(jnp.asarray(db.bitmaps))
        res = e.expand(jnp.asarray(db.bitmaps), iu, ju, sup1[iu],
                       mode=eng.MODE_TIDSET, min_sup=abs_ms)
        entry = {
            "n_devices": n,
            "db_rows": int(n1),
            "db_bitmap_bytes_total": int(frontier.nbytes),
            "db_bitmap_bytes_per_device":
                int(frontier.addressable_shards[0].data.nbytes),
            "level_bitmap_bytes_total": int(res.bitmaps.nbytes),
            "level_bitmap_bytes_per_device":
                int(res.bitmaps.addressable_shards[0].data.nbytes),
            "survivors": int(res.supports.shape[0]),
            "supports_checksum": int(np.asarray(res.supports).sum()),
        }
        report["memory"].append(entry)
        checksums.add(entry["supports_checksum"])
    report["memory_supports_identical"] = len(checksums) == 1
    m1 = report["memory"][0]["level_bitmap_bytes_per_device"]
    m4 = report["memory"][-1]["level_bitmap_bytes_per_device"]
    report["per_device_reduction_4dev"] = m1 / m4 if m4 else 0.0
    print(json.dumps(report))


# ---------------------------------------------------------------------------
# parent harness entry
# ---------------------------------------------------------------------------

def shardscale_bench(out: List[str], smoke: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"shardscale child failed:\n{proc.stderr[-2000:]}")
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    # parity is the acceptance-critical claim — a regression must fail the
    # harness (and CI), not just flip a flag inside the JSON artifact
    if not report["parity_ok"]:
        bad = [k for k, v in report["parity"].items() if not v["identical"]]
        raise RuntimeError(f"shardscale parity regression: {bad} not "
                           f"bit-identical (see {BENCH_PATH})")
    for variant in VARIANTS:
        p = report["parity"][variant]
        out.append(_row(f"shardscale/parity/{variant}",
                        p["wall_s"]["tidsharded"],
                        f"itemsets={p['itemsets']};identical={p['identical']}"))
    s = report["parity"]["streaming"]
    out.append(_row("shardscale/parity/streaming", 0.0,
                    f"slides={s['slides']};identical={s['identical']};"
                    f"ring_per_dev={s['ring_bytes_per_device']}"))
    for m in report["memory"]:
        out.append(_row(f"shardscale/mem/n{m['n_devices']}", 0.0,
                        f"level_per_dev={m['level_bitmap_bytes_per_device']};"
                        f"db_per_dev={m['db_bitmap_bytes_per_device']};"
                        f"checksum={m['supports_checksum']}"))
    out.append(_row("shardscale/reduction", 0.0,
                    f"x{report['per_device_reduction_4dev']:.2f};"
                    f"json={os.path.basename(BENCH_PATH)}"))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still writes BENCH_shardscale.json)")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        sys.path.insert(0, os.path.join(ROOT, "src"))
        _child(smoke=args.smoke)
    else:
        rows: List[str] = ["name,us_per_call,derived"]
        shardscale_bench(rows, smoke=args.smoke)
        print("\n".join(rows))
