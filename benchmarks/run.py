"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  fim_minsup           Figs 8-14: Eclat variants + Apriori vs min_sup
  fim_scale            Fig 16: dataset-size scaling
  fim_cores            Fig 15: executor-core scaling (subprocess per count)
  partitioner_balance  §4.5 extension: padding efficiency per partitioner
  kernel_microbench    kernels: popcount-support / trimatrix / containment
  moe_balance          DESIGN §4: Eclat-style expert placement balance

Env: BENCH_SCALE (default 0.08 of Table-2 sizes), BENCH_FULL=1 for the
paper-complete sweep, BENCH_ONLY=<name> to run a single table.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.fim_benchmarks import (fim_cores, fim_minsup, fim_scale,
                                       partitioner_balance)
from benchmarks.micro import kernel_microbench, moe_balance

TABLES = {
    "fim_minsup": fim_minsup,
    "fim_scale": fim_scale,
    "fim_cores": fim_cores,
    "partitioner_balance": partitioner_balance,
    "kernel_microbench": kernel_microbench,
    "moe_balance": moe_balance,
}


def main() -> None:
    only = os.environ.get("BENCH_ONLY")
    rows = ["name,us_per_call,derived"]
    for name, fn in TABLES.items():
        if only and name != only:
            continue
        try:
            fn(rows)
        except Exception as e:  # keep the harness going; report the failure
            rows.append(f"{name},0,ERROR={type(e).__name__}:{e}")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
