"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

  headline             the paper's headline claim: Apriori vs Eclat v1-v6
                       across dataset scale and mesh size, checksum-verified
                       -> BENCH_headline.json
  fim_minsup           Figs 8-14: Eclat variants + Apriori vs min_sup
  fim_scale            Fig 16: dataset-size scaling
  fim_cores            Fig 15: executor-core scaling (subprocess per count)
  partitioner_balance  §4.5 extension: padding efficiency per partitioner
  kernel_microbench    kernels: popcount-support / trimatrix / containment
  engine               core.engine backend trajectory -> BENCH_engine.json
  streaming            incremental vs full window re-mine -> BENCH_streaming.json
  shardscale           word-sharded frontier parity + per-device memory
                       vs mesh size -> BENCH_shardscale.json
  gridscale            2D (pairs x words) grid parity + per-axis
                       work/memory vs the 1D modes -> BENCH_gridscale.json
  kerneltune           autotune sweep + tuned-vs-default (checksum-gated)
                       + measured backend crossover -> BENCH_kerneltune.json
  recovery             restore-and-resume vs re-mine-from-scratch + live
                       re-meshing, checksum-gated -> BENCH_recovery.json
  serving              query storms at the async admission front end under
                       live slides, checksum-gated vs direct unbatched
                       answers -> BENCH_serving.json
  moe_balance          DESIGN §4: Eclat-style expert placement balance

Env: BENCH_SCALE (default 0.08 of Table-2 sizes), BENCH_FULL=1 for the
paper-complete sweep, BENCH_ONLY=<name> to run a single table.
CLI: ``--smoke`` runs the engine + streaming tables at a CI-sized scale
(still writes both BENCH json files); ``--only <name>`` mirrors BENCH_ONLY.
"""
import argparse
import functools
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _ROOT)                      # `benchmarks` package
sys.path.insert(0, os.path.join(_ROOT, "src"))  # `repro`

from benchmarks.engine_bench import engine_bench
from benchmarks.fim_benchmarks import (fim_cores, fim_minsup, fim_scale,
                                       partitioner_balance)
from benchmarks.gridscale_bench import gridscale_bench
from benchmarks.headline_bench import headline_bench
from benchmarks.kerneltune_bench import kerneltune_bench
from benchmarks.micro import kernel_microbench, moe_balance
from benchmarks.recovery_bench import recovery_bench
from benchmarks.serving_bench import serving_bench
from benchmarks.shardscale_bench import shardscale_bench
from benchmarks.streaming_bench import streaming_bench

TABLES = {
    "headline": headline_bench,
    "fim_minsup": fim_minsup,
    "fim_scale": fim_scale,
    "fim_cores": fim_cores,
    "partitioner_balance": partitioner_balance,
    "kernel_microbench": kernel_microbench,
    "engine": engine_bench,
    "streaming": streaming_bench,
    "shardscale": shardscale_bench,
    "gridscale": gridscale_bench,
    "kerneltune": kerneltune_bench,
    "recovery": recovery_bench,
    "serving": serving_bench,
    "moe_balance": moe_balance,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: engine table only, tiny scale")
    ap.add_argument("--only", default=os.environ.get("BENCH_ONLY"),
                    help="run a single table by name")
    args = ap.parse_args()

    tables = {
        "headline": functools.partial(headline_bench, smoke=True),
        "engine": functools.partial(engine_bench, smoke=True),
        "streaming": functools.partial(streaming_bench, smoke=True),
        "shardscale": functools.partial(shardscale_bench, smoke=True),
        "gridscale": functools.partial(gridscale_bench, smoke=True),
        "kerneltune": functools.partial(kerneltune_bench, smoke=True),
        "recovery": functools.partial(recovery_bench, smoke=True),
        "serving": functools.partial(serving_bench, smoke=True),
    } if args.smoke else TABLES
    rows = ["name,us_per_call,derived"]
    failures = []
    for name, fn in tables.items():
        if args.only and name != args.only:
            continue
        try:
            fn(rows)
        except Exception as e:  # keep the harness going; report the failure
            rows.append(f"{name},0,ERROR={type(e).__name__}:{e}")
            failures.append(name)
    print("\n".join(rows))
    if failures:  # ...but a failed table (e.g. a parity regression raised
        # by a bench harness) must still fail the run, and CI with it
        raise SystemExit(f"benchmark table(s) failed: {failures}")


if __name__ == "__main__":
    main()
