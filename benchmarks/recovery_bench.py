"""Recovery economics: restore-and-resume vs re-mine-from-scratch.

    python benchmarks/recovery_bench.py [--smoke]   # or benchmarks/run.py

The resilience contract (DESIGN.md §10) is only worth its checkpoint bytes
if recovering a crashed stream is cheaper than replaying it from the start.
This bench runs in a forced-4-device subprocess (the XLA device count is
process-global) and measures, on the paper's T10I4D100K stream:

  resume    crash the miner at a late slide, restore the newest durable
            checkpoint, replay the remaining slides — wall-clock vs a fresh
            miner replaying the whole stream, with *identical* final
            support checksums (divergence raises, it is not a data point);
  remesh    the same restore landed on a different mesh factorization
            (4 -> 2 devices, 2x2 grid -> 4x1, sharded -> single device),
            checksum-gated against the same reference;
  torn      a kill *inside* the checkpoint write itself: the torn step is
            invisible, restore falls back one step and still converges.

Writes ``BENCH_recovery.json`` for the cross-PR trajectory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_PATH = os.path.join(ROOT, "BENCH_recovery.json")
DATASET = "T10I4D100K"


def _row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


# ---------------------------------------------------------------------------
# child: runs under --xla_force_host_platform_device_count=4
# ---------------------------------------------------------------------------

def _child(smoke: bool) -> None:
    import tempfile
    import time

    import jax

    from repro.data import stream_spec, transaction_stream
    from repro.dist.compat import make_mesh
    from repro.faults import (InjectedFault, clear_kill_hook, set_kill_hook)
    from repro.streaming import (StreamCheckpointer, StreamConfig,
                                 StreamingMiner, restore_miner)
    from repro.training import valid_steps

    if len(jax.devices()) < 4:
        raise SystemExit("child needs 4 forced host devices (XLA_FLAGS)")

    spec = stream_spec(DATASET)
    block_txns, n_blocks, slides = (128, 2, 5) if smoke else (512, 4, 8)
    min_sup = 0.02 if smoke else 0.01
    kill_slide = slides - 1
    batches = list(transaction_stream(DATASET, block_txns, slides, seed=1))
    mesh4 = make_mesh((4,), ("data",))

    def checksum(res):
        sm = res.support_map()
        return {"itemsets": len(sm), "support_sum": int(sum(sm.values()))}

    def fresh(cfg, mesh):
        return StreamingMiner(spec.n_items, cfg, mesh=mesh,
                              keep_transactions=False)

    def crashed_stream(cfg, mesh, directory, point="miner:mid_append"):
        """Checkpoint-per-slide run killed at `point` during the last
        slide; returns the newest durable step."""
        miner = fresh(cfg, mesh)
        ck = StreamCheckpointer(directory, every=1, keep=3)
        hits = {"n": 0}

        def die(name):
            if name == point:
                hits["n"] += 1
                raise InjectedFault(name)
        try:
            for i, b in enumerate(batches):
                if i == kill_slide:
                    set_kill_hook(die)
                miner.advance(b)
                ck.save(miner, i + 1)
                try:
                    ck.wait()
                except InjectedFault:
                    break
        except InjectedFault:
            pass
        finally:
            clear_kill_hook()
        if not hits["n"] > 0:
            raise RuntimeError(f"kill point {point} never fired")
        steps = valid_steps(directory)
        if not steps:
            raise RuntimeError("no durable checkpoint survived")
        return steps[-1]

    def resume(directory, mesh, backend=None, shard=None):
        t0 = time.perf_counter()
        miner, start = restore_miner(directory, mesh=mesh, backend=backend,
                                     shard=shard, keep_transactions=False)
        res = None
        for b in batches[start:]:
            res = miner.advance(b)
        if res is None:
            res = miner.mine_window()
        return res, time.perf_counter() - t0, start

    report: dict = {
        "dataset": DATASET, "smoke": bool(smoke),
        "block_txns": block_txns, "n_blocks": n_blocks, "slides": slides,
        "kill_slide": kill_slide, "min_sup": min_sup,
        "jax_backend": jax.default_backend(),
        "checksums_identical": True,
    }
    cfg = StreamConfig(min_sup=min_sup, n_blocks=n_blocks,
                       block_txns=block_txns, backend="tidsharded")

    # ---- (a) resume vs scratch, same 4-device mesh ------------------------
    with tempfile.TemporaryDirectory() as d:
        step = crashed_stream(cfg, mesh4, d)     # also warms the jit caches
        t0 = time.perf_counter()
        scratch_miner = fresh(cfg, mesh4)
        for b in batches:
            ref = scratch_miner.advance(b)
        t_scratch = time.perf_counter() - t0
        ref_map = ref.support_map()
        res, t_restore, start = resume(d, mesh4)
        ok = res.support_map() == ref_map
        report["resume"] = {
            "durable_step": int(step), "resumed_from_slide": int(start),
            "replayed_slides": slides - int(start),
            "t_scratch_s": round(t_scratch, 4),
            "t_restore_s": round(t_restore, 4),
            "speedup": round(t_scratch / t_restore, 2) if t_restore else 0.0,
            "checksum": checksum(res), "identical": bool(ok),
        }
        report["checksums_identical"] &= ok

        # ---- (b) the same checkpoint landed on different meshes -----------
        report["remesh"] = []
        for label, mesh, backend, shard in (
            ("4dev->2dev", make_mesh((2,), ("data",),
                                     devices=jax.devices()[:2]), None, None),
            ("4dev->grid2x2", make_mesh((2, 2), ("class", "data"),
                                        devices=jax.devices()[:4]),
             "grid", "grid"),
            ("4dev->single", None, "pallas", "pairs"),
        ):
            res, t_r, _ = resume(d, mesh, backend=backend, shard=shard)
            ok = res.support_map() == ref_map
            report["remesh"].append({
                "move": label, "t_restore_s": round(t_r, 4),
                "checksum": checksum(res), "identical": bool(ok)})
            report["checksums_identical"] &= ok

    # ---- (b') a grid-mesh checkpoint refactored 2x2 -> 4x1 ----------------
    gcfg = StreamConfig(min_sup=min_sup, n_blocks=n_blocks,
                        block_txns=block_txns, backend="grid", shard="grid")
    mesh22 = make_mesh((2, 2), ("class", "data"), devices=jax.devices()[:4])
    with tempfile.TemporaryDirectory() as d:
        crashed_stream(gcfg, mesh22, d, point="miner:pre_deep_expand")
        mesh41 = make_mesh((4, 1), ("class", "data"),
                           devices=jax.devices()[:4])
        res, t_r, _ = resume(d, mesh41)
        ok = res.support_map() == ref_map
        report["remesh"].append({
            "move": "grid2x2->grid4x1", "t_restore_s": round(t_r, 4),
            "checksum": checksum(res), "identical": bool(ok)})
        report["checksums_identical"] &= ok

    # ---- (c) a kill inside the checkpoint write: fall back one step -------
    with tempfile.TemporaryDirectory() as d:
        step = crashed_stream(cfg, mesh4, d, point="checkpoint:mid_write")
        res, t_r, start = resume(d, mesh4)
        ok = res.support_map() == ref_map
        report["torn_write"] = {
            "durable_step": int(step), "resumed_from_slide": int(start),
            "t_restore_s": round(t_r, 4),
            "checksum": checksum(res), "identical": bool(ok)}
        report["checksums_identical"] &= ok

    print(json.dumps(report))


# ---------------------------------------------------------------------------
# parent harness entry
# ---------------------------------------------------------------------------

def recovery_bench(out: List[str], smoke: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"recovery child failed:\n{proc.stderr[-2000:]}")
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2)
    # bit-exact recovery is the acceptance-critical claim: a restore that
    # "works" but mines different itemsets must fail the harness, not ship
    # as a timing row
    if not report["checksums_identical"]:
        bad = ([m["move"] for m in report["remesh"] if not m["identical"]]
               + [k for k in ("resume", "torn_write")
                  if not report[k]["identical"]])
        raise RuntimeError(f"recovery checksum divergence: {bad} "
                           f"(see {BENCH_PATH})")
    r = report["resume"]
    out.append(_row("recovery/resume", r["t_restore_s"],
                    f"scratch={r['t_scratch_s']}s;speedup=x{r['speedup']};"
                    f"replayed={r['replayed_slides']}/{report['slides']};"
                    f"identical={r['identical']}"))
    for m in report["remesh"]:
        out.append(_row(f"recovery/remesh/{m['move']}", m["t_restore_s"],
                        f"itemsets={m['checksum']['itemsets']};"
                        f"identical={m['identical']}"))
    t = report["torn_write"]
    out.append(_row("recovery/torn_write", t["t_restore_s"],
                    f"fellback_to={t['durable_step']};"
                    f"identical={t['identical']};"
                    f"json={os.path.basename(BENCH_PATH)}"))
    return report


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (still writes BENCH_recovery.json)")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        sys.path.insert(0, os.path.join(ROOT, "src"))
        _child(smoke=args.smoke)
    else:
        rows: List[str] = ["name,us_per_call,derived"]
        recovery_bench(rows, smoke=args.smoke)
        print("\n".join(rows))
