"""Live top-k frequent itemsets over a sliding window (Python API tour).

    PYTHONPATH=src python examples/stream_topk.py [--batches 8]

Feeds a T10-style micro-batch stream into the incremental miner, queries the
current window through the serving layer, and cross-checks one slide against
batch ``mine()`` to show the windowed results are bit-exact.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import EclatConfig, mine
from repro.data import stream_spec, transaction_stream
from repro.serving import ItemsetQuery, StreamQueryService
from repro.streaming import StreamConfig, StreamingMiner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="T10I4D100K")
    ap.add_argument("--min-sup", type=float, default=0.02)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--block-txns", type=int, default=256)
    ap.add_argument("--n-blocks", type=int, default=4)
    args = ap.parse_args()

    spec = stream_spec(args.dataset)
    miner = StreamingMiner(
        spec.n_items,
        StreamConfig(min_sup=args.min_sup, n_blocks=args.n_blocks,
                     block_txns=args.block_txns))
    service = StreamQueryService(miner)

    for i, batch in enumerate(transaction_stream(
            args.dataset, args.block_txns, args.batches, seed=3)):
        res = service.ingest(batch)
        top = service.top_k_itemsets(k=3, min_len=2)
        print(f"slide {i}: {res.n_txn} txns in window, {res.total} frequent "
              f"itemsets, top pairs: {top}")

    # heterogeneous query batch, greedy-LPT packed across answer slots
    queries = [ItemsetQuery(qid=0, kind="topk", k=5, min_len=2),
               ItemsetQuery(qid=1, kind="rules", min_conf=0.9, k=5),
               ItemsetQuery(qid=2, kind="topk", k=3, min_len=3)]
    answers, stats = service.answer_batch(queries, n_batches=2)
    print(f"answered {len(answers)} queries "
          f"(packing efficiency {stats['padding_efficiency']:.2f})")
    print(f"  {len(answers[1])} rules at conf>=0.9; strongest: "
          f"{answers[1][0] if answers[1] else None}")

    # the windowed result is bit-exact with batch mining the same window
    batch_res = mine(miner.window_transactions(), spec.n_items,
                     EclatConfig(min_sup=args.min_sup))
    assert res.support_map() == batch_res.support_map()
    print(f"parity: windowed == batch mine() over the window "
          f"({batch_res.total} itemsets)")


if __name__ == "__main__":
    main()
