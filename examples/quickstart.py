"""Quickstart: mine frequent itemsets with RDD-Eclat on a paper dataset.

    PYTHONPATH=src python examples/quickstart.py [--dataset chess]
                                                  [--min-sup 0.8] [--variant v4]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import EclatConfig, apriori_mine, generate_rules, mine
from repro.data import PAPER_DATASETS, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="chess", choices=list(PAPER_DATASETS))
    ap.add_argument("--min-sup", type=float, default=0.8)
    ap.add_argument("--variant", default="v4",
                    choices=["v1", "v2", "v3", "v4", "v5", "v6"])
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--compare-apriori", action="store_true")
    ap.add_argument("--rules", action="store_true")
    args = ap.parse_args()

    txns, spec = generate(args.dataset, scale=args.scale, seed=1)
    print(f"dataset {spec.name}: {len(txns)} txns, {spec.n_items} items, "
          f"avg width {sum(map(len, txns))/len(txns):.1f}")

    cfg = EclatConfig(min_sup=args.min_sup, variant=args.variant, p=10,
                      tri_matrix=spec.tri_matrix or None)
    t0 = time.perf_counter()
    res = mine(txns, spec.n_items, cfg)
    dt = time.perf_counter() - t0
    print(f"RDD-Eclat[{args.variant}] min_sup={args.min_sup}: "
          f"{res.total} frequent itemsets in {dt:.2f}s "
          f"(per-level: {res.counts})")
    print(f"  intersections: {res.stats['n_intersections']}, "
          f"filter reduction: {res.stats.get('filter_reduction', 0):.1%}, "
          f"partition padding efficiency: "
          f"{res.stats.get('partition_balance', {}).get('padding_efficiency', 1):.3f}")

    top = sorted(res.itemsets(), key=lambda kv: (-len(kv[0]), -kv[1]))[:5]
    for iset, sup in top:
        print(f"  {iset} support={sup} ({sup/len(txns):.1%})")

    if args.compare_apriori:
        t0 = time.perf_counter()
        ap_res = apriori_mine(txns, spec.n_items, args.min_sup)
        dt_ap = time.perf_counter() - t0
        assert ap_res.support_map == res.support_map()
        print(f"Spark-Apriori baseline: {dt_ap:.2f}s "
              f"-> Eclat speedup {dt_ap/dt:.1f}x (results identical)")

    if args.rules:
        rules = generate_rules(res.support_map(), min_conf=0.9)
        print(f"{len(rules)} association rules at conf>=0.9; strongest:")
        for ante, cons, conf, sup in sorted(rules, key=lambda r: -r[2])[:5]:
            print(f"  {ante} => {cons}  conf={conf:.3f} sup={sup}")


if __name__ == "__main__":
    main()
