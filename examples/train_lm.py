"""End-to-end training driver: train a reduced-config LM for a few hundred
steps on CPU with checkpoint/restart, demonstrating the full training path
(data pipeline -> train step -> async checkpoints -> resume).

    PYTHONPATH=src python examples/train_lm.py --arch gemma-2b --steps 60
    # ~100M-param config (slower):
    PYTHONPATH=src python examples/train_lm.py --arch gemma-2b --preset 100m --steps 200
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.configs.reduced import reduced_config
from repro.data import TokenPipeline
from repro.models import Model, init_params
from repro.training import (RunnerConfig, TrainingRunner, adamw_init,
                            make_train_step)


def preset_cfg(arch: str, preset: str):
    base = get_config(arch)
    if preset == "tiny":
        return reduced_config(base, d_model=128, vocab=2048)
    if preset == "100m":   # ~100M params
        return dataclasses.replace(
            reduced_config(base, d_model=768, vocab=32768),
            n_layers=12, n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048)
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = preset_cfg(args.arch, args.preset)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name} [{args.preset}]: {n_params/1e6:.1f}M params")

    pipe = TokenPipeline(cfg.vocab_size, batch=args.batch, seq_len=args.seq, seed=0)
    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=20,
                       total_steps=args.steps, remat="none")
    step_fn = jax.jit(make_train_step(model, tcfg))

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}

    runner = TrainingRunner(
        RunnerConfig(args.ckpt_dir, checkpoint_every=25),
        step_fn, params, adamw_init(params), batch_fn)
    resumed = runner.maybe_restore()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")

    t0 = time.perf_counter()
    runner.run(args.steps)
    dt = time.perf_counter() - t0
    losses = [m["loss"] for m in runner.metrics_log]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"{len(losses)} steps in {dt:.1f}s "
              f"({dt/len(losses):.2f}s/step): "
              f"loss {sum(losses[:k])/k:.3f} -> {sum(losses[-k:])/k:.3f}")
        assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "loss did not drop"
    print("training example OK (checkpoints in", args.ckpt_dir + ")")


if __name__ == "__main__":
    main()
