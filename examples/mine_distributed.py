"""Distributed mining with fault tolerance: shard the equivalence classes
over a device mesh, kill a partition, recover it from lineage.

    PYTHONPATH=src python examples/mine_distributed.py [--devices 4]

(The script re-execs itself with XLA_FLAGS so --devices takes effect.)
"""
import argparse
import os
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--dataset", default="mushroom")
    ap.add_argument("--min-sup", type=float, default=0.3)
    args = ap.parse_args()

    if os.environ.get("_MINE_CHILD") != "1":
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
        os.environ["_MINE_CHILD"] = "1"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import numpy as np
    from repro.core import (EclatConfig, assign_partitions, build_vertical,
                            mine, recover_partition)
    from repro.data import generate
    from repro.dist.compat import make_mesh

    mesh = make_mesh((args.devices,), ("data",))
    txns, spec = generate(args.dataset, scale=0.2, seed=1)
    cfg = EclatConfig(min_sup=args.min_sup, variant="v5",
                      p=2 * args.devices, backend="sharded")
    res = mine(txns, spec.n_items, cfg, mesh=mesh)
    print(f"mined {res.total} itemsets on {args.devices} devices; "
          f"device balance: {res.stats['device_balance']}")

    # --- simulate losing a partition and recover it from lineage ----------
    abs_ms = cfg.resolve_min_sup(len(txns))
    db = build_vertical(txns, spec.n_items, abs_ms)
    table = assign_partitions(db.n_items - 1, "reverse_hash", 2 * args.devices)
    lost = 3
    recovered = recover_partition(db, table, pid=lost, abs_min_sup=abs_ms)
    # verify against the full result
    rank_of = {int(it): r for r, it in enumerate(db.items)}
    expect = {k: v for k, v in res.support_map().items()
              if len(k) >= 2 and table[min(rank_of[i] for i in k)] == lost}
    assert recovered == expect
    print(f"partition {lost} lost -> {len(recovered)} itemsets recovered "
          f"bit-exactly from lineage (vertical DB + partition table)")


if __name__ == "__main__":
    main()
