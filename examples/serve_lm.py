"""Batched serving example: heterogeneous prompts packed with the paper's
greedy-LPT partitioner, prefill + KV-cached decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --requests 12
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.reduced import reduced_config
from repro.models import Model, init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch), d_model=128, vocab=2048)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(model, params, s_max=128,
                           temperature=args.temperature)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 64))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    total_prompt = sum(r.prompt.shape[0] for r in reqs)

    t0 = time.perf_counter()
    results, pack_stats = engine.serve(reqs, n_batches=args.batches)
    dt = time.perf_counter() - t0
    total_new = sum(len(v) for v in results.values())
    print(f"{args.arch} [{cfg.d_model}d reduced]: served {len(reqs)} requests "
          f"({total_prompt} prompt + {total_new} new tokens) in {dt:.1f}s")
    print(f"greedy-LPT packing efficiency: "
          f"{pack_stats['padding_efficiency']:.3f} over {args.batches} batches")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid][:8].tolist()}...")


if __name__ == "__main__":
    main()
