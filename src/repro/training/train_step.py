"""Train step: loss/grad, microbatch accumulation, clipping, AdamW.

A single pjit-able function per (model, train-config).  Microbatching splits
the per-device batch with an accumulating ``lax.scan`` so the activation
footprint scales with the microbatch, not the global batch — the standard
large-scale memory lever alongside remat.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import adamw_update, clip_by_global_norm, lr_schedule

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(model, tcfg) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tcfg.remat)

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        m = tcfg.microbatches

        def split(x):
            b = x.shape[0]
            return x.reshape(m, b // m, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            loss_sum, g_sum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_sum = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), g_sum, g)
            return (loss_sum + loss, g_sum), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(acc_step, (jnp.zeros(()), zero), micro)
        grads = jax.tree.map(lambda g: (g / m).astype(jnp.float32), g_sum)
        return loss_sum / m, grads

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_schedule(opt_state["step"], tcfg.learning_rate,
                         tcfg.warmup_steps, tcfg.total_steps)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": opt_state["step"]}
        return params, opt_state, metrics

    return step


def make_eval_step(model) -> Callable:
    def step(params, batch):
        return model.loss(params, batch)
    return step
