"""AdamW (decoupled weight decay) as explicit pytrees, ZeRO-1 ready.

No optax dependency: the optimizer state is a plain pytree so the sharding
rules (``zero1_spec_tree``) and the distributed checkpoint see ordinary
arrays.  Moments are fp32 regardless of param dtype (bf16 training keeps an
fp32 master copy in the state when requested).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["adamw_init", "adamw_update", "global_norm", "clip_by_global_norm",
           "lr_schedule", "zero1_spec_tree"]


def adamw_init(params, master: bool = False, moment_dtype=jnp.float32):
    zero = lambda p: jnp.zeros(p.shape, moment_dtype)
    state = {
        "mu": jax.tree.map(zero, params),
        "nu": jax.tree.map(zero, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def lr_schedule(step, base_lr: float, warmup: int, total: int):
    """Linear warmup + cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return base_lr * warm * cos


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    use_master = "master" in state
    ref = state["master"] if use_master else params

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mdt = mu.dtype
        mu = (b1 * mu.astype(jnp.float32) + (1 - b1) * g).astype(mdt)
        nu = (b2 * nu.astype(jnp.float32) + (1 - b2) * g * g).astype(mdt)
        update = (mu.astype(jnp.float32) / c1) / (jnp.sqrt(nu.astype(jnp.float32) / c2) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (update + weight_decay * pf)
        return pf, mu, nu

    flat_p, treedef = jax.tree.flatten(ref)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    orig_flat = jax.tree.leaves(params)
    new_params = treedef.unflatten(
        [pf.astype(po.dtype) for pf, po in zip([o[0] for o in out], orig_flat)])
    if use_master:
        new_state["master"] = new_master
    return new_params, new_state


def zero1_spec_tree(param_specs, mesh):
    """ZeRO-1: further shard each optimizer-moment leaf over the data axes on
    its largest currently-unsharded dimension (divisibility permitting)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def widen(spec: P, shape) -> P:
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for e in entries:
            if e is None:
                continue
            for a in (e if isinstance(e, (tuple, list)) else (e,)):
                used.add(a)
        avail = tuple(a for a in dp if a not in used)
        if not avail:
            return spec
        size = 1
        for a in avail:
            size *= mesh.shape[a]
        if size <= 1:
            return spec
        best, best_dim = None, -1
        for i, (e, s) in enumerate(zip(entries, shape)):
            if e is None and s % size == 0 and s > best_dim:
                best, best_dim = i, s
        if best is not None:
            entries[best] = avail if len(avail) > 1 else avail[0]
        return P(*entries)

    return widen
