"""repro.training — optimizer, train step, checkpoint, compression, FT."""
from .checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                         restore_checkpoint, restore_latest, save_checkpoint,
                         valid_steps)
from .fault_tolerance import (Heartbeat, HeartbeatMonitor, RunnerConfig,
                              TrainingRunner, WriterStalledError)
from .grad_compress import compressed_psum, int8_roundtrip, make_compressor, topk_mask
from .optimizer import (adamw_init, adamw_update, clip_by_global_norm,
                        global_norm, lr_schedule, zero1_spec_tree)
from .train_step import make_eval_step, make_train_step

__all__ = [
    "AsyncCheckpointer", "latest_step", "load_checkpoint",
    "restore_checkpoint", "restore_latest", "save_checkpoint", "valid_steps",
    "RunnerConfig", "TrainingRunner",
    "Heartbeat", "HeartbeatMonitor", "WriterStalledError",
    "compressed_psum", "int8_roundtrip", "make_compressor", "topk_mask",
    "adamw_init", "adamw_update", "clip_by_global_norm", "global_norm",
    "lr_schedule", "zero1_spec_tree",
    "make_eval_step", "make_train_step",
]
