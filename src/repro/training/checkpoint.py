"""Distributed, elastic, async checkpointing.

Layout: one directory per step, containing ``manifest.json`` (paths, shapes,
dtypes, step, config name) plus one ``.npy`` per leaf.  Writes go to a temp
directory that is atomically renamed, so a crash mid-write never corrupts the
latest checkpoint.  Restore is *elastic*: arrays are loaded host-side and
``device_put`` against whatever sharding tree the new mesh prescribes — the
checkpoint stores logical content only, never device layouts, so a run can
resume on a different pod count (tests/test_checkpoint.py proves 1-device ->
4-device -> 1-device round trips).

Crash-consistency contract (DESIGN.md §10):

- the manifest is written *last* inside the temp dir and fsynced, so a step
  directory that contains a readable manifest contains every leaf it names;
- only directories with a readable manifest count as steps (``valid_steps``),
  so torn temp dirs and half-deleted GC victims are invisible to restore;
- overwriting an existing step renames the old directory aside before the
  new one lands — there is no instant at which the step name points at a
  partially-deleted tree;
- :func:`restore_latest` walks steps newest-first and falls back past any
  step whose manifest or leaves fail to load, so a crash *anywhere* in the
  writer loses at most the in-flight step.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..faults import kill_point

__all__ = ["save_checkpoint", "restore_checkpoint", "load_checkpoint",
           "restore_latest", "latest_step", "valid_steps", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"
_STEP_RE = re.compile(r"step_(\d+)")


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{int(step):08d}")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    paths, leaves, _ = _flatten_with_paths(tree)
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = _leaf_name(i)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    kill_point("checkpoint:mid_write")   # leaves down, manifest not yet
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    kill_point("checkpoint:pre_replace")  # complete tmp, not yet visible
    final = _step_dir(directory, step)
    if os.path.exists(final):
        # Re-saving an existing step: rename the old directory aside first so
        # the step name never points at a partially-deleted tree.  A crash
        # between the two renames hides this step entirely (restore falls
        # back to the previous one) — strictly better than the old
        # rmtree-then-replace, which could destroy the only copy.
        doomed = final + ".old"
        shutil.rmtree(doomed, ignore_errors=True)
        os.replace(final, doomed)
        os.replace(tmp, final)
        shutil.rmtree(doomed, ignore_errors=True)
    else:
        os.replace(tmp, final)
    return final


def valid_steps(directory: str) -> List[int]:
    """Steps whose directory holds a readable manifest, ascending.  Torn temp
    dirs, GC-renamed victims, and manifests cut off mid-write are excluded."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        m = _STEP_RE.fullmatch(d)
        if not m:
            continue
        try:
            with open(os.path.join(directory, d, _MANIFEST)) as f:
                json.load(f)
        except (OSError, ValueError):
            continue
        steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = valid_steps(directory)
    return steps[-1] if steps else None


def load_checkpoint(directory: str, step: int) -> Tuple[Dict[str, np.ndarray], dict]:
    """Self-describing restore: no ``like`` tree needed.  Returns
    ``({leaf_path: host_array}, manifest)`` — callers that persist trees of
    varying structure (e.g. a miner with or without kept transactions)
    rebuild from the path map."""
    ckpt_dir = _step_dir(directory, step)
    with open(os.path.join(ckpt_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    out = {e["path"]: np.load(os.path.join(ckpt_dir, e["file"]))
           for e in manifest["leaves"]}
    return out, manifest


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Any = None):
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (a matching pytree of ``NamedSharding``/``Sharding``) if given."""
    ckpt_dir = _step_dir(directory, step)
    with open(os.path.join(ckpt_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    shard_flat = (treedef.flatten_up_to(shardings) if shardings is not None
                  else [None] * len(leaves))
    for path, leaf, shard in zip(paths, leaves, shard_flat):
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(os.path.join(ckpt_dir, entry["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{path}: shape {arr.shape} != {np.shape(leaf)}")
        arr = arr.astype(np.asarray(leaf).dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), manifest


def restore_latest(directory: str, like: Any = None, shardings: Any = None):
    """Restore the newest step that actually loads, falling back past any
    partially-written or corrupt step (truncated leaf, missing file, bad
    manifest).  With ``like=None`` returns ``(path_map, manifest, step)``
    from :func:`load_checkpoint`; otherwise ``(tree, manifest, step)``."""
    last_err: Optional[BaseException] = None
    for step in reversed(valid_steps(directory)):
        try:
            if like is None:
                flat, manifest = load_checkpoint(directory, step)
            else:
                flat, manifest = restore_checkpoint(directory, step, like,
                                                    shardings)
            return flat, manifest, step
        except (OSError, ValueError, KeyError) as e:
            last_err = e
    raise FileNotFoundError(
        f"no restorable checkpoint under {directory!r}") from last_err


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread, with a
    bounded queue of one (a new save waits for the previous to land — the
    standard TPU-friendly pattern: snapshot to host, write off-thread)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._gc_lock = threading.Lock()

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc(just_wrote=int(step))
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self, just_wrote: Optional[int] = None):
        """Keep the newest ``keep`` valid steps.  Serialized under a lock so
        two checkpointers on one directory can't both collect; victims are
        renamed out of the step namespace *before* deletion, so a concurrent
        ``restore_latest`` either sees a step completely or not at all —
        never a directory losing leaves under it.  Steps at or above a save
        that just landed are never collected, even if an older save's GC runs
        late."""
        with self._gc_lock:
            steps = valid_steps(self.directory)
            doomed = steps[:-self.keep] if self.keep > 0 else steps
            for s in doomed:
                if just_wrote is not None and s >= just_wrote:
                    continue
                path = _step_dir(self.directory, s)
                trash = path + ".gc"
                try:
                    os.replace(path, trash)
                except OSError:
                    continue    # another collector got it first
                shutil.rmtree(trash, ignore_errors=True)
