"""Distributed, elastic, async checkpointing.

Layout: one directory per step, containing ``manifest.json`` (paths, shapes,
dtypes, step, config name) plus one ``.npy`` per leaf.  Writes go to a temp
directory that is atomically renamed, so a crash mid-write never corrupts the
latest checkpoint.  Restore is *elastic*: arrays are loaded host-side and
``device_put`` against whatever sharding tree the new mesh prescribes — the
checkpoint stores logical content only, never device layouts, so a run can
resume on a different pod count (tests/test_checkpoint.py proves 1-device ->
4-device -> 1-device round trips).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    paths, leaves, _ = _flatten_with_paths(tree)
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        fname = _leaf_name(i)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(directory, f"step_{int(step):08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Any = None):
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (a matching pytree of ``NamedSharding``/``Sharding``) if given."""
    ckpt_dir = os.path.join(directory, f"step_{int(step):08d}")
    with open(os.path.join(ckpt_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    shard_flat = (treedef.flatten_up_to(shardings) if shardings is not None
                  else [None] * len(leaves))
    for path, leaf, shard in zip(paths, leaves, shard_flat):
        entry = by_path.get(path)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(os.path.join(ckpt_dir, entry["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{path}: shape {arr.shape} != {np.shape(leaf)}")
        arr = arr.astype(np.asarray(leaf).dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), manifest


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread, with a
    bounded queue of one (a new save waits for the previous to land — the
    standard TPU-friendly pattern: snapshot to host, write off-thread)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
