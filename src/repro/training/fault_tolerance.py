"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler posture.

At 1000+ nodes the failure model is "some host dies every few hours"; the
framework's answer is (a) frequent async checkpoints with atomic rename,
(b) stateless-resumable data order (batch i is a pure function of (seed, i),
so a restarted run replays no data and skips ahead in O(1)), and (c) elastic
restore (checkpoints are mesh-agnostic — a run can come back on fewer pods).
Straggler mitigation at this layer is the backup-step knob: the runner
tolerates a configurable number of missed heartbeats before declaring a step
failed and re-dispatching it — on real fleets this maps to the
synchronous-with-backup-workers pattern; in tests it is exercised with
injected failures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint

__all__ = ["RunnerConfig", "TrainingRunner"]


@dataclasses.dataclass
class RunnerConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 2
    fail_injector: Optional[Callable[[int], bool]] = None  # tests: step -> bool


class TrainingRunner:
    """Drives step() with checkpoint/restart semantics."""

    def __init__(self, cfg: RunnerConfig, step_fn, params, opt_state,
                 batch_fn, shardings=None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.batch_fn = batch_fn          # step index -> batch (deterministic)
        self.shardings = shardings
        self.ckpt = AsyncCheckpointer(cfg.checkpoint_dir, keep=cfg.keep)
        self.start_step = 0
        self.metrics_log: list = []

    def maybe_restore(self):
        step = latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return 0
        state = {"params": self.params, "opt": self.opt_state}
        restored, manifest = restore_checkpoint(
            self.cfg.checkpoint_dir, step, state, self.shardings)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.start_step = int(manifest["step"])
        return self.start_step

    def run(self, n_steps: int):
        step = self.maybe_restore()
        end = step + n_steps if self.start_step == 0 else self.start_step + n_steps
        while step < end:
            batch = self.batch_fn(step)
            retries = 0
            while True:
                try:
                    if self.cfg.fail_injector and self.cfg.fail_injector(step) \
                            and retries == 0:
                        raise RuntimeError(f"injected failure at step {step}")
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params, self.opt_state, batch)
                    break
                except RuntimeError:
                    retries += 1
                    if retries > self.cfg.max_retries_per_step:
                        # full restart-from-checkpoint path
                        restored = latest_step(self.cfg.checkpoint_dir)
                        if restored is None:
                            raise
                        step = self.maybe_restore()
                        batch = self.batch_fn(step)
                        retries = 0
            self.metrics_log.append(
                {k: float(np.asarray(v)) for k, v in metrics.items()})
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, {"params": self.params, "opt": self.opt_state})
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state})
        self.ckpt.wait()
        return step
