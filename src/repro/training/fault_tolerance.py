"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler posture.

At 1000+ nodes the failure model is "some host dies every few hours"; the
framework's answer is (a) frequent async checkpoints with atomic rename,
(b) stateless-resumable data order (batch i is a pure function of (seed, i),
so a restarted run replays no data and skips ahead in O(1)), and (c) elastic
restore (checkpoints are mesh-agnostic — a run can come back on fewer pods).
Straggler mitigation at this layer is the backup-step knob: the runner
tolerates a configurable number of missed heartbeats before declaring a step
failed and re-dispatching it — on real fleets this maps to the
synchronous-with-backup-workers pattern; in tests it is exercised with
injected failures.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint

__all__ = ["RunnerConfig", "TrainingRunner", "Heartbeat", "HeartbeatMonitor",
           "WriterStalledError"]


class WriterStalledError(RuntimeError):
    """A monitored worker missed its heartbeat deadline (it is stalled or
    dead); raised to readers that would otherwise wait on it forever."""


class Heartbeat:
    """Monotonic liveness stamp a long-running worker thread beats.

    The missed-heartbeat detector this module's docstring promised, made
    concrete: the worker calls :meth:`beat` once per unit of progress (a
    training step, a window slide) and any other thread reads :meth:`age`
    without locks on the hot path.  ``clock`` is injectable so stall tests
    are deterministic, never sleep-based.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._lock = threading.Lock()
        self._last = clock()
        self._step: Optional[int] = None

    def beat(self, step: Optional[int] = None) -> None:
        with self._lock:
            self._last = self._clock()
            if step is not None:
                self._step = int(step)

    @property
    def last_step(self) -> Optional[int]:
        with self._lock:
            return self._step

    def age(self) -> float:
        """Seconds since the last beat."""
        with self._lock:
            return self._clock() - self._last


class HeartbeatMonitor:
    """Declares a worker stalled after ``timeout_s`` without a beat.

    :meth:`check` is pull-based (call it wherever you would otherwise block
    on the worker); the first detection latches, fires ``on_stall(report)``
    once, and every later :meth:`assert_alive` keeps raising — a stalled
    miner is *reported*, not silently waited on (ROADMAP "elastic mining").
    """

    def __init__(self, heartbeat: Heartbeat, timeout_s: float,
                 on_stall: Optional[Callable[[dict], None]] = None,
                 name: str = "worker"):
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {timeout_s}")
        self.heartbeat = heartbeat
        self.timeout_s = float(timeout_s)
        self.on_stall = on_stall
        self.name = name
        self._stalled = False
        self._lock = threading.Lock()

    @property
    def stalled(self) -> bool:
        return self._stalled

    def report(self) -> dict:
        return {"name": self.name, "age_s": self.heartbeat.age(),
                "timeout_s": self.timeout_s,
                "last_step": self.heartbeat.last_step}

    def check(self) -> bool:
        """True once the worker is stalled (latched; ``on_stall`` fires on
        the first detection only)."""
        if self._stalled:
            return True
        if self.heartbeat.age() <= self.timeout_s:
            return False
        with self._lock:
            if self._stalled:
                return True
            self._stalled = True
            hook = self.on_stall
        if hook is not None:
            hook(self.report())
        return True

    def assert_alive(self) -> None:
        if self.check():
            r = self.report()
            raise WriterStalledError(
                f"{self.name} stalled: no heartbeat for {r['age_s']:.2f}s "
                f"(timeout {self.timeout_s:.2f}s, last step "
                f"{r['last_step']})")


@dataclasses.dataclass
class RunnerConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_retries_per_step: int = 2
    fail_injector: Optional[Callable[[int], bool]] = None  # tests: step -> bool


class TrainingRunner:
    """Drives step() with checkpoint/restart semantics."""

    def __init__(self, cfg: RunnerConfig, step_fn, params, opt_state,
                 batch_fn, shardings=None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.batch_fn = batch_fn          # step index -> batch (deterministic)
        self.shardings = shardings
        self.ckpt = AsyncCheckpointer(cfg.checkpoint_dir, keep=cfg.keep)
        self.start_step = 0
        self.metrics_log: list = []
        self.heartbeat = Heartbeat()   # beaten per completed step; a
        # supervisor attaches a HeartbeatMonitor to spot a hung step_fn

    def maybe_restore(self):
        step = latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return 0
        state = {"params": self.params, "opt": self.opt_state}
        restored, manifest = restore_checkpoint(
            self.cfg.checkpoint_dir, step, state, self.shardings)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.start_step = int(manifest["step"])
        return self.start_step

    def run(self, n_steps: int):
        step = self.maybe_restore()
        end = step + n_steps if self.start_step == 0 else self.start_step + n_steps
        while step < end:
            batch = self.batch_fn(step)
            retries = 0
            while True:
                try:
                    if self.cfg.fail_injector and self.cfg.fail_injector(step) \
                            and retries == 0:
                        raise RuntimeError(f"injected failure at step {step}")
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params, self.opt_state, batch)
                    break
                except RuntimeError:
                    retries += 1
                    if retries > self.cfg.max_retries_per_step:
                        # full restart-from-checkpoint path
                        restored = latest_step(self.cfg.checkpoint_dir)
                        if restored is None:
                            raise
                        step = self.maybe_restore()
                        batch = self.batch_fn(step)
                        retries = 0
            self.metrics_log.append(
                {k: float(np.asarray(v)) for k, v in metrics.items()})
            step += 1
            self.heartbeat.beat(step)
            if step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, {"params": self.params, "opt": self.opt_state})
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state})
        self.ckpt.wait()
        return step
