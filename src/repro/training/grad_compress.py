"""Gradient compression for the data-parallel all-reduce.

Two schemes, both with error feedback (the residual of the lossy round-trip
is added to the next step's gradient, which is what makes compressed SGD
converge — Karimireddy et al., 2019):

  int8:  per-leaf absmax scaling -> int8 quantize -> psum -> dequantize.
         ~4x less DP all-reduce traffic than fp32 (2x vs bf16).
  topk:  keep the largest k-fraction of entries (magnitude), psum the sparse
         residual densely-masked.  Traffic model only (the mask still moves);
         included for the convergence harness.

``compressed_psum`` is the shard_map building block; ``make_compressor``
wraps a gradient pytree for the training path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["int8_roundtrip", "topk_mask", "make_compressor", "compressed_psum"]


def int8_roundtrip(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantize to int8 with per-tensor absmax scale; return (dequant, err)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def topk_mask(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    flat = jnp.abs(gf).reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    kept = jnp.where(jnp.abs(gf) >= thresh, gf, 0.0)
    return kept, gf - kept


def make_compressor(method: str, topk_frac: float = 0.05):
    """Returns (init_err, apply) where apply(grads, err) -> (grads', err')."""
    if method == "none":
        return (lambda params: None,
                lambda grads, err: (grads, err))

    def init_err(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(grads, err):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            if method == "int8":
                deq, new_e = int8_roundtrip(g)
            elif method == "topk":
                deq, new_e = topk_mask(g, topk_frac)
            else:
                raise ValueError(method)
            return deq, new_e
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return init_err, apply


def compressed_psum(x: jax.Array, axis: str, method: str = "int8"):
    """shard_map building block: lossy-compress, psum over ``axis``, mean.

    int8 path psums the *int32-upcast* quantized values (additive), then
    rescales by the max scale — the standard 1-pass approximation (scales are
    psum-maxed first so the quantization grid is shared)."""
    if method == "none":
        return jax.lax.pmean(x, axis)
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total.astype(jnp.float32) * scale / n
