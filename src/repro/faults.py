"""Deterministic kill points for fault-injection testing.

The resilience contract (DESIGN.md §10) is proven by crashing the miner at
*phase boundaries* — mid-append, mid-evict, between the level-2 delta and the
deep expansion, mid-checkpoint-write — and restoring from the latest durable
checkpoint.  Wall-clock kills (SIGKILL after a sleep) make that test flaky
and under-specified; instead, the production code names its boundaries with
:func:`kill_point` calls and the test harness (tests/faultinject.py) installs
a hook that raises :class:`InjectedFault` at exactly the Nth hit of a named
point.  With no hook installed a kill point is one ``is None`` check — the
hot path pays nothing.

This is the moral equivalent of Spark's own fault-injection listeners: the
kill is deterministic in (point name, occurrence count), never in thread or
checkpoint-writer scheduling, which is what lets CI run the recovery suite
5x without flakes.
"""
from __future__ import annotations

from typing import Callable, Optional

__all__ = ["InjectedFault", "kill_point", "set_kill_hook", "clear_kill_hook"]


class InjectedFault(RuntimeError):
    """Raised by a test hook to simulate a crash at a named kill point."""


_hook: Optional[Callable[[str], None]] = None


def set_kill_hook(hook: Callable[[str], None]) -> None:
    """Install ``hook(name)`` to run at every kill point (tests only)."""
    global _hook
    _hook = hook


def clear_kill_hook() -> None:
    global _hook
    _hook = None


def kill_point(name: str) -> None:
    """Named phase boundary; a no-op unless a test hook is installed.

    The hook may raise (typically :class:`InjectedFault`) to simulate the
    process dying at this exact point.
    """
    h = _hook
    if h is not None:
        h(name)
