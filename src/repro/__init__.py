"""repro — RDD-Eclat (Singh et al. 2021) as a multi-pod JAX/TPU framework.

Subpackages:
  core      the paper's contribution: RDD-Eclat variants v1..v6 + Apriori baseline
  kernels   Pallas TPU kernels (popcount support, trimatrix, flash attention)
  models    LM substrate: 10 assigned architectures
  configs   architecture + mining configs
  training  optimizer / train step / checkpoint / compression / fault tolerance
  serving   KV cache + prefill/decode engine
  dist      sharding rules + collectives
  data      transaction generators (paper datasets) + LM token pipeline
  launch    mesh / dryrun / train / serve / mine drivers
  analysis  roofline derivation from compiled HLO
"""
__version__ = "1.0.0"
