"""Sharding rules: mesh registry, dp axes, parameter/batch placement.

This is the contract between the mining/model core and every scaled
workload (DESIGN.md §2).  The mesh carries at most four axis names:

  pod, data   gradient-reduction ("data-parallel") axes — batches and expert
              blocks split here; ``dp_axes`` returns them in mesh order
  model       tensor-parallel axis — matmul weights split here
  pipe        reserved for deeper topologies; never used by the rules

Placement is *rule-based over parameter path + shape*, never stored with the
checkpoint, so checkpoints stay mesh-agnostic (elastic reshard) and a config
change re-derives the whole plan.  Rules follow Megatron conventions:
column-parallel weights (wq/wk/wv, w_up/w_gate, *_in_proj) split their
output dim over 'model'; row-parallel weights (wo, w_down, *_out_proj)
split their input dim; experts split over the EP (data) axis with d_ff over
'model' (or over (data, model) jointly for ``expert_sharding="tp2d"``);
norms, biases, routers and other small leaves replicate.  Every rule is
divisibility-guarded: a dim that doesn't divide its axis stays replicated
(e.g. whisper's 51865 vocab on a 16-wide model axis).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["set_mesh", "get_mesh", "reset_mesh", "dp_axes", "constrain",
           "param_spec", "batch_spec", "spec_tree", "sharding_tree",
           "word_shard_spec", "padded_word_count", "shard_words",
           "grid_pair_spec", "grid_block_spec", "mesh_descriptor"]

# axis names that count as gradient-reduction ("data-parallel") axes
DP_AXIS_NAMES = ("pod", "data")

# ---------------------------------------------------------------------------
# mesh registry
# ---------------------------------------------------------------------------

_MESH: Optional[jax.sharding.Mesh] = None


def set_mesh(mesh) -> Any:
    """Register ``mesh`` as the process-wide mesh (None to clear).

    Model code reads it back through :func:`get_mesh` at trace time, so the
    launch layer sets it once before building/jitting a step.
    """
    global _MESH
    _MESH = mesh
    return mesh


def get_mesh():
    """The registered mesh, else the active ``with mesh:`` context, else None."""
    if _MESH is not None:
        return _MESH
    try:  # thread-local context mesh (private path, stable across 0.4/0.5)
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


def reset_mesh() -> None:
    """Clear the registry (tests; single-device paths)."""
    set_mesh(None)


def mesh_descriptor(mesh) -> Optional[dict]:
    """Logical description of a mesh — ``{"axes": [...], "shape": [...]}`` —
    for checkpoint provenance (DESIGN.md §10).  Device placement is never
    restored *from* this: a checkpoint re-places its logical arrays under
    whatever mesh the restoring process brings (that is what makes live
    re-meshing work); the descriptor only records where the state ran so
    tools and benches can report 4->2 / 2x2->4x1 transitions.
    """
    if mesh is None or getattr(mesh, "empty", False):
        return None
    axes = [str(a) for a in mesh.axis_names]
    return {"axes": axes, "shape": [int(mesh.shape[a]) for a in axes]}


# ---------------------------------------------------------------------------
# axes + activation constraints
# ---------------------------------------------------------------------------

def dp_axes(mesh=None) -> Tuple[str, ...]:
    """Gradient-reduction axis names in mesh order; ("data",) without a mesh
    (the spec is then only ever used inside specs that a missing mesh makes
    a no-op)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return ("data",)
    dp = tuple(a for a in mesh.axis_names if a in DP_AXIS_NAMES)
    return dp or ("data",)


def _entry_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def _sanitize(spec: P, shape, mesh) -> P:
    """Drop spec entries naming absent axes or not dividing their dim."""
    names = set(mesh.axis_names)
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        axes = _entry_axes(entry)
        if not axes or not all(a in names for a in axes):
            out.append(None)
            continue
        size = math.prod(mesh.shape[a] for a in axes)
        if size and dim % size == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def constrain(x, spec: P):
    """``with_sharding_constraint`` against the registered/active mesh;
    identity when no mesh is set (single-device paths, host tests)."""
    mesh = get_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return x
    spec = _sanitize(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# word-axis (tid) sharding for packed bitmaps
# ---------------------------------------------------------------------------
#
# A packed vertical bitmap is (n_items, n_words) uint32 with transactions
# along the *word* axis.  Tid-sharded mining (DESIGN.md §7) splits that axis
# across the mesh: each device holds every item's row but only a word slice,
# so per-device frontier memory is total/n_shards — the axis the paper
# scales (database size) stops being bounded by one device.  Popcount is
# additive across word slices, so supports are recovered with one psum.
# On the 2D ("class", "data") grid mesh (DESIGN.md §8) the same
# P(None, "data") spec replicates the frontier over the class axis for free
# — the spec never names "class" — while the pair/block specs below give the
# grid engine its class-axis half.


def word_shard_spec(axis: str = "data") -> P:
    """PartitionSpec for a (rows, words) bitmap sharded on its word axis —
    ``P(None, axis)``: rows replicated, transaction words split."""
    return P(None, axis)


def padded_word_count(n_words: int, n_shards: int) -> int:
    """Smallest word count >= ``n_words`` divisible by ``n_shards`` (zero pad
    words carry no set bits, so supports are unchanged)."""
    n_shards = max(int(n_shards), 1)
    return max(int(n_words), 0) + (-int(n_words)) % n_shards


def grid_pair_spec(class_axis: str = "class") -> P:
    """PartitionSpec for a flattened ``(n_class * qmax,)`` padded pair block
    on the 2D grid mesh (DESIGN.md §8): split over the class axis, replicated
    over every other axis (each word shard sees its class shard's pairs)."""
    return P(class_axis)


def grid_block_spec(class_axis: str = "class", data_axis: str = "data") -> P:
    """PartitionSpec for the ``(rows, words)`` intersection block the grid
    engine produces — rows split by class shard, words by word shard, so no
    device ever materializes more than a ``1/(n_class * n_data)`` tile."""
    return P(class_axis, data_axis)


def shard_words(arr, mesh, axis: str = "data"):
    """Place a (rows, n_words) bitmap on ``mesh`` with its word axis sharded.

    Pads the word axis with zero words up to a multiple of the axis size
    (popcount-neutral) and returns a committed ``NamedSharding(mesh,
    P(None, axis))`` array.
    """
    import jax.numpy as jnp

    arr = jnp.asarray(arr)
    if arr.ndim != 2:
        raise ValueError(f"expected a (rows, words) bitmap, got {arr.shape}")
    n_shards = int(mesh.shape[axis])
    wp = padded_word_count(arr.shape[1], n_shards)
    if wp != arr.shape[1]:
        arr = jnp.pad(arr, ((0, 0), (0, wp - arr.shape[1])))
    return jax.device_put(arr, NamedSharding(mesh, word_shard_spec(axis)))


# ---------------------------------------------------------------------------
# parameter placement rules
# ---------------------------------------------------------------------------

def _axis_size(mesh, name: str) -> int:
    try:
        return int(mesh.shape[name])
    except Exception:
        return 0


def _repl(shape) -> P:
    return P(*([None] * len(shape)))


# row-parallel projections: input dim (-2) over 'model'
_ROW_NAMES = ("wo", "xwo", "w_down", "out_proj")
# leaves that always replicate regardless of shape
_REPLICATED_NAMES = ("router", "enc_pos", "conv", "a_log")


def _leaf_name(path: str) -> str:
    name = path.split("/")[-1]
    if name.startswith("stk_"):
        name = name[4:]
    return name


def param_spec(path: str, shape, mesh, expert_sharding: str = "ep",
               mlp_dp: bool = False) -> P:
    """Placement rule for one parameter leaf.

    ``path`` is the '/'-joined pytree path (e.g. "stages/s0/stk_wq"),
    ``shape`` the leaf shape (a leading stack dim from the stage compiler is
    transparent), ``mesh`` anything with ``.axis_names`` and a ``.shape``
    mapping.  ``expert_sharding``: "ep"/"ep_pad" split experts over the last
    dp axis with d_ff over 'model'; "tp2d" leaves experts replicated and
    splits d_ff over (data, model) jointly.  ``mlp_dp`` replicates the dense
    FFN weights (the seq-parallel data-parallel-FFN posture, see models.mlp).
    """
    name = _leaf_name(path)
    names = set(mesh.axis_names)
    m = _axis_size(mesh, "model") if "model" in names else 0

    def over_model(dim: int) -> bool:
        return m > 0 and dim % m == 0

    # --- always-replicated leaves ---------------------------------------
    if len(shape) == 0 or any(t in name for t in ("norm", "scale", "bias")):
        return _repl(shape)
    if any(name == t or name.endswith(t) for t in _REPLICATED_NAMES):
        return _repl(shape)

    # --- embedding / unembedding ----------------------------------------
    if name == "embed":
        # vocab over 'model' (chunked loss reduces over it); replicate when
        # the vocab doesn't divide (whisper's 51865)
        if over_model(shape[0]):
            return P("model", *([None] * (len(shape) - 1)))
        return _repl(shape)
    if name == "lm_head":
        if over_model(shape[-1]):
            return P(*([None] * (len(shape) - 1)), "model")
        return _repl(shape)

    # --- experts ----------------------------------------------------------
    if "experts" in name:
        # (stack?, E, d_in, d_ff) for up/gate, (stack?, E, d_ff, d_out) down
        entries = [None] * len(shape)
        ff_dim = len(shape) - 1 if name.endswith(("up", "gate")) else len(shape) - 2
        if expert_sharding == "tp2d":
            axes = tuple(a for a in (*dp_axes(mesh), "model") if a in names)
            size = math.prod(_axis_size(mesh, a) for a in axes) if axes else 0
            if axes and size and shape[ff_dim] % size == 0:
                entries[ff_dim] = axes if len(axes) > 1 else axes[0]
            return P(*entries)
        ep_axis = dp_axes(mesh)[-1]
        e_dim = len(shape) - 3
        if ep_axis in names and shape[e_dim] % max(_axis_size(mesh, ep_axis), 1) == 0:
            entries[e_dim] = ep_axis
        if over_model(shape[ff_dim]):
            entries[ff_dim] = "model"
        return P(*entries)

    # --- dense FFN under mlp_dp: replicate over 'model' -------------------
    if mlp_dp and name in ("w_up", "w_gate", "w_down"):
        return _repl(shape)

    # --- row-parallel (output projections): input dim over 'model' --------
    if len(shape) >= 2 and any(name == t or name.endswith(t) for t in _ROW_NAMES):
        if over_model(shape[-2]):
            entries = [None] * len(shape)
            entries[-2] = "model"
            return P(*entries)
        return _repl(shape)

    # --- column-parallel (everything else >= 2D): output dim over 'model' -
    if len(shape) >= 2 and over_model(shape[-1]):
        entries = [None] * len(shape)
        entries[-1] = "model"
        return P(*entries)
    return _repl(shape)


# ---------------------------------------------------------------------------
# batch + tree-level rules
# ---------------------------------------------------------------------------

def batch_spec(batch: int, mesh=None) -> P:
    """Leading-axis spec for a global batch: split over the dp axes when the
    batch divides them, else replicate (odd calibration batches)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return P(None)
    dp = dp_axes(mesh)
    size = math.prod(_axis_size(mesh, a) for a in dp)
    if size and batch % size == 0:
        return P(dp if len(dp) > 1 else dp[0])
    return P(None)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_tree(tree, mesh, expert_sharding: str = "ep", mlp_dp: bool = False):
    """Map :func:`param_spec` over a parameter pytree -> tree of P."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(_path_str(path), leaf.shape, mesh,
                                      expert_sharding, mlp_dp),
        tree)


def sharding_tree(tree, mesh, expert_sharding: str = "ep",
                  mlp_dp: bool = False):
    """Same rules as :func:`spec_tree` but as NamedSharding leaves, ready for
    ``jax.device_put`` / ``jit(in_shardings=...)``."""
    specs = spec_tree(tree, mesh, expert_sharding, mlp_dp)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
