"""Device-mesh sharding layer (DESIGN.md §2).

The paper's scalability property — equivalence classes partitioned once,
mined communication-free per executor — maps onto JAX as a mesh + a small
set of placement rules:

  compat    jax-version shims (make_mesh / shard_map / AxisType)
  sharding  mesh registry, data-parallel axes, parameter/batch placement
            rules, activation sharding constraints

Everything model- and launch-side goes through :mod:`repro.dist.sharding`;
everything that touches a drifting jax API goes through
:mod:`repro.dist.compat`.
"""
from .compat import AxisType, make_mesh, shard_map
from .sharding import (batch_spec, constrain, dp_axes, get_mesh,
                       padded_word_count, param_spec, reset_mesh, set_mesh,
                       shard_words, sharding_tree, spec_tree, word_shard_spec)

__all__ = [
    "AxisType", "make_mesh", "shard_map",
    "batch_spec", "constrain", "dp_axes", "get_mesh", "param_spec",
    "reset_mesh", "set_mesh", "sharding_tree", "spec_tree",
    "word_shard_spec", "padded_word_count", "shard_words",
]
