"""jax version-drift shims for the dist layer.

The repo targets the jax.sharding API as of jax >= 0.5 (``AxisType``,
``jax.make_mesh(..., axis_types=...)``, top-level ``jax.shard_map``) while
remaining runnable on jax 0.4.x, where none of those exist yet.  Every call
site that would otherwise touch a drifting symbol goes through this module:

  make_mesh   ``jax.make_mesh`` with ``axis_types`` accepted on every version
              (silently dropped on 0.4.x, where all mesh axes are Auto-like)
  shard_map   ``jax.shard_map`` on >= 0.5/0.6, else
              ``jax.experimental.shard_map.shard_map``
  AxisType    the real enum when available, else a stand-in with the same
              member names so ``AxisType.Auto`` spells the same everywhere
"""
from __future__ import annotations

import enum

import jax

__all__ = ["AxisType", "make_mesh", "shard_map", "HAS_AXIS_TYPES"]

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")

if HAS_AXIS_TYPES:
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on jax 0.4.x."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that accepts ``axis_types`` on every jax version.

    On jax >= 0.5 the types are forwarded (defaulting every axis to Auto, the
    GSPMD-propagation behaviour the whole codebase assumes).  On 0.4.x the
    argument is dropped — meshes there are implicitly Auto.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401
