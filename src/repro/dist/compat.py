"""jax version-drift shims for the dist layer.

The repo targets the jax.sharding API as of jax >= 0.5 (``AxisType``,
``jax.make_mesh(..., axis_types=...)``, top-level ``jax.shard_map``) while
remaining runnable on jax 0.4.x, where none of those exist yet.  Every call
site that would otherwise touch a drifting symbol goes through this module:

  make_mesh   ``jax.make_mesh`` with ``axis_types`` accepted on every version
              (silently dropped on 0.4.x, where all mesh axes are Auto-like)
  shard_map   ``jax.shard_map`` on >= 0.5/0.6, else
              ``jax.experimental.shard_map.shard_map``
  AxisType    the real enum when available, else a stand-in with the same
              member names so ``AxisType.Auto`` spells the same everywhere
"""
from __future__ import annotations

import enum
import inspect

import jax

__all__ = ["AxisType", "make_mesh", "shard_map", "shard_map_unchecked",
           "HAS_AXIS_TYPES"]

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")

if HAS_AXIS_TYPES:
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on jax 0.4.x."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that accepts ``axis_types`` on every jax version.

    On jax >= 0.5 the types are forwarded (defaulting every axis to Auto, the
    GSPMD-propagation behaviour the whole codebase assumes).  On 0.4.x the
    argument is dropped — meshes there are implicitly Auto.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def _rep_check_flag():
    try:
        params = inspect.signature(shard_map).parameters
    except (TypeError, ValueError):
        return None
    for name in ("check_rep", "check_vma"):
        if name in params:
            return name
    return None


_REP_CHECK_FLAG = _rep_check_flag()


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled, across versions.

    ``pallas_call`` has no replication rule, so a shard-mapped Pallas kernel
    (the sharded mining engine's fused inner executor) must opt out of the
    check.  The flag is ``check_rep`` on jax <= 0.6 and ``check_vma`` later;
    the flag name is resolved from ``shard_map``'s signature at import time,
    so an unknown rename fails loudly here instead of as an opaque
    replication-rule error inside the first sharded kernel launch.
    """
    if _REP_CHECK_FLAG is None:
        raise NotImplementedError(
            "this jax version's shard_map exposes neither check_rep nor "
            "check_vma; teach dist.compat._rep_check_flag its new name")
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **{_REP_CHECK_FLAG: False})
