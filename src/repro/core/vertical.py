"""Vertical database construction (Phase-1/2/3 of the paper's variants).

Three construction paths mirror the paper:

* :func:`build_vertical` — EclatV1 Phase-1: scatter the horizontal DB into a
  packed bitmap, compute item supports, keep frequent items.
* :func:`filter_transactions` — EclatV2 Phase-2: Borgelt's filtered
  transactions; here a bitmap compaction (drop infrequent item rows, drop
  transaction columns that became empty, optionally re-sort items).
* :func:`build_vertical_accumulated` — EclatV3 Phase-3: the accumulator-built
  vertical DB; semantically identical output, produced through the
  ``repro.core.accumulator`` psum path so the V3 lineage is honest.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from . import bitmap as bm

__all__ = ["VerticalDB", "build_vertical", "filter_transactions", "sort_items"]


@dataclasses.dataclass
class VerticalDB:
    """Frequent-item vertical database.

    Attributes:
      bitmaps:   (n_freq, W) uint32 packed tidsets, row order == ``items`` order.
      items:     (n_freq,) original item ids for each row.
      supports:  (n_freq,) int64 item supports.
      n_txn:     number of (possibly compacted) transaction columns.
      order:     how ``items`` rows are sorted ("support_asc" | "lex").
    """

    bitmaps: np.ndarray
    items: np.ndarray
    supports: np.ndarray
    n_txn: int
    order: str = "support_asc"

    @property
    def n_items(self) -> int:
        return int(self.items.shape[0])

    @property
    def n_words(self) -> int:
        return int(self.bitmaps.shape[1])

    def validate(self) -> None:
        # a real integrity check, not an ``assert`` — it must also hold
        # under ``python -O`` (staticcheck RS001)
        want = (self.items.shape[0], bm.n_words(self.n_txn))
        if self.bitmaps.shape != want:
            raise RuntimeError(
                f"vertical bitmap shape drifted: expected {want}, got "
                f"{self.bitmaps.shape}")
        np.testing.assert_array_equal(bm.support_np(self.bitmaps), self.supports)


def sort_items(items: np.ndarray, supports: np.ndarray, order: str):
    """Total order used for equivalence-class construction.

    ``support_asc`` (paper: "sorted ... by the total order of increasing
    support count") breaks ties lexicographically so the order is
    deterministic.  ``lex`` is the alphanumeric order of EclatV2 Phase-1.
    """
    if order == "support_asc":
        perm = np.lexsort((items, supports))
    elif order == "lex":
        perm = np.argsort(items, kind="stable")
    else:
        raise ValueError(f"unknown item order {order!r}")
    return perm


def build_vertical(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    min_sup: int,
    order: str = "support_asc",
) -> VerticalDB:
    """EclatV1 Phase-1: horizontal -> packed vertical DB of frequent items."""
    packed = bm.pack_transactions(transactions, n_items)
    supports = bm.support_np(packed)
    freq_mask = supports >= int(min_sup)
    items = np.nonzero(freq_mask)[0].astype(np.int64)
    packed = packed[freq_mask]
    supports = supports[freq_mask]
    perm = sort_items(items, supports, order)
    return VerticalDB(
        bitmaps=packed[perm],
        items=items[perm],
        supports=supports[perm],
        n_txn=len(transactions),
        order=order,
    )


def filter_transactions(db: VerticalDB, drop_empty_cols: bool = True) -> VerticalDB:
    """EclatV2's filtered-transaction technique as bitmap compaction.

    The infrequent item *rows* are already gone after ``build_vertical``; the
    remaining saving — exactly the paper's observation that filtering only
    pays when the DB shrinks "significantly" — is removing transaction
    columns containing no frequent item, which shrinks W for every later AND.
    """
    if not drop_empty_cols:
        return db
    # word-level column occupancy: OR-reduce the rows, then test each
    # transaction's bit — no dense (n_items, n_txn) matrix is materialized
    orred = np.bitwise_or.reduce(db.bitmaps, axis=0) if db.n_items else np.zeros(
        bm.n_words(db.n_txn), db.bitmaps.dtype)
    t = np.arange(db.n_txn)
    touched = ((orred[t // bm.WORD_BITS] >> (t % bm.WORD_BITS).astype(orred.dtype)) & 1).astype(bool)
    if touched.all():
        return db  # nothing to compact; avoid a useless repack
    compact, kept = bm.column_compact(db.bitmaps, db.n_txn, touched)
    return VerticalDB(
        bitmaps=compact,
        items=db.items,
        supports=db.supports,
        n_txn=kept,
        order=db.order,
    )


def filtering_reduction(db_before: VerticalDB, db_after: VerticalDB) -> float:
    """Fraction of transaction columns removed by filtering (paper §5.2.1
    reports e.g. 3.2%..25.8% for T40I10D100K)."""
    if db_before.n_txn == 0:
        return 0.0
    return 1.0 - db_after.n_txn / db_before.n_txn
