"""Spark-Apriori (YAFIM-like) baseline, in the same substrate.

The paper compares RDD-Eclat against a YAFIM-style Spark Apriori.  To keep
the comparison meaningful here, this baseline keeps Apriori's defining costs:

  * level-wise candidate generation with subset pruning (host, like the
    driver's hash-tree build), and
  * support counting by re-scanning the *horizontal* database every level —
    a (n_txn x n_items) @ (n_items x n_cands) containment matmul, the dense
    analogue of "each transaction probes the broadcast hash tree".

No tidset memoization crosses levels — that is exactly the advantage Eclat
keeps for itself.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import bitmap as bm

__all__ = ["AprioriResult", "apriori_mine"]


@dataclasses.dataclass
class AprioriResult:
    support_map: Dict[Tuple[int, ...], int]
    counts: List[int]
    stats: dict

    @property
    def total(self) -> int:
        return sum(self.counts)


@partial(jax.jit, static_argnames=("k",))
def _containment_counts(txn_f32: jax.Array, cand_mask: jax.Array, k: int) -> jax.Array:
    """counts[c] = #transactions containing all k items of candidate c.

    txn_f32:   (n_txn, n_items) 0/1
    cand_mask: (Q, n_items)     0/1
    """
    hits = txn_f32 @ cand_mask.T            # (n_txn, Q) — the full-DB rescan
    return (hits >= float(k)).astype(jnp.int32).sum(axis=0)


def _gen_candidates(prev: List[Tuple[int, ...]], prev_set: set, k: int) -> List[Tuple[int, ...]]:
    """F(k-1) x F(k-1) join on a common (k-2)-prefix + subset pruning."""
    cands: List[Tuple[int, ...]] = []
    n = len(prev)
    i = 0
    while i < n:
        j = i + 1
        while j < n and prev[i][:-1] == prev[j][:-1]:
            cand = prev[i] + (prev[j][-1],)
            # prune: all (k-1)-subsets frequent
            ok = all(
                cand[:m] + cand[m + 1:] in prev_set for m in range(k)
            )
            if ok:
                cands.append(cand)
            j += 1
        i += 1
    return cands


def apriori_mine(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    min_sup: float,
    max_k: int | None = None,
    cand_chunk: int = 8192,
) -> AprioriResult:
    t_start = time.perf_counter()
    # same boundary semantics as the Eclat drivers (the differential-oracle
    # contract): max_k >= 1 or None, never silently coerced
    if max_k is not None and max_k < 1:
        raise ValueError(f"max_k must be >= 1 (or None for unbounded), "
                         f"got {max_k}")
    if cand_chunk < 1:
        raise ValueError(f"cand_chunk must be >= 1, got {cand_chunk}")
    n_txn = len(transactions)
    # same type-based fraction/count disambiguation as Eclat, so the
    # baseline and the paper variants stay comparable at any threshold
    from .eclat import resolve_min_sup
    abs_min_sup = resolve_min_sup(min_sup, n_txn)

    # Phase 1 (YAFIM): frequent items — single pass
    packed = bm.pack_transactions(transactions, n_items)
    sup1 = bm.support_np(packed)
    freq = np.nonzero(sup1 >= abs_min_sup)[0]
    item_of_col = freq.astype(np.int64)
    col_of_item = {int(it): c for c, it in enumerate(item_of_col)}
    n1 = freq.shape[0]

    support_map: Dict[Tuple[int, ...], int] = {
        (int(it),): int(sup1[it]) for it in freq
    }
    counts = [n1]
    stats = {"abs_min_sup": abs_min_sup, "n_freq_items": n1, "level_s": []}
    if n1 < 2:
        stats["total_s"] = time.perf_counter() - t_start
        return AprioriResult(support_map, counts, stats)

    # horizontal DB over frequent columns only (YAFIM keeps the RDD cached)
    dense = bm.unpack_bitmap(packed[freq], n_txn)       # (n1, n_txn)
    txn_f32 = jnp.asarray(dense.T, dtype=jnp.float32)   # (n_txn, n1)

    frequent_prev: List[Tuple[int, ...]] = sorted((int(c),) for c in range(n1))
    k = 1
    # NOT `max_k or n1`: with the old truthiness coercion an (invalid but
    # accepted) max_k=0 silently meant "unbounded" — the opposite direction
    kmax = n1 if max_k is None else max_k
    while frequent_prev and k < kmax:
        k += 1
        t0 = time.perf_counter()
        prev_set = set(frequent_prev)
        cands = _gen_candidates(frequent_prev, prev_set, k)
        if not cands:
            break
        survivors: List[Tuple[int, ...]] = []
        for s in range(0, len(cands), cand_chunk):
            chunk = cands[s: s + cand_chunk]
            mask = np.zeros((len(chunk), n1), np.float32)
            for r, cand in enumerate(chunk):
                mask[r, list(cand)] = 1.0
            cnt = np.asarray(_containment_counts(txn_f32, jnp.asarray(mask), k))
            for r, cand in enumerate(chunk):
                if cnt[r] >= abs_min_sup:
                    survivors.append(cand)
                    support_map[tuple(sorted(int(item_of_col[c]) for c in cand))] = int(cnt[r])
        stats["level_s"].append(time.perf_counter() - t0)
        counts.append(len(survivors))
        if not survivors:
            counts.pop()
            break
        frequent_prev = sorted(survivors)

    stats["total_s"] = time.perf_counter() - t_start
    return AprioriResult(support_map, counts, stats)
