"""The device-resident mining engine: pluggable executors for the Eclat hot loop.

``core.eclat.mine`` is pure driver logic (class segmentation, partition
tables, store bookkeeping); every device-side intersection goes through the
backend interface defined here.  A backend turns one level-expansion request

    (frontier bitmaps, pair lists, parent supports, mode, min_sup)

into a :class:`LevelResult`: the survivor mask and supports for the driver
plus the survivor bitmaps, compacted *on device* — the padded ``(Q, W)``
intersection never crosses the host boundary.

Backends (``register_backend`` registry, selected by ``EclatConfig.backend``):

  jnp      reference executor — ``jnp.take`` gather + AND + popcount, the
           semantics every other backend must match bit-exactly.
  pallas   fused executor — one ``pallas_call`` (kernels.fused_intersect)
           gathers rows by scalar-prefetch index maps, intersects, popcounts
           and applies the min-support threshold in a single kernel on TPU;
           off-TPU it dispatches to the identically-fused jnp path.  Default.
  sharded  shard_map-over-either: pairs are grouped by the device their
           equivalence class was partitioned to, padded per device to a
           common bucket, and executed under ``shard_map`` — the paper's
           executor-task mapping.  Constructed automatically when ``mine``
           receives a mesh.
  tidsharded  word-sharded (tid-axis) execution: the frontier bitmap is
           carried as ``P(None, "data")`` — every device holds all rows but
           only a word slice — each shard intersects and popcounts its
           slice, supports are recovered with one psum, and survivor
           compaction stays shard-local.  Per-device frontier memory is
           total/n_shards, so windows larger than one device's memory stay
           minable (DESIGN.md §7).  Selected by ``shard="words"``.
  grid     grid-sharded execution on a 2D ``("class", "data")`` mesh:
           candidate pairs split over the class axis (as in ``sharded``)
           AND the frontier's word axis split over the data axis (as in
           ``tidsharded``), so per-device pair work drops ~1/n_class and
           per-device frontier memory ~1/n_data at the same time — the
           first backend that composes both shard_map axes (DESIGN.md §8).
           Selected by ``shard="grid"``.

Axis ownership (who interprets what): ``device_of_pair`` always routes over
the backend's *pair* axis (``n_devices`` wide — the class axis for
``sharded``/``grid``, trivial for the rest); ``prepare_frontier``/``_take``
own the *word* axis placement (``P(None, data)`` for ``tidsharded``/
``grid``, identity otherwise); ``_compact`` is axis-agnostic and delegates
the row gather to ``_take``.  The shared helpers ``group_pairs_by_device``
and ``_WordShardedFrontierMixin`` implement one axis each, so a backend
composes them instead of copy-pasting an engine.

Bucket ladder: pair batches are padded up to a power-of-two ladder
(``bucket_min * 2**k``), so every XLA/Mosaic executable is compiled once per
rung and reused across levels; the padded host-side index buffers themselves
are persistent per rung (no per-call allocation or ``argsort`` churn for the
single-device backends).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Type

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..dist.compat import shard_map, shard_map_unchecked
from ..dist.sharding import (grid_block_spec, grid_pair_spec, shard_words,
                             word_shard_spec)
from ..kernels.fused_intersect import (MODE_DIFFSET, MODE_TID_TO_DIFF,
                                       MODE_TIDSET, fused_intersect,
                                       fused_intersect_partial,
                                       fused_intersect_partial_ref,
                                       fused_intersect_ref)

__all__ = [
    "MODE_TIDSET", "MODE_TID_TO_DIFF", "MODE_DIFFSET",
    "LevelResult", "Engine", "JnpEngine", "PallasEngine", "ShardedEngine",
    "TidShardedEngine", "GridShardedEngine", "group_pairs_by_device",
    "register_backend", "available_backends", "make_engine", "resolve_engine",
]


# ---------------------------------------------------------------------------
# result type + bucket-ladder pair buffers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LevelResult:
    """One level expansion, already min-support filtered.

    mask:     (Q,) bool — which input pairs survived, in input pair order.
    supports: (S,) int64 — supports of the survivors (S = mask.sum()).
    bitmaps:  (Sb, W) uint32 device array — survivor tidsets/diffsets,
              compacted on device into a power-of-two row rung Sb >= S.
              Rows [:S] are the survivors in mask order; rows [S:] are
              padding (duplicates of row 0) and must not be read.  Padding
              the compaction keeps device shapes on the same bucket ladder
              as the pair batches, so steady-state mining (and every window
              slide of the streaming miner) reuses compiled executables
              instead of recompiling per survivor count.
    """

    mask: np.ndarray
    supports: np.ndarray
    bitmaps: jax.Array


def bucket_size(n: int, floor: int) -> int:
    """Smallest power-of-two ladder rung >= n (>= floor)."""
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return b


class PairBuffers:
    """Persistent bucket-ladder host buffers for padded pair batches.

    One (left, right, sup_left) int32 triple per rung, reused across levels:
    refilling in place avoids the per-call allocation the old executor paid,
    and the power-of-two rungs keep the jit cache to O(log Q) entries.
    """

    def __init__(self, floor: int):
        self.floor = max(int(floor), 1)
        self._rungs: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}

    def fill(self, left: np.ndarray, right: np.ndarray, sup_left: np.ndarray):
        q = int(left.shape[0])
        qb = bucket_size(q, self.floor)
        rung = self._rungs.get(qb)
        if rung is None:
            rung = tuple(np.zeros(qb, np.int32) for _ in range(3))
            self._rungs[qb] = rung
        l, r, s = rung
        l[:q], r[:q], s[:q] = left, right, sup_left
        l[q:] = 0
        r[q:] = 0
        s[q:] = 0
        return qb, l, r, s


def group_pairs_by_device(
    left: np.ndarray,
    right: np.ndarray,
    sup_left: np.ndarray,
    device_of_pair: Optional[np.ndarray],
    n_devices: int,
    floor: int,
) -> Tuple[int, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group candidate pairs by their assigned pair-axis slot and pad every
    slot's block to a shared ladder rung.

    The pair-axis half of the mesh-mapped backends (``sharded`` distributes
    over its one axis, ``grid`` over its class axis): returns ``(qmax, lpad,
    rpad, spad, slot_of_pair, counts)`` where the ``(n_devices, qmax)`` pad
    blocks hold each device's pairs, ``slot_of_pair[q] = dev * qmax + slot``
    maps input pair order to padded-block position, and ``counts`` is the
    per-device pair load (the balance stats input).  Out-of-range device ids
    are refused up front: one would fall outside the grouping loop and leave
    its ``slot_of_pair`` entry uninitialized — garbage slots, silently wrong
    supports.
    """
    q = int(left.shape[0])
    d = int(n_devices)
    if device_of_pair is None:
        device_of_pair = np.zeros(q, np.int64)
    device_of_pair = np.asarray(device_of_pair, np.int64)
    if device_of_pair.shape != (q,):
        raise ValueError(f"device_of_pair must be shape ({q},), got "
                         f"{device_of_pair.shape}")
    if (device_of_pair < 0).any() or (device_of_pair >= d).any():
        bad = device_of_pair[(device_of_pair < 0) | (device_of_pair >= d)]
        raise ValueError(
            f"device_of_pair contains ids outside [0, {d}) for this "
            f"{d}-device pair axis: {np.unique(bad).tolist()[:8]}")
    order = np.argsort(device_of_pair, kind="stable")
    counts = np.bincount(device_of_pair, minlength=d)
    qmax = bucket_size(int(counts.max()), floor)
    lpad = np.zeros((d, qmax), np.int32)
    rpad = np.zeros((d, qmax), np.int32)
    spad = np.zeros((d, qmax), np.int32)
    slot_of_pair = np.empty(q, np.int64)
    off = 0
    for dev in range(d):
        c = int(counts[dev])
        idx = order[off: off + c]
        lpad[dev, :c] = left[idx]
        rpad[dev, :c] = right[idx]
        spad[dev, :c] = sup_left[idx]
        slot_of_pair[idx] = dev * qmax + np.arange(c)
        off += c
    return qmax, lpad, rpad, spad, slot_of_pair, counts


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

BACKENDS: Dict[str, Type["Engine"]] = {}


def register_backend(name: str):
    def deco(cls: Type["Engine"]) -> Type["Engine"]:
        BACKENDS[name] = cls
        cls.name = name
        return cls
    return deco


def available_backends() -> List[str]:
    return sorted(BACKENDS)


def make_engine(
    backend: str,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    bucket_min: int = 1024,
    interpret: Optional[bool] = None,
    inner: str = "pallas",
) -> "Engine":
    """Construct a backend by registry name.

    ``sharded`` / ``tidsharded`` / ``grid`` require a mesh (``grid`` a 2D
    one with ``("class", "data")`` axes); ``interpret`` forces the Pallas
    kernel's interpreter (tests) instead of the TPU/ref dispatch.
    """
    cls = BACKENDS.get(backend)
    if cls is None:
        raise ValueError(f"unknown engine backend {backend!r}; "
                         f"available: {available_backends()}")
    if backend in ("sharded", "tidsharded", "grid"):
        if mesh is None:
            raise ValueError(f"{backend} backend requires a mesh")
        return cls(mesh, bucket_min=bucket_min, inner=inner,
                   interpret=interpret)
    if backend == "pallas":
        return PallasEngine(bucket_min=bucket_min, interpret=interpret)
    return cls(bucket_min=bucket_min)


def resolve_engine(
    backend: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    *,
    bucket_min: int = 1024,
    shard: str = "pairs",
) -> "Engine":
    """Map a (backend name, mesh, shard mode) request onto an engine.

    A mesh always means a mesh-mapped backend, with the named single-device
    backend as its inner executor; ``shard`` picks which axis (or axes) the
    mesh splits: ``"pairs"`` (ShardedEngine — candidate pairs distributed,
    the frontier replicated; the paper's executor mapping), ``"words"``
    (TidShardedEngine — the frontier's word axis distributed, pairs
    replicated; DESIGN.md §7), or ``"grid"`` (GridShardedEngine — pairs
    over a ``"class"`` axis AND words over a ``"data"`` axis of a 2D mesh;
    DESIGN.md §8).  ``"batched"`` and ``"auto"`` are legacy aliases for the
    single-device default (pallas); ``"sharded"`` / ``"tidsharded"`` /
    ``"grid"`` without a mesh degrade gracefully to that default.  Naming a
    mesh-mapped backend implies its shard mode (``sharded`` -> pairs,
    ``tidsharded`` -> words, ``grid`` -> grid); combining one with a
    *different* non-default ``shard`` is contradictory and rejected rather
    than silently resolved to either side.  Both the batch driver
    (``core.eclat.mine``) and the streaming miner (``repro.streaming``)
    resolve their executors here.
    """
    shard_to_backend = {"pairs": "sharded", "words": "tidsharded",
                        "grid": "grid"}
    if shard not in shard_to_backend:
        raise ValueError(f"unknown shard mode {shard!r}; "
                         "expected 'pairs', 'words' or 'grid'")
    if backend in ("batched", "auto"):
        backend = "pallas"
    implied = {"sharded": "pairs", "tidsharded": "words",
               "grid": "grid"}.get(backend)
    if implied is not None:
        # shard="pairs" is the config default, so only an explicit
        # disagreement is a conflict
        if shard not in ("pairs", implied):
            raise ValueError(
                f"backend {backend!r} implies shard={implied!r} but "
                f"shard={shard!r} was requested; drop one of the two")
        shard = implied
    if mesh is not None or backend in ("sharded", "tidsharded", "grid"):
        if mesh is None:
            backend = "pallas"
        else:
            inner = backend if backend in ("jnp", "pallas") else "pallas"
            return make_engine(shard_to_backend[shard], mesh=mesh,
                               bucket_min=bucket_min, inner=inner)
    return make_engine(backend, bucket_min=bucket_min)


class Engine:
    """Backend interface + shared accounting."""

    name = "abstract"

    def __init__(self, bucket_min: int = 1024):
        self.buffers = PairBuffers(bucket_min)
        self.n_intersections = 0
        self.n_padded = 0
        self.device_pair_counts: List[np.ndarray] = []
        self.n_devices = 1

    def expand(
        self,
        bitmaps: jax.Array,
        left: np.ndarray,
        right: np.ndarray,
        sup_left: np.ndarray,
        *,
        mode: int,
        min_sup: int,
        device_of_pair: Optional[np.ndarray] = None,
    ) -> LevelResult:
        """Intersect all (left[q], right[q]) frontier-row pairs, threshold at
        ``min_sup``, and return the device-compacted survivors."""
        raise NotImplementedError

    def _empty(self, bitmaps: jax.Array) -> LevelResult:
        w = bitmaps.shape[1]
        return LevelResult(mask=np.zeros(0, bool),
                           supports=np.zeros(0, np.int64),
                           bitmaps=jnp.zeros((0, w), jnp.uint32))

    def _take(self, block: jax.Array, idx: jax.Array) -> jax.Array:
        """Device row gather behind compaction; backends that must preserve
        a placement (tid-sharding) override only this."""
        return _take_rows(block, idx)

    def _compact(self, block: jax.Array, sel: np.ndarray) -> jax.Array:
        """Gather survivor rows ``sel`` out of ``block``, padded to a
        power-of-two rung (pad slots gather row 0) so the device gather and
        every downstream expansion see ladder shapes, not raw counts."""
        sb = bucket_size(max(int(sel.shape[0]), 1), self.buffers.floor)
        idx = np.zeros(sb, np.int32)
        idx[:sel.shape[0]] = sel
        return self._take(block, jnp.asarray(idx))

    def prepare_frontier(self, bitmaps: jax.Array) -> jax.Array:
        """Place a frontier the way this backend will carry it (identity for
        single-device backends).  Drivers that expand the same frontier many
        times (chunked level 2) call this once instead of paying per-call
        placement."""
        return bitmaps

    def snapshot(self) -> Tuple[int, int, int]:
        """Counter snapshot, for per-call deltas on a long-lived engine
        (``stats(since=snapshot)`` — the streaming miner reports per-slide
        work, not lifetime totals)."""
        return (self.n_intersections, self.n_padded,
                len(self.device_pair_counts))

    def stats(self, since: Optional[Tuple[int, int, int]] = None) -> dict:
        i0, p0, d0 = since if since is not None else (0, 0, 0)
        out = {
            "backend": self.name,
            "n_intersections": self.n_intersections - i0,
            "n_padded": self.n_padded - p0,
        }
        if self.device_pair_counts[d0:]:
            per_dev = np.sum(self.device_pair_counts[d0:], axis=0)
            out["device_balance"] = {
                "pairs_per_device": per_dev.tolist(),
                "padding_efficiency": float(
                    per_dev.sum() / (per_dev.max() * per_dev.shape[0]))
                if per_dev.max() > 0 else 1.0,
            }
        return out


# ---------------------------------------------------------------------------
# jnp reference backend
# ---------------------------------------------------------------------------

@jax.jit
def _take_rows(arr: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(arr, idx, axis=0)


@register_backend("jnp")
class JnpEngine(Engine):
    """Unfused reference: gather via ``jnp.take``, AND+popcount, host mask."""

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        qb, l, r, s = self.buffers.fill(left, right, sup_left)
        self.n_padded += qb - q
        out, sup, _ = fused_intersect_ref(
            bitmaps, jnp.asarray(l), jnp.asarray(r), jnp.asarray(s),
            jnp.int32(min_sup), mode=mode)
        sup_np = np.asarray(sup)[:q]
        mask = sup_np >= min_sup
        sel = np.nonzero(mask)[0]
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=self._compact(out, sel))


# ---------------------------------------------------------------------------
# fused pallas backend
# ---------------------------------------------------------------------------

@register_backend("pallas")
class PallasEngine(Engine):
    """Fused executor: one pallas_call per bucket (TPU) / fused jit (CPU).

    Only the (Q,) support and mask vectors come back to the host; the
    intersection block stays on device and survivors are compacted there.
    """

    def __init__(self, bucket_min: int = 1024, interpret: Optional[bool] = None):
        super().__init__(bucket_min)
        self.interpret = interpret

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        qb, l, r, s = self.buffers.fill(left, right, sup_left)
        self.n_padded += qb - q
        inter, sup, mask_dev = fused_intersect(
            bitmaps, jnp.asarray(l), jnp.asarray(r), jnp.asarray(s),
            jnp.int32(min_sup), mode=mode, interpret=self.interpret)
        mask = np.asarray(mask_dev)[:q].astype(bool)
        sup_np = np.asarray(sup)[:q]
        sel = np.nonzero(mask)[0]
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=self._compact(inter, sel))


# ---------------------------------------------------------------------------
# sharded backend (shard_map over either single-device executor)
# ---------------------------------------------------------------------------

@register_backend("sharded")
class ShardedEngine(Engine):
    """Executor-task mapping: pairs grouped by partition device, padded per
    device to a common bucket, run under ``shard_map`` with the frontier
    replicated — the paper's communication-free executor stage."""

    def __init__(self, mesh: jax.sharding.Mesh, bucket_min: int = 1024,
                 axis: str = "data", inner: str = "pallas",
                 interpret: Optional[bool] = None):
        super().__init__(bucket_min)
        self.mesh = mesh
        self.axis = axis
        self.inner = inner
        self.n_devices = int(mesh.shape[axis])
        if inner not in ("jnp", "pallas"):
            raise ValueError(f"unknown inner executor {inner!r}")

        def _local(bms, l, r, s, msup, _mode):
            if inner == "pallas":
                inter, sup, _ = fused_intersect(bms, l, r, s, msup,
                                                mode=_mode, interpret=interpret)
            else:
                inter, sup, _ = fused_intersect_ref(bms, l, r, s, msup,
                                                    mode=_mode)
            return inter, sup

        # pallas_call has no shard_map replication rule -> unchecked variant
        smap = shard_map_unchecked if inner == "pallas" else shard_map
        self._sharded = {
            mode: jax.jit(
                smap(
                    lambda bms, l, r, s, m, _mode=mode: _local(bms, l, r, s, m, _mode),
                    mesh=mesh,
                    in_specs=(P(), P(axis), P(axis), P(axis), P()),
                    out_specs=(P(axis), P(axis)),
                )
            )
            for mode in (MODE_TIDSET, MODE_TID_TO_DIFF, MODE_DIFFSET)
        }

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        d = self.n_devices
        qmax, lpad, rpad, spad, slot_of_pair, counts = group_pairs_by_device(
            left, right, sup_left, device_of_pair, d, self.buffers.floor)
        self.device_pair_counts.append(counts)
        self.n_padded += d * qmax - q
        out, sup = self._sharded[mode](
            bitmaps,
            jnp.asarray(lpad.reshape(d * qmax)),
            jnp.asarray(rpad.reshape(d * qmax)),
            jnp.asarray(spad.reshape(d * qmax)),
            jnp.int32(min_sup),
        )
        sup_np = np.asarray(sup).reshape(-1)[slot_of_pair]
        mask = sup_np >= min_sup
        sel = np.nonzero(mask)[0]
        surv = self._compact(out.reshape(d * qmax, -1),
                             slot_of_pair[sel].astype(np.int32))
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=surv)


# ---------------------------------------------------------------------------
# word-axis frontier handling, shared by tidsharded + grid
# ---------------------------------------------------------------------------

class _WordShardedFrontierMixin:
    """The word-axis (tid) half of a mesh-mapped backend: carry the frontier
    as ``P(None, data_axis)`` — rows replicated over every other mesh axis,
    the packed word axis split — and keep it that way across levels.

    Owns exactly three responsibilities (the axis-ownership contract in the
    module docstring): ``_ensure_sharded`` commits/pads a frontier to the
    word sharding, ``_take`` keeps survivor row gathers under that
    constraint so next-level frontiers are *born* word-sharded, and
    ``prepare_frontier`` exposes the placement to drivers that expand one
    frontier many times (the chunked level-2 path).
    """

    def _init_word_axis(self, mesh: jax.sharding.Mesh, data_axis: str) -> None:
        self.mesh = mesh
        self.data_axis = data_axis
        self.n_shards = int(mesh.shape[data_axis])
        self._spec = word_shard_spec(data_axis)
        self._sharding = NamedSharding(mesh, self._spec)
        self._take_rows_sharded = jax.jit(
            lambda arr, idx: jax.lax.with_sharding_constraint(
                jnp.take(arr, idx, axis=0), self._sharding))

    def _ensure_sharded(self, bitmaps: jax.Array) -> jax.Array:
        """Commit the frontier to ``P(None, data_axis)``, zero-padding the
        word axis to a shard multiple.  Frontiers this engine produced are
        already placed (compaction keeps the constraint), so steady-state
        levels are a no-op here."""
        if bitmaps.shape[1] % self.n_shards == 0:
            sh = getattr(bitmaps, "sharding", None)
            if (isinstance(sh, NamedSharding) and sh.mesh == self.mesh
                    and sh.spec == self._spec):
                return bitmaps
        return shard_words(bitmaps, self.mesh, self.data_axis)

    def _take(self, block: jax.Array, idx: jax.Array) -> jax.Array:
        # survivor gather under the word-sharding constraint: rows move (for
        # the grid backend, across the class axis only), the word slices stay
        # on the shard that owns them
        return self._take_rows_sharded(block, idx)

    def prepare_frontier(self, bitmaps: jax.Array) -> jax.Array:
        return self._ensure_sharded(bitmaps)

    def _build_partial_kernels(self, inner: str, interpret: Optional[bool],
                               pair_spec: P, block_spec: P) -> Dict[int, Callable]:
        """Per-mode ``jit(shard_map)`` executors over the partial fused
        kernel: shard-local intersect + popcount, one psum over the word
        (data) axis only — class shards, if any, own disjoint pair blocks
        whose counts must never mix — then support conversion and the
        min-support mask on the reduced value.  The pair/block specs are
        the only thing the word-sharded backends differ by: ``P()`` /
        ``P(None, data)`` for ``tidsharded`` (pairs replicated),
        ``P(class)`` / ``P(class, data)`` for ``grid`` (pairs split)."""
        if inner not in ("jnp", "pallas"):
            raise ValueError(f"unknown inner executor {inner!r}")
        data_axis = self.data_axis

        def _local(bms, l, r, s, msup, _mode):
            if inner == "pallas":
                inter, pop = fused_intersect_partial(bms, l, r, mode=_mode,
                                                     interpret=interpret)
            else:
                inter, pop = fused_intersect_partial_ref(bms, l, r, mode=_mode)
            total = jax.lax.psum(pop, data_axis)
            sup = total if _mode == MODE_TIDSET else s - total
            mask = (sup >= msup).astype(jnp.int32)
            return inter, sup, mask

        # pallas_call has no shard_map replication rule -> unchecked variant
        smap = shard_map_unchecked if inner == "pallas" else shard_map
        return {
            mode: jax.jit(
                smap(
                    lambda bms, l, r, s, m, _mode=mode: _local(bms, l, r, s, m, _mode),
                    mesh=self.mesh,
                    in_specs=(self._spec, pair_spec, pair_spec, pair_spec, P()),
                    out_specs=(block_spec, pair_spec, pair_spec),
                )
            )
            for mode in (MODE_TIDSET, MODE_TID_TO_DIFF, MODE_DIFFSET)
        }


# ---------------------------------------------------------------------------
# tid-sharded backend (frontier word axis split across the mesh)
# ---------------------------------------------------------------------------

@register_backend("tidsharded")
class TidShardedEngine(_WordShardedFrontierMixin, Engine):
    """Word-sharded executor: the frontier bitmap is carried as
    ``P(None, axis)`` — rows replicated, the packed word (tid) axis split
    across the mesh — so each device stores 1/n_shards of every tidset.

    Per expansion, every shard intersects and popcounts its word slice for
    *all* pairs (the partial kernel), one ``psum`` across shards turns the
    partial counts into supports, and the min-support mask is applied to the
    reduced value.  Survivor compaction is a shard-local row gather under a
    ``P(None, axis)`` constraint, so the full (Q, W) intersection block never
    materializes on any single device, the host, or the interconnect — only
    the (Q,) count vector crosses shards.  This is the mode that lets a
    window larger than one device's memory stay minable (DESIGN.md §7);
    trade-off vs the pair-sharded engine: every device does every pair's
    AND, but on 1/n of the words, so compute per device is unchanged while
    memory drops ~1/n.
    """

    def __init__(self, mesh: jax.sharding.Mesh, bucket_min: int = 1024,
                 axis: str = "data", inner: str = "pallas",
                 interpret: Optional[bool] = None):
        super().__init__(bucket_min)
        self.inner = inner
        self._init_word_axis(mesh, axis)
        # pairs are never distributed in this mode: partition->device routing
        # (device_of_pair) is meaningless and ignored, so advertise a single
        # pair device to the drivers
        self.n_devices = 1
        self._sharded = self._build_partial_kernels(inner, interpret,
                                                    P(), self._spec)

    def stats(self, since=None) -> dict:
        out = super().stats(since=since)
        out["n_word_shards"] = self.n_shards
        return out

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        qb, l, r, s = self.buffers.fill(left, right, sup_left)
        self.n_padded += qb - q
        bitmaps = self._ensure_sharded(bitmaps)
        inter, sup, mask_dev = self._sharded[mode](
            bitmaps, jnp.asarray(l), jnp.asarray(r), jnp.asarray(s),
            jnp.int32(min_sup))
        mask = np.asarray(mask_dev)[:q].astype(bool)
        sup_np = np.asarray(sup)[:q]
        sel = np.nonzero(mask)[0]
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=self._compact(inter, sel))


# ---------------------------------------------------------------------------
# grid-sharded backend (pairs x words on a 2D mesh)
# ---------------------------------------------------------------------------

@register_backend("grid")
class GridShardedEngine(_WordShardedFrontierMixin, Engine):
    """Grid-sharded executor on a 2D ``("class", "data")`` mesh: the pair
    list is split over the **class** axis (grouped by partitioned
    equivalence class, exactly as in :class:`ShardedEngine`) while the
    frontier's packed word (tid) axis is split over the **data** axis
    (exactly as in :class:`TidShardedEngine`).  The frontier is carried as
    ``P(None, "data")`` — replicated over ``"class"``, word-sharded over
    ``"data"`` — so each of the ``n_class * n_data`` devices executes the
    partial fused kernel on one (class-shard pairs) x (word-shard words)
    tile.

    Supports are recovered with one ``psum`` over the **data axis only**:
    the class shards own disjoint pair blocks, so their counts must never
    mix — after the reduce, every device in a data row holds the finished
    supports of its class shard's pairs.  Survivor compaction gathers rows
    under the ``P(None, "data")`` constraint: word slices never cross the
    data axis; survivor rows are replicated over the class axis only (the
    same survivor broadcast the pair-sharded engine performs implicitly),
    so the next level's frontier is born grid-placed.

    Net effect vs the 1D modes (DESIGN.md §8): per-device pair work drops
    ~1/n_class (vs ``tidsharded``, which replicates all pairs) AND
    per-device frontier memory drops ~1/n_data (vs ``sharded``, which
    replicates the whole frontier) — the two scaling axes the paper treats
    separately (executor count, database size), composed on one mesh.
    """

    def __init__(self, mesh: jax.sharding.Mesh, bucket_min: int = 1024,
                 class_axis: str = "class", data_axis: str = "data",
                 inner: str = "pallas", interpret: Optional[bool] = None):
        super().__init__(bucket_min)
        missing = [a for a in (class_axis, data_axis)
                   if a not in mesh.axis_names]
        if missing:
            raise ValueError(
                f"grid backend needs a 2D ({class_axis!r}, {data_axis!r}) "
                f"mesh (launch.mesh.make_grid_mesh); this mesh has axes "
                f"{tuple(mesh.axis_names)}")
        self.class_axis = class_axis
        self.inner = inner
        self._init_word_axis(mesh, data_axis)
        self.n_class = int(mesh.shape[class_axis])
        # drivers route partition->device over the pair (class) axis
        self.n_devices = self.n_class
        self._sharded = self._build_partial_kernels(
            inner, interpret, grid_pair_spec(class_axis),
            grid_block_spec(class_axis, data_axis))

    def stats(self, since=None) -> dict:
        out = super().stats(since=since)
        out["n_class_shards"] = self.n_class
        out["n_word_shards"] = self.n_shards
        out["grid"] = [self.n_class, self.n_shards]
        return out

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        d = self.n_class
        qmax, lpad, rpad, spad, slot_of_pair, counts = group_pairs_by_device(
            left, right, sup_left, device_of_pair, d, self.buffers.floor)
        self.device_pair_counts.append(counts)
        self.n_padded += d * qmax - q
        bitmaps = self._ensure_sharded(bitmaps)
        inter, sup, mask_dev = self._sharded[mode](
            bitmaps,
            jnp.asarray(lpad.reshape(d * qmax)),
            jnp.asarray(rpad.reshape(d * qmax)),
            jnp.asarray(spad.reshape(d * qmax)),
            jnp.int32(min_sup),
        )
        sup_np = np.asarray(sup).reshape(-1)[slot_of_pair]
        mask = np.asarray(mask_dev).reshape(-1)[slot_of_pair].astype(bool)
        sel = np.nonzero(mask)[0]
        surv = self._compact(inter, slot_of_pair[sel].astype(np.int32))
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=surv)
