"""The device-resident mining engine: pluggable executors for the Eclat hot loop.

``core.eclat.mine`` is pure driver logic (class segmentation, partition
tables, store bookkeeping); every device-side intersection goes through the
backend interface defined here.  A backend turns one level-expansion request

    (frontier bitmaps, pair lists, parent supports, mode, min_sup)

into a :class:`LevelResult`: the survivor mask and supports for the driver
plus the survivor bitmaps, compacted *on device* — the padded ``(Q, W)``
intersection never crosses the host boundary.

Backends (``register_backend`` registry, selected by ``EclatConfig.backend``):

  jnp      reference executor — ``jnp.take`` gather + AND + popcount, the
           semantics every other backend must match bit-exactly.
  pallas   fused executor — one ``pallas_call`` (kernels.fused_intersect)
           gathers rows by scalar-prefetch index maps, intersects, popcounts
           and applies the min-support threshold in a single kernel on TPU;
           off-TPU it dispatches to the identically-fused jnp path.  Default.
  sharded  shard_map-over-either: pairs are grouped by the device their
           equivalence class was partitioned to, padded per device to a
           common bucket, and executed under ``shard_map`` — the paper's
           executor-task mapping.  Constructed automatically when ``mine``
           receives a mesh.
  tidsharded  word-sharded (tid-axis) execution: the frontier bitmap is
           carried as ``P(None, "data")`` — every device holds all rows but
           only a word slice — each shard intersects and popcounts its
           slice, supports are recovered with one psum, and survivor
           compaction stays shard-local.  Per-device frontier memory is
           total/n_shards, so windows larger than one device's memory stay
           minable (DESIGN.md §7).  Selected by ``shard="words"``.
  grid     grid-sharded execution on a 2D ``("class", "data")`` mesh:
           candidate pairs split over the class axis (as in ``sharded``)
           AND the frontier's word axis split over the data axis (as in
           ``tidsharded``), so per-device pair work drops ~1/n_class and
           per-device frontier memory ~1/n_data at the same time — the
           first backend that composes both shard_map axes (DESIGN.md §8).
           Selected by ``shard="grid"``.

Axis ownership (who interprets what): ``device_of_pair`` always routes over
the backend's *pair* axis (``n_devices`` wide — the class axis for
``sharded``/``grid``, trivial for the rest); ``prepare_frontier``/``_take``
own the *word* axis placement (``P(None, data)`` for ``tidsharded``/
``grid``, identity otherwise); ``_compact`` is axis-agnostic and delegates
the row gather to ``_take``.  The shared helpers ``group_pairs_by_device``
and ``_WordShardedFrontierMixin`` implement one axis each, so a backend
composes them instead of copy-pasting an engine.

Bucket ladder: pair batches are padded up to a half-power-of-two ladder
(``bucket_min`` x {1, 1.5, 2, 3, 4, 6, 8, ...}), so every XLA/Mosaic
executable is compiled once per rung and reused across levels while
worst-case padding stays under ~33% (vs ~50% on the pure pow2 ladder); the
padded host-side index buffers themselves are persistent per rung (no
per-call allocation or ``argsort`` churn for the single-device backends).
The default floor is 128 — the ladder is discrete, so a low floor costs at
most a handful of extra one-time compiles, while a high one (the old 1024)
dominated padding waste on small levels (BENCH_engine.json recorded
``padding_efficiency: 0.115`` with every sub-floor level padded to 1024).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Callable, Dict, List, Optional, Tuple, Type

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..dist.compat import shard_map, shard_map_unchecked
from ..dist.sharding import (grid_block_spec, grid_pair_spec, mesh_descriptor,
                             shard_words, word_shard_spec)
from ..kernels.fused_intersect import (MODE_DIFFSET, MODE_TID_TO_DIFF,
                                       MODE_TIDSET, compact_epilogue,
                                       fused_intersect,
                                       fused_intersect_compact,
                                       fused_intersect_compact_ref,
                                       fused_intersect_partial,
                                       fused_intersect_partial_ref,
                                       fused_intersect_ref)

__all__ = [
    "MODE_TIDSET", "MODE_TID_TO_DIFF", "MODE_DIFFSET",
    "LevelResult", "Engine", "EngineState", "JnpEngine", "PallasEngine",
    "ShardedEngine", "TidShardedEngine", "GridShardedEngine",
    "group_pairs_by_device", "register_backend", "available_backends",
    "make_engine", "engine_from_state", "resolve_engine",
    "DispatchPolicy", "KERNELTUNE_ENV",
]


def _dput(x, sharding=None) -> jax.Array:
    """Explicit host->device upload.  The expand hot loops never rely on
    implicit ``jnp.asarray`` conversion of host state (staticcheck RS005),
    so steady-state mining runs clean under ``jax.transfer_guard``.  Mesh
    backends pass the placement their executor declares (replicated or
    pair-split) — without it the upload lands on one device and dispatch
    would re-shard implicitly, which the guard also forbids."""
    return jax.device_put(x, sharding)


def _dput_i32(v, sharding=None) -> jax.Array:
    """Explicit scalar upload as a strong-typed int32 (see :func:`_dput`)."""
    return jax.device_put(np.int32(v), sharding)


# ---------------------------------------------------------------------------
# result type + bucket-ladder pair buffers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LevelResult:
    """One level expansion, already min-support filtered.

    mask:     (Q,) bool — which input pairs survived, in input pair order.
    supports: (S,) int64 — supports of the survivors (S = mask.sum()).
    bitmaps:  (Sb, W) uint32 device array — survivor tidsets/diffsets,
              compacted on device into a power-of-two row rung Sb >= S.
              Rows [:S] are the survivors in mask order; rows [S:] are
              padding (duplicates of row 0) and must not be read.  Padding
              the compaction keeps device shapes on the same bucket ladder
              as the pair batches, so steady-state mining (and every window
              slide of the streaming miner) reuses compiled executables
              instead of recompiling per survivor count.
    """

    mask: np.ndarray
    supports: np.ndarray
    bitmaps: jax.Array


@dataclasses.dataclass
class EngineState:
    """Serializable engine state (DESIGN.md §10): config + accounting as
    *data*, never Python object innards.

    What is data: the knobs a rebuild needs (backend / inner executor /
    ladder floors / kernel config) and the accounting ledgers that must
    survive a crash so per-slide ``stats(since=...)`` deltas stay truthful
    after recovery.  What is derived (and therefore absent): pair buffers,
    compiled shard_map executors, shardings, autotune tables — all
    reconstructed by :func:`engine_from_state` under whatever mesh the
    restoring process brings.  ``mesh`` is the provenance descriptor of the
    mesh the snapshot ran on; it is reported, never restored from.
    """
    backend: str
    inner: str
    bucket_min: int
    compact_min: int
    block_w: Optional[int]
    compact: bool
    autotune: bool
    interpret: Optional[bool]
    mesh: Optional[dict]                      # mesh_descriptor provenance
    n_intersections: int
    n_padded: int
    level_padding: List[Tuple[int, int]]
    device_pair_counts: List[np.ndarray]

    def to_tree(self) -> Tuple[Dict[str, np.ndarray], dict]:
        """(array tree, JSON-able extra) for ``training.checkpoint``."""
        tree: Dict[str, np.ndarray] = {
            "level_padding": np.asarray(self.level_padding,
                                        np.int64).reshape(-1, 2),
        }
        if self.device_pair_counts:
            tree["device_pair_counts"] = np.stack(
                [np.asarray(c, np.int64) for c in self.device_pair_counts])
        extra = {"backend": self.backend, "inner": self.inner,
                 "bucket_min": int(self.bucket_min),
                 "compact_min": int(self.compact_min),
                 "block_w": None if self.block_w is None else int(self.block_w),
                 "compact": bool(self.compact),
                 "autotune": bool(self.autotune),
                 "interpret": self.interpret, "mesh": self.mesh,
                 "n_intersections": int(self.n_intersections),
                 "n_padded": int(self.n_padded)}
        return tree, extra

    @classmethod
    def from_tree(cls, tree: Dict[str, np.ndarray], extra: dict) -> "EngineState":
        lp = np.asarray(tree["level_padding"], np.int64).reshape(-1, 2)
        dpc = tree.get("device_pair_counts")
        return cls(
            backend=str(extra["backend"]), inner=str(extra["inner"]),
            bucket_min=int(extra["bucket_min"]),
            compact_min=int(extra["compact_min"]),
            block_w=(None if extra["block_w"] is None
                     else int(extra["block_w"])),
            compact=bool(extra["compact"]), autotune=bool(extra["autotune"]),
            interpret=extra["interpret"], mesh=extra["mesh"],
            n_intersections=int(extra["n_intersections"]),
            n_padded=int(extra["n_padded"]),
            level_padding=[(int(a), int(b)) for a, b in lp],
            device_pair_counts=([np.asarray(c, np.int64) for c in dpc]
                                if dpc is not None else []))


def bucket_size(n: int, floor: int) -> int:
    """Smallest ladder rung >= n (>= floor).

    The ladder is half-power-of-two: ``floor * {1, 1.5, 2, 3, 4, 6, 8, ...}``
    rather than pure doubling.  Pure powers of two waste up to ~50% of every
    padded batch in the worst case (n just past a rung); the 1.5x
    intermediate rungs cap that at ~33% for ~2x the executable count — a
    measured win for the engine benchmarks, whose level-1/2 frontier counts
    routinely land just past a power of two (BENCH_engine.json
    padding_efficiency was 0.115 on the pure-pow2 ladder)."""
    b = max(int(floor), 1)
    while b < n:
        h = b + (b >> 1)
        if n <= h:
            return h
        b <<= 1
    return b


class PairBuffers:
    """Persistent bucket-ladder host buffers for padded pair batches.

    One (left, right, sup_left) int32 triple per rung, reused across levels:
    refilling in place avoids the per-call allocation the old executor paid,
    and the power-of-two rungs keep the jit cache to O(log Q) entries.
    """

    def __init__(self, floor: int):
        self.floor = max(int(floor), 1)
        self._rungs: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}

    def fill(self, left: np.ndarray, right: np.ndarray, sup_left: np.ndarray):
        q = int(left.shape[0])
        qb = bucket_size(q, self.floor)
        rung = self._rungs.get(qb)
        if rung is None:
            rung = tuple(np.zeros(qb, np.int32) for _ in range(3))
            self._rungs[qb] = rung
        l, r, s = rung
        l[:q], r[:q], s[:q] = left, right, sup_left
        l[q:] = 0
        r[q:] = 0
        s[q:] = 0
        return qb, l, r, s


def group_pairs_by_device(
    left: np.ndarray,
    right: np.ndarray,
    sup_left: np.ndarray,
    device_of_pair: Optional[np.ndarray],
    n_devices: int,
    floor: int,
) -> Tuple[int, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group candidate pairs by their assigned pair-axis slot and pad every
    slot's block to a shared ladder rung.

    The pair-axis half of the mesh-mapped backends (``sharded`` distributes
    over its one axis, ``grid`` over its class axis): returns ``(qmax, lpad,
    rpad, spad, slot_of_pair, counts)`` where the ``(n_devices, qmax)`` pad
    blocks hold each device's pairs, ``slot_of_pair[q] = dev * qmax + slot``
    maps input pair order to padded-block position, and ``counts`` is the
    per-device pair load (the balance stats input).  Out-of-range device ids
    are refused up front: one would fall outside the grouping loop and leave
    its ``slot_of_pair`` entry uninitialized — garbage slots, silently wrong
    supports.
    """
    q = int(left.shape[0])
    d = int(n_devices)
    if device_of_pair is None:
        device_of_pair = np.zeros(q, np.int64)
    device_of_pair = np.asarray(device_of_pair, np.int64)
    if device_of_pair.shape != (q,):
        raise ValueError(f"device_of_pair must be shape ({q},), got "
                         f"{device_of_pair.shape}")
    if (device_of_pair < 0).any() or (device_of_pair >= d).any():
        bad = device_of_pair[(device_of_pair < 0) | (device_of_pair >= d)]
        raise ValueError(
            f"device_of_pair contains ids outside [0, {d}) for this "
            f"{d}-device pair axis: {np.unique(bad).tolist()[:8]}")
    order = np.argsort(device_of_pair, kind="stable")
    counts = np.bincount(device_of_pair, minlength=d)
    qmax = bucket_size(int(counts.max()), floor)
    lpad = np.zeros((d, qmax), np.int32)
    rpad = np.zeros((d, qmax), np.int32)
    spad = np.zeros((d, qmax), np.int32)
    # every slot is written by the grouping loop below — the range check
    # above refuses the one id class that could leave a hole
    slot_of_pair = np.empty(q, np.int64)  # staticcheck: disable=RS002
    off = 0
    for dev in range(d):
        c = int(counts[dev])
        idx = order[off: off + c]
        lpad[dev, :c] = left[idx]
        rpad[dev, :c] = right[idx]
        spad[dev, :c] = sup_left[idx]
        slot_of_pair[idx] = dev * qmax + np.arange(c)
        off += c
    return qmax, lpad, rpad, spad, slot_of_pair, counts


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

BACKENDS: Dict[str, Type["Engine"]] = {}


def register_backend(name: str):
    def deco(cls: Type["Engine"]) -> Type["Engine"]:
        BACKENDS[name] = cls
        cls.name = name
        return cls
    return deco


def available_backends() -> List[str]:
    return sorted(BACKENDS)


def make_engine(
    backend: str,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    bucket_min: int = 128,
    interpret: Optional[bool] = None,
    inner: str = "pallas",
    block_w: Optional[int] = None,
    compact: bool = True,
    autotune: bool = False,
) -> "Engine":
    """Construct a backend by registry name.

    ``sharded`` / ``tidsharded`` / ``grid`` require a mesh (``grid`` a 2D
    one with ``("class", "data")`` axes); ``interpret`` forces the Pallas
    kernel's interpreter (tests) instead of the TPU/ref dispatch.
    ``block_w`` / ``compact`` / ``autotune`` are the kernel-config knobs
    every backend accepts (see :class:`Engine`).
    """
    cls = BACKENDS.get(backend)
    if cls is None:
        raise ValueError(f"unknown engine backend {backend!r}; "
                         f"available: {available_backends()}")
    kcfg = dict(block_w=block_w, compact=compact, autotune=autotune)
    if backend in ("sharded", "tidsharded", "grid"):
        if mesh is None:
            raise ValueError(f"{backend} backend requires a mesh")
        return cls(mesh, bucket_min=bucket_min, inner=inner,
                   interpret=interpret, **kcfg)
    if backend == "pallas":
        return PallasEngine(bucket_min=bucket_min, interpret=interpret,
                            **kcfg)
    return cls(bucket_min=bucket_min, **kcfg)


_UNSET = object()


def engine_from_state(
    state: EngineState,
    mesh: Optional[jax.sharding.Mesh] = None,
    *,
    backend: Optional[str] = None,
    interpret=_UNSET,
) -> "Engine":
    """Rebuild an engine from an :class:`EngineState`, possibly on a
    different mesh — the engine half of live re-meshing (DESIGN.md §10).

    The snapshot's mesh descriptor is provenance only: the rebuilt engine is
    constructed against ``mesh`` (whatever factorization the restoring
    process brings), so a ``tidsharded`` state taken on 4 devices restores
    onto a 2-device mesh, a ``grid`` state taken on 2x2 onto 4x1, and any
    mesh-mapped state onto a single device (``mesh=None`` falls back to the
    snapshot's inner executor).  ``backend`` overrides the target backend
    outright (cross-family re-meshing, e.g. ``sharded`` -> ``tidsharded``);
    ``interpret`` overrides the kernel-interpreter flag (tests).
    """
    target = state.backend if backend is None else backend
    mesh_backends = ("sharded", "tidsharded", "grid")
    if target in mesh_backends and mesh is None:
        target = state.inner if state.inner in ("jnp", "pallas") else "pallas"
    interp = state.interpret if interpret is _UNSET else interpret
    eng = make_engine(target,
                      mesh=mesh if target in mesh_backends else None,
                      bucket_min=state.bucket_min,
                      interpret=interp,
                      inner=state.inner,
                      block_w=state.block_w,
                      compact=state.compact,
                      autotune=state.autotune)
    eng.compact_min = int(state.compact_min)
    return eng.restore_state(state)


# ---------------------------------------------------------------------------
# measured dispatch policy (BENCH_kerneltune.json crossover table)
# ---------------------------------------------------------------------------

KERNELTUNE_ENV = "REPRO_KERNELTUNE_TABLE"


def _default_policy_paths() -> List[str]:
    paths = []
    env = os.environ.get(KERNELTUNE_ENV)
    if env:
        paths.append(env)
    paths.append(os.path.join(os.getcwd(), "BENCH_kerneltune.json"))
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    paths.append(os.path.join(root, "BENCH_kerneltune.json"))
    return paths


class DispatchPolicy:
    """Backend choice from *measured* crossovers, not assumptions.

    ``benchmarks/kerneltune_bench.py`` sweeps the backends over a Q x W
    grid and records, per cell, which backend won single-device and which
    won mesh-mapped (``BENCH_kerneltune.json["crossover"]``).  This class
    loads that table and answers "which backend for an expansion of ~q
    pairs over ~w words?" by nearest measured cell in log space — the
    measured replacement for the hand-waved dispatch table DESIGN.md §6
    used to carry.  Missing / unreadable / empty tables load as ``None``
    so ``resolve_engine(auto=...)`` can fall back to the static default
    (pallas, or the mesh-implied backend) instead of guessing.
    """

    def __init__(self, cells: List[dict], source: Optional[str] = None):
        self.cells = [c for c in cells
                      if "q" in c and "w" in c and c.get("best_single")]
        self.source = source

    @classmethod
    def load(cls, path: Optional[str] = None) -> Optional["DispatchPolicy"]:
        for p in ([path] if path else _default_policy_paths()):
            try:
                with open(p) as f:
                    data = json.load(f)
            except (OSError, ValueError):
                continue
            cells = data.get("crossover", [])
            if cells:
                policy = cls(cells, source=p)
                if policy.cells:
                    return policy
        return None

    def choose(self, q: int, w: int, *, have_mesh: bool = False) -> str:
        """Measured-best backend for a ~(q pairs, w words) expansion.

        Nearest cell by euclidean distance in (log2 q, log2 w) — the bench
        grid is log-spaced, so log distance matches its geometry.  With a
        mesh the cell's ``best_mesh`` winner is used (falling back to the
        single-device winner's mesh mapping when the sweep ran
        single-device only)."""
        lq, lw = np.log2(max(int(q), 1)), np.log2(max(int(w), 1))

        def dist(c):
            return ((np.log2(max(int(c["q"]), 1)) - lq) ** 2
                    + (np.log2(max(int(c["w"]), 1)) - lw) ** 2)

        cell = min(self.cells, key=dist)
        if have_mesh:
            return cell.get("best_mesh") or cell["best_single"]
        return cell["best_single"]


def resolve_engine(
    backend: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    *,
    bucket_min: int = 128,
    shard: str = "pairs",
    block_w: Optional[int] = None,
    compact: bool = True,
    autotune: bool = False,
    auto: Optional[bool] = None,
    hints: Optional[Tuple[int, int]] = None,
    policy_path: Optional[str] = None,
) -> "Engine":
    """Map a (backend name, mesh, shard mode) request onto an engine.

    A mesh always means a mesh-mapped backend, with the named single-device
    backend as its inner executor; ``shard`` picks which axis (or axes) the
    mesh splits: ``"pairs"`` (ShardedEngine — candidate pairs distributed,
    the frontier replicated; the paper's executor mapping), ``"words"``
    (TidShardedEngine — the frontier's word axis distributed, pairs
    replicated; DESIGN.md §7), or ``"grid"`` (GridShardedEngine — pairs
    over a ``"class"`` axis AND words over a ``"data"`` axis of a 2D mesh;
    DESIGN.md §8).  ``"sharded"`` / ``"tidsharded"`` / ``"grid"`` without a
    mesh degrade gracefully to the single-device default (pallas).  Naming
    a mesh-mapped backend implies its shard mode (``sharded`` -> pairs,
    ``tidsharded`` -> words, ``grid`` -> grid); combining one with a
    *different* non-default ``shard`` is contradictory and rejected rather
    than silently resolved to either side.  Both the batch driver
    (``core.eclat.mine``) and the streaming miner (``repro.streaming``)
    resolve their executors here.

    **Measured dispatch**: ``backend="auto"`` (or ``auto=True``) consults
    the :class:`DispatchPolicy` crossover table measured by
    ``benchmarks/kerneltune_bench.py``, using ``hints=(est_pairs, words)``
    — the driver's estimate of the dominant expansion shape — to pick the
    backend nearest the measured winner (DESIGN.md §6).  The fallback is
    always safe: no table, no hints, or an unknown winner resolves to the
    static default exactly as before (``"batched"`` remains a legacy alias
    for that default).  ``block_w`` / ``compact`` / ``autotune`` thread the
    kernel-config knobs to whichever engine wins.
    """
    shard_to_backend = {"pairs": "sharded", "words": "tidsharded",
                        "grid": "grid"}
    if shard not in shard_to_backend:
        raise ValueError(f"unknown shard mode {shard!r}; "
                         "expected 'pairs', 'words' or 'grid'")
    requested = backend
    auto = (backend == "auto") if auto is None else bool(auto)
    if backend in ("batched", "auto"):
        backend = "pallas"
    policy = None
    if auto:
        policy = DispatchPolicy.load(policy_path)
        if policy is not None and hints is not None:
            est_q, est_w = hints
            choice = policy.choose(est_q, est_w, have_mesh=mesh is not None)
            if choice in BACKENDS:
                backend = choice
    implied = {"sharded": "pairs", "tidsharded": "words",
               "grid": "grid"}.get(backend)
    if implied is not None:
        # shard="pairs" is the config default, so only an explicit
        # disagreement is a conflict — except under auto, where the policy
        # (not the user) picked the backend and simply overrides the shard
        if auto:
            shard = implied
        elif shard not in ("pairs", implied):
            raise ValueError(
                f"backend {backend!r} implies shard={implied!r} but "
                f"shard={shard!r} was requested; drop one of the two")
        else:
            shard = implied
    kcfg = dict(block_w=block_w, compact=compact, autotune=autotune)
    if mesh is not None or backend in ("sharded", "tidsharded", "grid"):
        if mesh is None:
            backend = "pallas"
        else:
            inner = backend if backend in ("jnp", "pallas") else "pallas"
            engine = make_engine(shard_to_backend[shard], mesh=mesh,
                                 bucket_min=bucket_min, inner=inner, **kcfg)
            engine.dispatch = {"requested": requested, "auto": auto,
                              "policy": policy.source if policy else None}
            return engine
    engine = make_engine(backend, bucket_min=bucket_min, **kcfg)
    engine.dispatch = {"requested": requested, "auto": auto,
                       "policy": policy.source if policy else None}
    return engine


class Engine:
    """Backend interface + shared accounting.

    Kernel-config knobs (shared by every backend, threaded from
    ``EclatConfig`` / ``StreamConfig`` through :func:`resolve_engine`):

    ``block_w``  explicit word-tile width for the fused kernel; ``None``
                 resolves through the autotuned shape table at trace time
                 (``kernels.autotune.lookup``, cost-model seed on a miss).
    ``compact``  fold the survivor-compaction epilogue into the fused
                 executable where the backend supports it (one dispatch,
                 only survivors cross back) instead of the legacy host-mask
                 -> separate-gather two-step.
    ``autotune`` tune-on-miss: before dispatching a shape class that has no
                 table entry, run the measured sweep (cheap: cost-model
                 seeded, truncated) and cache the winner.
    ``compact_min``  floor of the *survivor* bucket ladder — decoupled from
                 the pair-batch floor because survivor counts collapse fast
                 at deep levels; a 1024-row survivor rung for 12 survivors
                 was most of BENCH_engine.json's 0.115 padding efficiency.
    """

    name = "abstract"

    def __init__(self, bucket_min: int = 128, *,
                 block_w: Optional[int] = None,
                 compact: bool = True,
                 autotune: bool = False,
                 compact_min: Optional[int] = None):
        self.buffers = PairBuffers(bucket_min)
        self.block_w = None if block_w is None else int(block_w)
        self.compact = bool(compact)
        self.autotune = bool(autotune)
        self.compact_min = (min(self.buffers.floor, 128)
                            if compact_min is None else max(int(compact_min), 1))
        self.n_intersections = 0
        self.n_padded = 0
        self.device_pair_counts: List[np.ndarray] = []
        self.level_padding: List[Tuple[int, int]] = []
        self.n_devices = 1

    def _record_padding(self, q: int, padded: int) -> None:
        """Per-level pair-padding ledger behind ``stats()['pair_padding']``."""
        self.n_padded += padded - q
        self.level_padding.append((int(q), int(padded)))

    def _maybe_tune(self, q: int, w: int, mode: int) -> None:
        """Tune-on-miss: warm the autotune table for this call shape so the
        trace-time ``block_w=None`` lookup hits a measured entry.  No-op
        unless ``autotune`` is on and no explicit ``block_w`` overrides it."""
        if not self.autotune or self.block_w is not None:
            return
        from ..kernels import autotune as at
        if at.load_table().get(at.shape_class(q, w, mode)) is None:
            at.tune_shape(q, w, mode, reps=2, max_candidates=3)

    def expand(
        self,
        bitmaps: jax.Array,
        left: np.ndarray,
        right: np.ndarray,
        sup_left: np.ndarray,
        *,
        mode: int,
        min_sup: int,
        device_of_pair: Optional[np.ndarray] = None,
    ) -> LevelResult:
        """Intersect all (left[q], right[q]) frontier-row pairs, threshold at
        ``min_sup``, and return the device-compacted survivors."""
        raise NotImplementedError

    def _empty(self, bitmaps: jax.Array) -> LevelResult:
        w = bitmaps.shape[1]
        return LevelResult(mask=np.zeros(0, bool),
                           supports=np.zeros(0, np.int64),
                           bitmaps=jnp.zeros((0, w), jnp.uint32))

    def _take(self, block: jax.Array, idx: jax.Array) -> jax.Array:
        """Device row gather behind compaction; backends that must preserve
        a placement (tid-sharding) override only this."""
        return _take_rows(block, idx)

    def _compact(self, block: jax.Array, sel: np.ndarray) -> jax.Array:
        """Gather survivor rows ``sel`` out of ``block``, padded to a
        ladder rung (pad slots gather row 0) so the device gather and
        every downstream expansion see ladder shapes, not raw counts.
        Uses the survivor floor ``compact_min``, not the pair floor."""
        sb = bucket_size(max(int(sel.shape[0]), 1), self.compact_min)
        idx = np.zeros(sb, np.int32)
        idx[:sel.shape[0]] = sel
        return self._take(block, _dput(idx, getattr(self, "_rep_sharding",
                                                    None)))

    def _slice_survivors(self, compact: jax.Array, n_surv: int) -> jax.Array:
        """Rung-slice a fused-epilogue compaction result: rows ``[:n_surv]``
        are the survivors, the rung padding beyond them duplicates row 0 —
        the same convention :meth:`_compact` produces, so the two paths are
        interchangeable bit-for-bit."""
        sb = bucket_size(max(int(n_surv), 1), self.compact_min)
        return _prefix_rows(compact, sb)

    def prepare_frontier(self, bitmaps: jax.Array) -> jax.Array:
        """Place a frontier the way this backend will carry it (identity for
        single-device backends).  Drivers that expand the same frontier many
        times (chunked level 2) call this once instead of paying per-call
        placement."""
        return bitmaps

    def snapshot_state(self) -> EngineState:
        """Serializable snapshot of config + accounting (DESIGN.md §10).
        Deep-copies the ledgers so the snapshot is stable while the engine
        keeps expanding."""
        return EngineState(
            backend=self.name,
            inner=getattr(self, "inner",
                          self.name if self.name in ("jnp", "pallas")
                          else "pallas"),
            bucket_min=self.buffers.floor,
            compact_min=self.compact_min,
            block_w=self.block_w,
            compact=self.compact,
            autotune=self.autotune,
            interpret=getattr(self, "interpret", None),
            mesh=mesh_descriptor(getattr(self, "mesh", None)),
            n_intersections=self.n_intersections,
            n_padded=self.n_padded,
            level_padding=[(int(a), int(b)) for a, b in self.level_padding],
            device_pair_counts=[np.asarray(c, np.int64).copy()
                                for c in self.device_pair_counts])

    def restore_state(self, state: EngineState) -> "Engine":
        """Adopt a snapshot's accounting.  Per-device pair counts are kept
        only when this engine's pair axis has the same width as the
        snapshot's — restoring onto a different mesh factorization makes the
        old per-device attribution meaningless, so it is dropped (derived
        accounting, not data; DESIGN.md §10)."""
        self.n_intersections = int(state.n_intersections)
        self.n_padded = int(state.n_padded)
        self.level_padding = [(int(a), int(b)) for a, b in state.level_padding]
        dpc = [np.asarray(c, np.int64).copy()
               for c in state.device_pair_counts]
        if any(c.shape[0] != self.n_devices for c in dpc):
            dpc = []
        self.device_pair_counts = dpc
        return self

    def snapshot(self) -> Tuple[int, int, int, int]:
        """Counter snapshot, for per-call deltas on a long-lived engine
        (``stats(since=snapshot)`` — the streaming miner reports per-slide
        work, not lifetime totals)."""
        return (self.n_intersections, self.n_padded,
                len(self.device_pair_counts), len(self.level_padding))

    def stats(self, since: Optional[Tuple[int, ...]] = None) -> dict:
        i0, p0, d0, l0 = (tuple(since) + (0,) * 4)[:4] if since else (0,) * 4
        out = {
            "backend": self.name,
            "n_intersections": self.n_intersections - i0,
            "n_padded": self.n_padded - p0,
        }
        levels = self.level_padding[l0:]
        if levels:
            tot_q = sum(q for q, _ in levels)
            tot_p = sum(p for _, p in levels)
            out["pair_padding"] = {
                "per_level": [
                    {"pairs": q, "padded_to": p,
                     "efficiency": q / p if p else 1.0}
                    for q, p in levels
                ],
                "efficiency": tot_q / tot_p if tot_p else 1.0,
            }
        if self.device_pair_counts[d0:]:
            per_dev = np.sum(self.device_pair_counts[d0:], axis=0)
            out["device_balance"] = {
                "pairs_per_device": per_dev.tolist(),
                "padding_efficiency": float(
                    per_dev.sum() / (per_dev.max() * per_dev.shape[0]))
                if per_dev.max() > 0 else 1.0,
            }
        return out


# ---------------------------------------------------------------------------
# jnp reference backend
# ---------------------------------------------------------------------------

@jax.jit
def _take_rows(arr: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(arr, idx, axis=0)


@functools.partial(jax.jit, static_argnames=("n",))
def _prefix_rows(arr: jax.Array, n: int) -> jax.Array:
    # static-size prefix slice: an eager ``arr[:n]`` dispatches dynamic-slice
    # with host scalar starts — an implicit h2d the steady-state transfer
    # guard forbids (staticcheck SH002)
    return jax.lax.slice_in_dim(arr, 0, n, axis=0)


@register_backend("jnp")
class JnpEngine(Engine):
    """XLA reference executor: one fused jit (gather + AND + popcount +
    threshold), the semantics every other backend must match bit-exactly.
    With ``compact`` (default) the survivor-compaction epilogue runs inside
    the same jit — one dispatch, survivors only — via
    :func:`fused_intersect_compact_ref`; ``compact=False`` keeps the legacy
    host-mask -> separate-gather two-step."""

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        qb, l, r, s = self.buffers.fill(left, right, sup_left)
        self._record_padding(q, qb)
        if self.compact:
            out, sup, mask_dev, n_surv = fused_intersect_compact_ref(
                bitmaps, _dput(l), _dput(r), _dput(s),
                _dput_i32(min_sup), _dput_i32(q), mode=mode)
            mask = jax.device_get(mask_dev)[:q].astype(bool)
            sup_np = jax.device_get(sup)[:q]
            return LevelResult(mask=mask,
                               supports=sup_np[mask].astype(np.int64),
                               bitmaps=self._slice_survivors(out, int(mask.sum())))
        out, sup, _ = fused_intersect_ref(
            bitmaps, _dput(l), _dput(r), _dput(s),
            _dput_i32(min_sup), mode=mode)
        sup_np = jax.device_get(sup)[:q]
        mask = sup_np >= min_sup
        sel = np.nonzero(mask)[0]
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=self._compact(out, sel))


# ---------------------------------------------------------------------------
# fused pallas backend
# ---------------------------------------------------------------------------

@register_backend("pallas")
class PallasEngine(Engine):
    """Fused executor: one pallas_call per bucket (TPU) / fused jit (CPU).

    Only the (Q,) support and mask vectors come back to the host; the
    intersection block stays on device and survivors are compacted there.
    """

    def __init__(self, bucket_min: int = 128, interpret: Optional[bool] = None,
                 *, block_w: Optional[int] = None, compact: bool = True,
                 autotune: bool = False, compact_min: Optional[int] = None):
        super().__init__(bucket_min, block_w=block_w, compact=compact,
                         autotune=autotune, compact_min=compact_min)
        self.interpret = interpret

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        qb, l, r, s = self.buffers.fill(left, right, sup_left)
        self._record_padding(q, qb)
        self._maybe_tune(qb, bitmaps.shape[1], mode)
        if self.compact:
            inter, sup, mask_dev, n_surv = fused_intersect_compact(
                bitmaps, _dput(l), _dput(r), _dput(s),
                _dput_i32(min_sup), _dput_i32(q), mode=mode,
                block_w=self.block_w, interpret=self.interpret)
            mask = jax.device_get(mask_dev)[:q].astype(bool)
            sup_np = jax.device_get(sup)[:q]
            return LevelResult(mask=mask,
                               supports=sup_np[mask].astype(np.int64),
                               bitmaps=self._slice_survivors(inter, int(mask.sum())))
        inter, sup, mask_dev = fused_intersect(
            bitmaps, _dput(l), _dput(r), _dput(s),
            _dput_i32(min_sup), mode=mode, block_w=self.block_w,
            interpret=self.interpret)
        mask = jax.device_get(mask_dev)[:q].astype(bool)
        sup_np = jax.device_get(sup)[:q]
        sel = np.nonzero(mask)[0]
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=self._compact(inter, sel))


# ---------------------------------------------------------------------------
# sharded backend (shard_map over either single-device executor)
# ---------------------------------------------------------------------------

@register_backend("sharded")
class ShardedEngine(Engine):
    """Executor-task mapping: pairs grouped by partition device, padded per
    device to a common bucket, run under ``shard_map`` with the frontier
    replicated — the paper's communication-free executor stage."""

    def __init__(self, mesh: jax.sharding.Mesh, bucket_min: int = 128,
                 axis: str = "data", inner: str = "pallas",
                 interpret: Optional[bool] = None,
                 *, block_w: Optional[int] = None, compact: bool = True,
                 autotune: bool = False, compact_min: Optional[int] = None):
        super().__init__(bucket_min, block_w=block_w, compact=compact,
                         autotune=autotune, compact_min=compact_min)
        self.mesh = mesh
        self.axis = axis
        self.inner = inner
        self.interpret = interpret
        self.n_devices = int(mesh.shape[axis])
        # upload placements matching the executor's in_specs (see _dput)
        self._rep_sharding = NamedSharding(mesh, P())
        self._pair_sharding = NamedSharding(mesh, P(axis))
        if inner not in ("jnp", "pallas"):
            raise ValueError(f"unknown inner executor {inner!r}")

        def _local(bms, l, r, s, msup, _mode):
            if inner == "pallas":
                # block_w=None resolves through the autotune table at trace
                # time (shard-local shapes), so tuned widths reach the
                # shard_map body without re-plumbing
                inter, sup, _ = fused_intersect(bms, l, r, s, msup,
                                                mode=_mode,
                                                block_w=self.block_w,
                                                interpret=interpret)
            else:
                inter, sup, _ = fused_intersect_ref(bms, l, r, s, msup,
                                                    mode=_mode)
            return inter, sup

        # pallas_call has no shard_map replication rule -> unchecked variant
        smap = shard_map_unchecked if inner == "pallas" else shard_map
        self._sharded = {
            mode: jax.jit(
                smap(
                    lambda bms, l, r, s, m, _mode=mode: _local(bms, l, r, s, m, _mode),
                    mesh=mesh,
                    in_specs=(P(), P(axis), P(axis), P(axis), P()),
                    out_specs=(P(axis), P(axis)),
                )
            )
            for mode in (MODE_TIDSET, MODE_TID_TO_DIFF, MODE_DIFFSET)
        }

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        d = self.n_devices
        qmax, lpad, rpad, spad, slot_of_pair, counts = group_pairs_by_device(
            left, right, sup_left, device_of_pair, d, self.buffers.floor)
        self.device_pair_counts.append(counts)
        self._record_padding(q, d * qmax)
        # tune the shard-LOCAL trace shape: qmax pairs over the full width
        self._maybe_tune(qmax, bitmaps.shape[1], mode)
        out, sup = self._sharded[mode](
            bitmaps,
            _dput(lpad.reshape(d * qmax), self._pair_sharding),
            _dput(rpad.reshape(d * qmax), self._pair_sharding),
            _dput(spad.reshape(d * qmax), self._pair_sharding),
            _dput_i32(min_sup, self._rep_sharding),
        )
        sup_np = jax.device_get(sup).reshape(-1)[slot_of_pair]
        mask = sup_np >= min_sup
        sel = np.nonzero(mask)[0]
        surv = self._compact(out.reshape(d * qmax, -1),
                             slot_of_pair[sel].astype(np.int32))
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=surv)


# ---------------------------------------------------------------------------
# word-axis frontier handling, shared by tidsharded + grid
# ---------------------------------------------------------------------------

class _WordShardedFrontierMixin:
    """The word-axis (tid) half of a mesh-mapped backend: carry the frontier
    as ``P(None, data_axis)`` — rows replicated over every other mesh axis,
    the packed word axis split — and keep it that way across levels.

    Owns exactly three responsibilities (the axis-ownership contract in the
    module docstring): ``_ensure_sharded`` commits/pads a frontier to the
    word sharding, ``_take`` keeps survivor row gathers under that
    constraint so next-level frontiers are *born* word-sharded, and
    ``prepare_frontier`` exposes the placement to drivers that expand one
    frontier many times (the chunked level-2 path).
    """

    def _init_word_axis(self, mesh: jax.sharding.Mesh, data_axis: str) -> None:
        self.mesh = mesh
        self.data_axis = data_axis
        self.n_shards = int(mesh.shape[data_axis])
        self._spec = word_shard_spec(data_axis)
        self._sharding = NamedSharding(mesh, self._spec)
        self._rep_sharding = NamedSharding(mesh, P())
        self._take_rows_sharded = jax.jit(
            lambda arr, idx: jax.lax.with_sharding_constraint(
                jnp.take(arr, idx, axis=0), self._sharding))

    def _ensure_sharded(self, bitmaps: jax.Array) -> jax.Array:
        """Commit the frontier to ``P(None, data_axis)``, zero-padding the
        word axis to a shard multiple.  Frontiers this engine produced are
        already placed (compaction keeps the constraint), so steady-state
        levels are a no-op here."""
        if bitmaps.shape[1] % self.n_shards == 0:
            sh = getattr(bitmaps, "sharding", None)
            if (isinstance(sh, NamedSharding) and sh.mesh == self.mesh
                    and sh.spec == self._spec):
                return bitmaps
        return shard_words(bitmaps, self.mesh, self.data_axis)

    def _take(self, block: jax.Array, idx: jax.Array) -> jax.Array:
        # survivor gather under the word-sharding constraint: rows move (for
        # the grid backend, across the class axis only), the word slices stay
        # on the shard that owns them
        return self._take_rows_sharded(block, idx)

    def prepare_frontier(self, bitmaps: jax.Array) -> jax.Array:
        return self._ensure_sharded(bitmaps)

    def _build_partial_kernels(self, inner: str, interpret: Optional[bool],
                               pair_spec: P, block_spec: P,
                               compact: bool = False) -> Dict[int, Callable]:
        """Per-mode ``jit(shard_map)`` executors over the partial fused
        kernel: shard-local intersect + popcount, one psum over the word
        (data) axis only — class shards, if any, own disjoint pair blocks
        whose counts must never mix — then support conversion and the
        min-support mask on the reduced value.  The pair/block specs are
        the only thing the word-sharded backends differ by: ``P()`` /
        ``P(None, data)`` for ``tidsharded`` (pairs replicated),
        ``P(class)`` / ``P(class, data)`` for ``grid`` (pairs split).

        ``compact=True`` (tidsharded only — its pairs are replicated, so
        survivor order is globally consistent across shards) additionally
        runs the survivor-compaction epilogue *inside* the shard_map body:
        the post-psum mask is replicated, so every shard gathers the same
        survivor rows out of its own word slice, and the padded (Q, W)
        block never exists outside the executable.  Callers pass the true
        pair count ``n_valid`` as an extra traced operand (bucket-pad pairs
        must not be compacted even when their garbage supports pass the
        threshold)."""
        if inner not in ("jnp", "pallas"):
            raise ValueError(f"unknown inner executor {inner!r}")
        data_axis = self.data_axis

        def _local(bms, l, r, s, msup, _mode):
            if inner == "pallas":
                inter, pop = fused_intersect_partial(bms, l, r, mode=_mode,
                                                     block_w=self.block_w,
                                                     interpret=interpret)
            else:
                inter, pop = fused_intersect_partial_ref(bms, l, r, mode=_mode)
            total = jax.lax.psum(pop, data_axis)
            sup = total if _mode == MODE_TIDSET else s - total
            mask = (sup >= msup).astype(jnp.int32)
            return inter, sup, mask

        # pallas_call has no shard_map replication rule -> unchecked variant
        smap = shard_map_unchecked if inner == "pallas" else shard_map
        if compact:
            def _local_compact(bms, l, r, s, msup, nv, _mode):
                inter, sup, mask = _local(bms, l, r, s, msup, _mode)
                return compact_epilogue(inter, sup, mask, nv)

            return {
                mode: jax.jit(
                    smap(
                        lambda bms, l, r, s, m, nv, _mode=mode:
                            _local_compact(bms, l, r, s, m, nv, _mode),
                        mesh=self.mesh,
                        in_specs=(self._spec, pair_spec, pair_spec,
                                  pair_spec, P(), P()),
                        out_specs=(block_spec, pair_spec, pair_spec, P()),
                    )
                )
                for mode in (MODE_TIDSET, MODE_TID_TO_DIFF, MODE_DIFFSET)
            }
        return {
            mode: jax.jit(
                smap(
                    lambda bms, l, r, s, m, _mode=mode: _local(bms, l, r, s, m, _mode),
                    mesh=self.mesh,
                    in_specs=(self._spec, pair_spec, pair_spec, pair_spec, P()),
                    out_specs=(block_spec, pair_spec, pair_spec),
                )
            )
            for mode in (MODE_TIDSET, MODE_TID_TO_DIFF, MODE_DIFFSET)
        }


# ---------------------------------------------------------------------------
# tid-sharded backend (frontier word axis split across the mesh)
# ---------------------------------------------------------------------------

@register_backend("tidsharded")
class TidShardedEngine(_WordShardedFrontierMixin, Engine):
    """Word-sharded executor: the frontier bitmap is carried as
    ``P(None, axis)`` — rows replicated, the packed word (tid) axis split
    across the mesh — so each device stores 1/n_shards of every tidset.

    Per expansion, every shard intersects and popcounts its word slice for
    *all* pairs (the partial kernel), one ``psum`` across shards turns the
    partial counts into supports, and the min-support mask is applied to the
    reduced value.  Survivor compaction is shard-local: with ``compact``
    (default) the prefix-sum compaction epilogue runs *inside* the shard_map
    executable — the post-psum mask is replicated, so every shard gathers
    the same survivor rows out of its own word slice in the same dispatch —
    and with ``compact=False`` it is a separate row gather under a
    ``P(None, axis)`` constraint.  Either way the full (Q, W) intersection
    block never materializes on any single device, the host, or the
    interconnect — only the (Q,) count vector crosses shards.  This is the mode that lets a
    window larger than one device's memory stay minable (DESIGN.md §7);
    trade-off vs the pair-sharded engine: every device does every pair's
    AND, but on 1/n of the words, so compute per device is unchanged while
    memory drops ~1/n.
    """

    def __init__(self, mesh: jax.sharding.Mesh, bucket_min: int = 128,
                 axis: str = "data", inner: str = "pallas",
                 interpret: Optional[bool] = None,
                 *, block_w: Optional[int] = None, compact: bool = True,
                 autotune: bool = False, compact_min: Optional[int] = None):
        super().__init__(bucket_min, block_w=block_w, compact=compact,
                         autotune=autotune, compact_min=compact_min)
        self.inner = inner
        self.interpret = interpret
        self._init_word_axis(mesh, axis)
        # pairs are never distributed in this mode: partition->device routing
        # (device_of_pair) is meaningless and ignored, so advertise a single
        # pair device to the drivers
        self.n_devices = 1
        self._sharded = self._build_partial_kernels(inner, interpret,
                                                    P(), self._spec,
                                                    compact=self.compact)

    def stats(self, since=None) -> dict:
        out = super().stats(since=since)
        out["n_word_shards"] = self.n_shards
        return out

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        qb, l, r, s = self.buffers.fill(left, right, sup_left)
        self._record_padding(q, qb)
        bitmaps = self._ensure_sharded(bitmaps)
        self._maybe_tune(qb, bitmaps.shape[1] // self.n_shards, mode)
        if self.compact:
            rep = self._rep_sharding
            inter, sup, mask_dev, _ = self._sharded[mode](
                bitmaps, _dput(l, rep), _dput(r, rep), _dput(s, rep),
                _dput_i32(min_sup, rep), _dput_i32(q, rep))
            mask = jax.device_get(mask_dev)[:q].astype(bool)
            sup_np = jax.device_get(sup)[:q]
            surv = jax.device_put(
                self._slice_survivors(inter, int(mask.sum())), self._sharding)
            return LevelResult(mask=mask,
                               supports=sup_np[mask].astype(np.int64),
                               bitmaps=surv)
        rep = self._rep_sharding
        inter, sup, mask_dev = self._sharded[mode](
            bitmaps, _dput(l, rep), _dput(r, rep), _dput(s, rep),
            _dput_i32(min_sup, rep))
        mask = jax.device_get(mask_dev)[:q].astype(bool)
        sup_np = jax.device_get(sup)[:q]
        sel = np.nonzero(mask)[0]
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=self._compact(inter, sel))


# ---------------------------------------------------------------------------
# grid-sharded backend (pairs x words on a 2D mesh)
# ---------------------------------------------------------------------------

@register_backend("grid")
class GridShardedEngine(_WordShardedFrontierMixin, Engine):
    """Grid-sharded executor on a 2D ``("class", "data")`` mesh: the pair
    list is split over the **class** axis (grouped by partitioned
    equivalence class, exactly as in :class:`ShardedEngine`) while the
    frontier's packed word (tid) axis is split over the **data** axis
    (exactly as in :class:`TidShardedEngine`).  The frontier is carried as
    ``P(None, "data")`` — replicated over ``"class"``, word-sharded over
    ``"data"`` — so each of the ``n_class * n_data`` devices executes the
    partial fused kernel on one (class-shard pairs) x (word-shard words)
    tile.

    Supports are recovered with one ``psum`` over the **data axis only**:
    the class shards own disjoint pair blocks, so their counts must never
    mix — after the reduce, every device in a data row holds the finished
    supports of its class shard's pairs.  Survivor compaction gathers rows
    under the ``P(None, "data")`` constraint: word slices never cross the
    data axis; survivor rows are replicated over the class axis only (the
    same survivor broadcast the pair-sharded engine performs implicitly),
    so the next level's frontier is born grid-placed.

    Net effect vs the 1D modes (DESIGN.md §8): per-device pair work drops
    ~1/n_class (vs ``tidsharded``, which replicates all pairs) AND
    per-device frontier memory drops ~1/n_data (vs ``sharded``, which
    replicates the whole frontier) — the two scaling axes the paper treats
    separately (executor count, database size), composed on one mesh.
    """

    def __init__(self, mesh: jax.sharding.Mesh, bucket_min: int = 128,
                 class_axis: str = "class", data_axis: str = "data",
                 inner: str = "pallas", interpret: Optional[bool] = None,
                 *, block_w: Optional[int] = None, compact: bool = True,
                 autotune: bool = False, compact_min: Optional[int] = None):
        # grid keeps the post-gather compaction path: its survivors live in
        # per-class pad blocks whose order differs from global pair order,
        # so in-executable compaction would emit them class-blocked;
        # `compact` still tightens the survivor rung via _compact.
        super().__init__(bucket_min, block_w=block_w, compact=compact,
                         autotune=autotune, compact_min=compact_min)
        missing = [a for a in (class_axis, data_axis)
                   if a not in mesh.axis_names]
        if missing:
            raise ValueError(
                f"grid backend needs a 2D ({class_axis!r}, {data_axis!r}) "
                f"mesh (launch.mesh.make_grid_mesh); this mesh has axes "
                f"{tuple(mesh.axis_names)}")
        self.class_axis = class_axis
        self.inner = inner
        self.interpret = interpret
        self._init_word_axis(mesh, data_axis)
        self.n_class = int(mesh.shape[class_axis])
        # drivers route partition->device over the pair (class) axis
        self.n_devices = self.n_class
        self._pair_vec_sharding = NamedSharding(mesh, grid_pair_spec(class_axis))
        self._sharded = self._build_partial_kernels(
            inner, interpret, grid_pair_spec(class_axis),
            grid_block_spec(class_axis, data_axis))

    def stats(self, since=None) -> dict:
        out = super().stats(since=since)
        out["n_class_shards"] = self.n_class
        out["n_word_shards"] = self.n_shards
        out["grid"] = [self.n_class, self.n_shards]
        return out

    def expand(self, bitmaps, left, right, sup_left, *, mode, min_sup,
               device_of_pair=None):
        q = int(left.shape[0])
        if q == 0:
            return self._empty(bitmaps)
        self.n_intersections += q
        d = self.n_class
        qmax, lpad, rpad, spad, slot_of_pair, counts = group_pairs_by_device(
            left, right, sup_left, device_of_pair, d, self.buffers.floor)
        self.device_pair_counts.append(counts)
        self._record_padding(q, d * qmax)
        bitmaps = self._ensure_sharded(bitmaps)
        self._maybe_tune(qmax, bitmaps.shape[1] // self.n_shards, mode)
        inter, sup, mask_dev = self._sharded[mode](
            bitmaps,
            _dput(lpad.reshape(d * qmax), self._pair_vec_sharding),
            _dput(rpad.reshape(d * qmax), self._pair_vec_sharding),
            _dput(spad.reshape(d * qmax), self._pair_vec_sharding),
            _dput_i32(min_sup, self._rep_sharding),
        )
        sup_np = jax.device_get(sup).reshape(-1)[slot_of_pair]
        mask = jax.device_get(mask_dev).reshape(-1)[slot_of_pair].astype(bool)
        sel = np.nonzero(mask)[0]
        surv = self._compact(inter, slot_of_pair[sel].astype(np.int32))
        return LevelResult(mask=mask,
                           supports=sup_np[sel].astype(np.int64),
                           bitmaps=surv)
