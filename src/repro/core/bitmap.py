"""Packed-bitmap tidsets — the TPU-native vertical data format.

The paper stores a tidset as a variable-length list of transaction ids and
intersects tidsets by merging id lists.  On TPU that access pattern is
hostile (pointer chasing, data-dependent shapes), so the framework adopts the
dense *bitmap* encoding of the vertical database:

    B[i, w] : uint32   bit t%32 of word t//32 set  <=>  item i in txn t

Intersection becomes a bitwise AND over words (VPU) and support counting a
``lax.population_count`` reduction — fixed-shape, fully vectorizable, and the
2-itemset "triangular matrix" of the paper becomes a blocked popcount-matmul
(see ``repro.kernels.trimatrix``).

All helpers here exist in two forms: a NumPy form (host-side encode/compact,
used by the driver the way Spark's driver owns dataset prep) and a jnp form
(device-side inner loop).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

WORD_BITS = 32
_WORD_DTYPE = np.uint32

__all__ = [
    "WORD_BITS",
    "n_words",
    "pack_bool_matrix",
    "unpack_bitmap",
    "pack_transactions",
    "popcount_np",
    "support_np",
    "support",
    "intersect_support",
    "pair_intersect",
    "bitmap_or_reduce",
    "column_compact",
]


def n_words(n_txn: int) -> int:
    """Number of uint32 words needed for ``n_txn`` transaction columns."""
    return (int(n_txn) + WORD_BITS - 1) // WORD_BITS


def pack_bool_matrix(dense: np.ndarray) -> np.ndarray:
    """Pack a boolean ``(n_items, n_txn)`` matrix into ``(n_items, W)`` uint32.

    Bit layout: transaction ``t`` lives in word ``t // 32`` at bit ``t % 32``.
    """
    dense = np.asarray(dense, dtype=bool)
    if dense.ndim != 2:
        raise ValueError(f"expected 2-D bool matrix, got shape {dense.shape}")
    n_items, n_txn = dense.shape
    w = n_words(n_txn)
    padded = np.zeros((n_items, w * WORD_BITS), dtype=bool)
    padded[:, :n_txn] = dense
    lanes = padded.reshape(n_items, w, WORD_BITS)
    weights = (np.uint64(1) << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint64)
    packed = (lanes.astype(np.uint64) * weights).sum(axis=-1)
    return packed.astype(_WORD_DTYPE)


def unpack_bitmap(packed: np.ndarray, n_txn: int) -> np.ndarray:
    """Inverse of :func:`pack_bool_matrix` (host-side; used for compaction)."""
    packed = np.asarray(packed, dtype=_WORD_DTYPE)
    n_items, w = packed.shape
    bits = (packed[:, :, None] >> np.arange(WORD_BITS, dtype=_WORD_DTYPE)) & 1
    dense = bits.reshape(n_items, w * WORD_BITS).astype(bool)
    return dense[:, :n_txn]


def pack_transactions(transactions, n_items: int) -> np.ndarray:
    """Encode a horizontal database (iterable of item-id iterables) into the
    packed vertical bitmap ``(n_items, W)``.

    This is Phase-1's ``flatMapToPair -> groupByKey`` collapsed into a single
    scatter: the database is flattened to one (item, tid) pair list and every
    bit is set by one vectorized ``np.bitwise_or.at``.  Duplicate items within
    a transaction are harmless (OR is idempotent) and out-of-range items are
    rejected with the offending transaction id, as before.

    Timing note: the flat scatter replaced a per-transaction Python loop;
    on a T10-style database (100k txns x ~10 items) the encode drops from
    seconds to tens of milliseconds (~30-40x on this container's host CPU).
    """
    txns = [np.asarray(t if isinstance(t, (list, tuple, np.ndarray)) else list(t),
                       dtype=np.int64).reshape(-1) for t in transactions]
    n_txn = len(txns)
    w = n_words(n_txn)
    packed = np.zeros((n_items, w), dtype=_WORD_DTYPE)
    if n_txn == 0:
        return packed
    items = np.concatenate(txns) if txns else np.zeros(0, np.int64)
    if items.size == 0:
        return packed
    tids = np.repeat(np.arange(n_txn, dtype=np.int64), [a.size for a in txns])
    bad = (items < 0) | (items >= n_items)
    if bad.any():
        t = int(tids[int(np.argmax(bad))])
        raise ValueError(f"txn {t} has item outside [0, {n_items})")
    np.bitwise_or.at(
        packed,
        (items, tids // WORD_BITS),
        _WORD_DTYPE(1) << (tids % WORD_BITS).astype(_WORD_DTYPE),
    )
    return packed


def popcount_np(x: np.ndarray) -> np.ndarray:
    """Per-element popcount for host-side uint32 arrays."""
    x = np.asarray(x, dtype=np.uint64)
    # SWAR popcount
    x = x - ((x >> np.uint64(1)) & np.uint64(0x5555555555555555))
    x = (x & np.uint64(0x3333333333333333)) + ((x >> np.uint64(2)) & np.uint64(0x3333333333333333))
    x = (x + (x >> np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    return ((x * np.uint64(0x0101010101010101)) >> np.uint64(56)).astype(np.int64)


def support_np(packed: np.ndarray) -> np.ndarray:
    """Host-side row supports of a packed bitmap ``(n, W)`` -> ``(n,)``."""
    return popcount_np(packed).sum(axis=-1)


# ---------------------------------------------------------------------------
# jnp device-side primitives (the executor-task inner loop)
# ---------------------------------------------------------------------------

def support(packed: jax.Array) -> jax.Array:
    """Row supports ``(..., W) -> (...)`` on device."""
    return jax.lax.population_count(packed).astype(jnp.int32).sum(axis=-1)


def intersect_support(a: jax.Array, b: jax.Array):
    """AND two bitmap batches and return (intersection, support).

    The paper's Algorithm-1 lines 8-9:
        tidset(A_ij) = tidset(A_i) ∩ tidset(A_j);  σ = |tidset(A_ij)|
    """
    inter = jnp.bitwise_and(a, b)
    return inter, support(inter)


@jax.jit
def pair_intersect(bitmaps: jax.Array, left: jax.Array, right: jax.Array):
    """Gather rows ``left``/``right`` from ``bitmaps`` and intersect them.

    bitmaps : (P, W) uint32 frontier tidsets
    left/right : (Q,) int32 pair indices (candidate = itemset(left) ∪ item(right))
    returns (Q, W) intersections and (Q,) supports.
    """
    a = jnp.take(bitmaps, left, axis=0)
    b = jnp.take(bitmaps, right, axis=0)
    return intersect_support(a, b)


@jax.jit
def bitmap_or_reduce(packed: jax.Array) -> jax.Array:
    """OR-reduce rows: which transaction columns are touched by any row."""
    return jax.lax.reduce(
        packed, jnp.uint32(0), jax.lax.bitwise_or, dimensions=(0,)
    )


def column_compact(packed: np.ndarray, n_txn: int, keep_cols: np.ndarray):
    """Re-pack a bitmap keeping only ``keep_cols`` transaction columns.

    This is the bitmap form of the paper's filtered-transaction technique
    (EclatV2, Borgelt): after dropping infrequent items, transactions that
    became empty are removed, shrinking the packed width W and hence every
    subsequent AND/popcount.  Host-side (driver) operation.

    The gather works at the word level: output bit ``j`` of each row is read
    directly from word ``keep_idx[j] // 32`` of the source, and the selected
    bits are re-packed with ``np.packbits`` — the only intermediate is one
    byte per *kept* column, never the dense ``(n_items, W*32)`` matrix the
    old path materialized (which blew up memory on wide databases).
    """
    packed = np.asarray(packed, dtype=_WORD_DTYPE)
    keep_cols = np.asarray(keep_cols)
    if keep_cols.dtype == bool:
        keep_idx = np.nonzero(keep_cols[:n_txn])[0]
    else:
        keep_idx = np.asarray(keep_cols, dtype=np.int64)
    n_items = packed.shape[0]
    k = int(keep_idx.shape[0])
    w_out = n_words(k)
    if k == 0:
        return np.zeros((n_items, 0), dtype=_WORD_DTYPE), 0
    src_word = (keep_idx // WORD_BITS).astype(np.int64)
    src_bit = (keep_idx % WORD_BITS).astype(_WORD_DTYPE)
    bits = ((packed[:, src_word] >> src_bit) & _WORD_DTYPE(1)).astype(np.uint8)
    pad = w_out * WORD_BITS - k
    if pad:
        bits = np.pad(bits, ((0, 0), (0, pad)))
    packed_bytes = np.ascontiguousarray(
        np.packbits(bits, axis=-1, bitorder="little"))
    out = packed_bytes.view("<u4").astype(_WORD_DTYPE)
    return out, k
