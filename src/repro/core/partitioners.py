"""Equivalence-class partitioners (paper §4.5, Algorithm 10) + beyond-paper.

``getPartition(v)`` maps the rank ``v`` of a class's 1-length prefix (ranks
are assigned 0..n-1 in the frequent-item sort order) to a partition id.

Paper partitioners:
  * default       : partition v   -> one class per partition ((n-1) partitions)
  * hash          : v % p                                  (EclatV4)
  * reverse_hash  : r = v % p; v >= p ? (p-1) - r : r       (EclatV5)

Beyond paper:
  * greedy        : LPT bin-packing on an explicit per-class work estimate —
    classes sorted by decreasing estimated work, each placed on the currently
    lightest partition.  The estimate |EC_v|^2 * W counts the AND/popcount
    word-ops of the class's first expansion level, which empirically
    dominates the subtree cost.

The same interface is reused for MoE expert->device placement
(``repro.models.moe``): there ``v`` is the expert id and the work estimate is
the routed token count.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

__all__ = [
    "default_partitioner",
    "hash_partitioner",
    "reverse_hash_partitioner",
    "greedy_partitioner",
    "assign_partitions",
    "partition_stats",
    "pack_items",
    "PARTITIONERS",
]


def default_partitioner(v: np.ndarray, p: int, work: Optional[np.ndarray] = None) -> np.ndarray:
    """Paper's default: class v -> partition v (n-1 singleton partitions).

    With a fixed executor/device count ``p`` Spark schedules those (n-1)
    tasks round-robin; the modulo below is that scheduling step, applied
    after the identity partitioning so semantics match the paper's V1-V3.
    """
    v = np.asarray(v, dtype=np.int64)
    return v % int(p)


def hash_partitioner(v: np.ndarray, p: int, work: Optional[np.ndarray] = None) -> np.ndarray:
    """EclatV4: getPartition(v) = v % p."""
    v = np.asarray(v, dtype=np.int64)
    return v % int(p)


def reverse_hash_partitioner(v: np.ndarray, p: int, work: Optional[np.ndarray] = None) -> np.ndarray:
    """EclatV5: reflect every second "row" of the modulo so that big and small
    classes (class size is monotone in prefix rank) alternate ends."""
    v = np.asarray(v, dtype=np.int64)
    p = int(p)
    r = v % p
    return np.where(v >= p, (p - 1) - r, r)


def greedy_partitioner(v: np.ndarray, p: int, work: Optional[np.ndarray] = None) -> np.ndarray:
    """Beyond-paper LPT: heaviest class first onto the lightest partition."""
    v = np.asarray(v, dtype=np.int64)
    p = int(p)
    if work is None:
        # fall back to the structural estimate: class of rank v among n items
        # has (n-1-v) members -> first-level pair work ~ members^2
        n = int(v.max()) + 1 if v.size else 0
        members = (n - 1 - v).clip(min=0)
        work = members.astype(np.float64) ** 2
    work = np.asarray(work, dtype=np.float64)
    order = np.argsort(-work, kind="stable")
    loads = np.zeros(p, dtype=np.float64)
    out = np.zeros(v.shape[0], dtype=np.int64)
    for idx in order:
        tgt = int(np.argmin(loads))
        out[idx] = tgt
        loads[tgt] += work[idx]
    return out


PARTITIONERS: dict[str, Callable] = {
    "default": default_partitioner,
    "hash": hash_partitioner,
    "reverse_hash": reverse_hash_partitioner,
    "greedy": greedy_partitioner,
}


def assign_partitions(
    n_classes: int,
    partitioner: str,
    p: int,
    work: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Partition table: class rank -> partition id.  This table plus the
    immutable vertical DB is the full lineage of every partition (see
    ``repro.core.lineage``)."""
    if n_classes <= 0:
        return np.zeros(0, dtype=np.int64)
    fn = PARTITIONERS[partitioner]
    v = np.arange(n_classes, dtype=np.int64)
    return fn(v, p, work)


def pack_items(work: np.ndarray, n_slots: int):
    """Greedy-LPT pack ``len(work)`` items into ``n_slots`` balanced groups.

    The one packing entry point every serving-side caller shares
    (``serving.engine.pack_requests``, ``serving.stream_query.pack_queries``,
    the admission drain loop): items are placed heaviest-first on the
    lightest slot and the balance of the assignment that will actually run
    is reported.  Returns ``(assignment, stats)``.
    """
    work = np.asarray(work, dtype=np.float64)
    assign = greedy_partitioner(np.arange(work.shape[0]), int(n_slots),
                                work=work)
    return assign, partition_stats(assign, work, int(n_slots))


def partition_stats(assignment: np.ndarray, work: np.ndarray, p: int) -> dict:
    """Balance metrics.  ``padding_efficiency`` = mean/max per-partition work:
    in the SPMD execution every device steps the padded maximum, so this is
    the fraction of device cycles doing useful ANDs — the TPU restatement of
    the paper's workload-balance argument."""
    loads = np.zeros(int(p), dtype=np.float64)
    np.add.at(loads, np.asarray(assignment, dtype=np.int64), np.asarray(work, dtype=np.float64))
    total = float(loads.sum())
    mx = float(loads.max()) if loads.size else 0.0
    return {
        "loads": loads,
        "max": mx,
        "mean": total / max(int(p), 1),
        "cv": float(loads.std() / loads.mean()) if total > 0 else 0.0,
        "padding_efficiency": (total / (mx * int(p))) if mx > 0 else 1.0,
    }
