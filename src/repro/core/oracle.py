"""Brute-force FIM oracle for correctness tests (host-only, tiny inputs)."""
from __future__ import annotations

from itertools import combinations
from typing import Dict, Sequence, Tuple

__all__ = ["bruteforce_fim"]


def bruteforce_fim(
    transactions: Sequence[Sequence[int]], min_sup: int, max_k: int | None = None
) -> Dict[Tuple[int, ...], int]:
    """All frequent itemsets by direct enumeration.  Exponential — tests only."""
    txn_sets = [frozenset(int(i) for i in t) for t in transactions]
    counts: Dict[int, int] = {}
    for t in txn_sets:
        for i in t:
            counts[i] = counts.get(i, 0) + 1
    freq_items = sorted(i for i, c in counts.items() if c >= min_sup)
    out: Dict[Tuple[int, ...], int] = {}
    # None-check, not truthiness: max_k=0 means "no itemsets", not
    # "unbounded" (staticcheck RS003)
    kmax = len(freq_items) if max_k is None else max_k
    for k in range(1, kmax + 1):
        found_any = False
        for combo in combinations(freq_items, k):
            s = frozenset(combo)
            sup = sum(1 for t in txn_sets if s <= t)
            if sup >= min_sup:
                out[tuple(combo)] = sup
                found_any = True
        if not found_any:
            break
    return out
