"""Itemset store, reconstruction and association-rule generation.

Frontier rows carry (parent pointer, last item) only; this module turns the
per-level row records into explicit itemsets (the ``saveAsTextFile`` analogue)
and implements ARM step 2 (confident rules) for completeness.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["LevelRecord", "ItemsetStore", "generate_rules"]


@dataclasses.dataclass
class LevelRecord:
    """Compact record of one mined level (host-side, bitmap-free)."""

    k: int
    parent: np.ndarray      # (P,) row index into level k-1 (-1 at k == 1)
    item_rank: np.ndarray   # (P,) frequent-item rank of the last item
    support: np.ndarray     # (P,)
    partition: np.ndarray   # (P,)


class ItemsetStore:
    """Accumulates LevelRecords and reconstructs explicit itemsets."""

    def __init__(self, item_ids: np.ndarray):
        self._item_ids = np.asarray(item_ids, dtype=np.int64)
        self.levels: List[LevelRecord] = []

    def add_level(self, rec: LevelRecord) -> None:
        if self.levels and rec.k != self.levels[-1].k + 1:
            raise ValueError("levels must be added in order")
        self.levels.append(rec)

    @property
    def counts(self) -> List[int]:
        return [int(l.parent.shape[0]) for l in self.levels]

    @property
    def total(self) -> int:
        return int(sum(self.counts))

    def itemsets(self) -> List[Tuple[Tuple[int, ...], int]]:
        """All frequent itemsets as (sorted item-id tuple, support)."""
        out: List[Tuple[Tuple[int, ...], int]] = []
        prev_paths: List[Tuple[int, ...]] = []
        for rec in self.levels:
            paths: List[Tuple[int, ...]] = []
            for r in range(rec.parent.shape[0]):
                item = int(self._item_ids[rec.item_rank[r]])
                if rec.k == 1:
                    path = (item,)
                else:
                    path = prev_paths[int(rec.parent[r])] + (item,)
                paths.append(path)
                out.append((tuple(sorted(path)), int(rec.support[r])))
            prev_paths = paths
        return out

    def support_map(self) -> Dict[Tuple[int, ...], int]:
        return dict(self.itemsets())


def generate_rules(
    support_map: Dict[Tuple[int, ...], int], min_conf: float
) -> List[Tuple[Tuple[int, ...], Tuple[int, ...], float, int]]:
    """ARM step 2: rules X => Y with conf = sup(X∪Y)/sup(X) >= min_conf.

    Returns (antecedent, consequent, confidence, support) tuples.
    """
    from itertools import combinations

    rules = []
    for itemset, sup in support_map.items():
        k = len(itemset)
        if k < 2:
            continue
        for r in range(1, k):
            for ante in combinations(itemset, r):
                sup_a = support_map.get(tuple(sorted(ante)))
                if not sup_a:
                    continue
                conf = sup / sup_a
                if conf >= min_conf:
                    cons = tuple(sorted(set(itemset) - set(ante)))
                    rules.append((tuple(sorted(ante)), cons, float(conf), int(sup)))
    return rules
