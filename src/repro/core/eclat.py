"""RDD-Eclat on JAX: the paper's five variants (plus a beyond-paper sixth).

Execution model (see DESIGN.md §2-3): the host process plays the Spark driver
— it owns data-dependent control flow (class segmentation, survivor
bookkeeping, checkpointing) — while devices execute the tidset-intersection
hot loop behind the ``core.engine`` backend interface (jnp reference, fused
Pallas kernel, or shard_map over a mesh).  Equivalence classes are assigned
to partitions once, from their 1-length prefix, and descendants never
migrate: the mining is communication-free after partitioning, exactly the
property the paper engineers on Spark.

This module contains no device-execution details — no pallas, shard_map or
padding logic; ``EclatConfig.backend`` selects the engine backend.

Variants:
  v1  vertical build via scatter, no filtering, default partitioner
  v2  + filtered transactions (bitmap column compaction)
  v3  + accumulator-built vertical DB (psum path)
  v4  v3 + hash partitioner (p user-set)
  v5  v3 + reverse-hash partitioner
  v6  (beyond paper) v3 + greedy-LPT partitioner, optional dEclat diffsets
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import engine as eng
from .accumulator import build_vertical_accumulated
from .equivalence import class_segments, pair_work, segment_pairs
from .itemsets import ItemsetStore, LevelRecord
from .partitioners import assign_partitions, partition_stats
from .triangular import cooccurrence_counts, frequent_pairs
from .vertical import VerticalDB, build_vertical, filter_transactions, filtering_reduction

__all__ = ["EclatConfig", "EclatResult", "mine", "resume_mine",
           "resolve_min_sup", "run_bottom_up", "VARIANTS"]

VARIANTS: Dict[str, dict] = {
    "v1": dict(filter_txns=False, accumulator=False, partitioner="default"),
    "v2": dict(filter_txns=True, accumulator=False, partitioner="default"),
    "v3": dict(filter_txns=True, accumulator=True, partitioner="default"),
    "v4": dict(filter_txns=True, accumulator=True, partitioner="hash"),
    "v5": dict(filter_txns=True, accumulator=True, partitioner="reverse_hash"),
    "v6": dict(filter_txns=True, accumulator=True, partitioner="greedy"),
}


def resolve_min_sup(min_sup, n_txn: int) -> int:
    """Support threshold -> absolute count, disambiguated by *type*:

    - a float in (0, 1] is a support **fraction** of ``n_txn`` (so
      ``min_sup=1.0`` means "appears in every transaction", resolving to
      ``n_txn`` — not the absolute count 1 a value-based cutoff would read);
    - an int >= 1 (or a float > 1) is an absolute **count**.

    Anything else (zero, negatives, bools) is rejected.  Shared by the batch
    and streaming configs: the streaming/batch bit-exactness contract
    (DESIGN.md §5) requires both to resolve a threshold identically.
    """
    if isinstance(min_sup, (bool, np.bool_)):
        raise TypeError(f"min_sup must be a number, got bool {min_sup!r}")
    if isinstance(min_sup, (int, np.integer)):
        if min_sup < 1:
            raise ValueError(f"integer min_sup is an absolute count and must "
                             f"be >= 1, got {int(min_sup)}")
        return int(min_sup)
    f = float(min_sup)
    if 0.0 < f <= 1.0:
        return max(1, int(math.ceil(f * n_txn)))
    if f > 1.0:
        if not f.is_integer():
            raise ValueError(
                f"float min_sup > 1 is an absolute count and must be "
                f"integral (truncating {min_sup!r} would lower the "
                f"threshold); pass an int or a fraction in (0, 1]")
        return int(f)
    raise ValueError(f"min_sup must be a fraction in (0, 1] or an absolute "
                     f"count >= 1, got {min_sup!r}")


@dataclasses.dataclass
class EclatConfig:
    min_sup: float                      # float in (0,1] = fraction; int >= 1 = count
    variant: str = "v4"
    p: int = 10                         # partitions for v4/v5/v6 (paper: p=10)
    tri_matrix: Optional[bool] = None   # None = auto (paper's triMatrixMode)
    tri_matrix_max_items: int = 4096    # auto threshold (paper: item-id range)
    use_diffsets: bool = False          # v6 only (dEclat); other variants reject it
    backend: str = "pallas"             # jnp | pallas | sharded | tidsharded | grid | auto (measured dispatch, DESIGN.md §6; "batched" = legacy alias)
    shard: str = "pairs"                # mesh split: "pairs" (frontier replicated) | "words" (tid axis, DESIGN.md §7) | "grid" (pairs x words 2D mesh, DESIGN.md §8)
    block_w: Optional[int] = None       # fused-kernel word-tile width; None = autotuned table / cost-model seed
    autotune: bool = False              # tune-on-miss: measure untuned kernel shapes before dispatching them
    compact: bool = True                # in-executable survivor compaction (False = legacy mask-roundtrip + gather)
    mode: str = "all"                   # workload: all | closed | maximal (lineage post-filter, DESIGN.md §9)
    max_k: Optional[int] = None         # deepest itemset length to mine (>= 1); None = unbounded
    bucket_min: int = 128               # pair-buffer bucket-ladder floor (half-pow2 rungs; low floor = low padding waste)
    chunk_pairs: int = 1 << 18          # level-2 chunking when tri-matrix off
    checkpoint_dir: Optional[str] = None
    checkpoint_every_level: bool = False

    def resolve_min_sup(self, n_txn: int) -> int:
        return resolve_min_sup(self.min_sup, n_txn)


@dataclasses.dataclass
class EclatResult:
    store: ItemsetStore
    db: Optional[VerticalDB]            # None when resumed from a checkpoint
    stats: dict
    mode: str = "all"                   # the workload mode this run mined for

    @property
    def counts(self) -> List[int]:
        return self.store.counts

    @property
    def total(self) -> int:
        return self.store.total

    def itemsets(self):
        return self.store.itemsets()

    def support_map(self):
        """The full frequent map (every mode mines the whole lattice —
        closed/maximal are post-filters over it, see :meth:`workload_map`)."""
        return self.store.support_map()

    def workload_map(self):
        """The mode-filtered map this run was configured for: the full
        frequent map for ``mode="all"``, its closed or maximal subset
        otherwise (DESIGN.md §9)."""
        from .postfilter import filter_mode
        return filter_mode(self.store.support_map(), self.mode)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_bottom_up(
    execu: eng.Engine,
    store: ItemsetStore,
    lvl_bitmaps: jax.Array,
    class_id: np.ndarray,
    item_rank: np.ndarray,
    partition: np.ndarray,
    support: np.ndarray,
    *,
    abs_min_sup: int,
    mode: int,
    max_k: int,
    part_to_dev: np.ndarray,
    on_level=None,
) -> None:
    """Levels >= 3: per-class level-wise expansion (the paper's Phase-4).

    One shared loop drives both the batch miner and the streaming miner —
    the streaming/batch bit-exactness contract (DESIGN.md §5) depends on
    the survivor bookkeeping below staying identical, so it exists once.
    Starts from a level-2 frontier (``class_id``/``item_rank``/``partition``/
    ``support`` row-aligned with ``lvl_bitmaps``) and appends one
    ``LevelRecord`` per surviving level; ``on_level`` (checkpointing) sees
    every new frontier.
    """
    k = 2
    while support.shape[0] and k < max_k:
        starts, sizes = class_segments(class_id)
        left, right = segment_pairs(starts, sizes)
        if left.size == 0:
            break
        res = execu.expand(
            lvl_bitmaps, left.astype(np.int32), right.astype(np.int32),
            support[left].astype(np.int32),
            mode=mode, min_sup=abs_min_sup,
            device_of_pair=part_to_dev[partition[left]],
        )
        k += 1
        if not res.mask.any():
            break
        sel = np.nonzero(res.mask)[0]
        parent = left[sel]
        item_rank = item_rank[right[sel]]
        class_id = left[sel]
        partition = partition[left[sel]]
        support = res.supports
        store.add_level(LevelRecord(k=k, parent=parent, item_rank=item_rank,
                                    support=support, partition=partition))
        lvl_bitmaps = res.bitmaps
        if on_level is not None:
            on_level(k, class_id, item_rank, partition, support, lvl_bitmaps)


def _build_db(transactions, n_items, abs_min_sup, spec, mesh) -> Tuple[VerticalDB, dict]:
    info: dict = {}
    if spec["accumulator"]:
        db = build_vertical_accumulated(
            transactions, n_items, abs_min_sup, order="support_asc",
            mesh=mesh if mesh is not None else None,
        )
    else:
        db = build_vertical(transactions, n_items, abs_min_sup, order="support_asc")
    if spec["filter_txns"]:
        before = db
        db = filter_transactions(db)
        info["filter_reduction"] = filtering_reduction(before, db)
    return db, info


def _finish(store: ItemsetStore, db: VerticalDB, stats: dict,
            config: EclatConfig, t_start: float) -> EclatResult:
    """Common tail of every ``mine()`` return path: record the workload
    mode (and, for closed/maximal, the post-filtered count — the filter
    itself is lazy via :meth:`EclatResult.workload_map`) and stamp wall
    time last so it covers the mode bookkeeping too."""
    stats["mode"] = config.mode
    res = EclatResult(store=store, db=db, stats=stats, mode=config.mode)
    if config.mode != "all":
        stats["mode_itemsets"] = len(res.workload_map())
    stats["total_s"] = time.perf_counter() - t_start
    return res


def mine(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    config: EclatConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> EclatResult:
    """Mine all frequent itemsets.  ``mesh`` enables the mesh-mapped
    backends (``config.shard`` picks pair-, word-, or 2D grid-sharding)."""
    spec = VARIANTS[config.variant]
    if config.use_diffsets and config.variant != "v6":
        # every variant but v6 mines tidsets; silently dropping the flag
        # would hand back correct-looking results from a different algorithm
        raise ValueError(
            f"use_diffsets is only supported by variant 'v6' (dEclat); "
            f"variant {config.variant!r} would silently ignore it")
    if config.max_k is not None and config.max_k < 1:
        raise ValueError(f"max_k must be >= 1 (or None for unbounded), "
                         f"got {config.max_k}")
    from .postfilter import WORKLOAD_MODES
    if config.mode not in WORKLOAD_MODES:
        raise ValueError(f"unknown workload mode {config.mode!r}; "
                         f"expected one of {WORKLOAD_MODES}")
    t_start = time.perf_counter()
    stats: dict = {"variant": config.variant, "phase_s": {}}

    n_txn = len(transactions)
    abs_min_sup = config.resolve_min_sup(n_txn)
    stats["abs_min_sup"] = abs_min_sup

    # ---- Phase 1 (+2 filtering / +3 accumulator): vertical DB -------------
    t0 = time.perf_counter()
    db, info = _build_db(transactions, n_items, abs_min_sup, spec, mesh)
    stats.update(info)
    stats["phase_s"]["vertical"] = time.perf_counter() - t0
    n1, w = db.n_items, db.n_words
    stats["n_freq_items"] = n1
    stats["n_words"] = w

    store = ItemsetStore(db.items)
    # partition table over 1-length-prefix classes (class rank r, r < n1-1)
    n_classes = max(n1 - 1, 0)
    sizes1 = (n1 - 1 - np.arange(n_classes)).clip(min=0)
    est = pair_work(sizes1 + 1, w)  # +1: member count of class r is n1-1-r
    eff_p = config.p if spec["partitioner"] in ("hash", "reverse_hash", "greedy") else max(n_classes, 1)
    table = assign_partitions(n_classes, spec["partitioner"], eff_p, work=est)
    # dispatch hints for backend="auto": the dominant expansion is level 2
    # (all cross-class pairs of the n1 frequent items over w words); the
    # measured crossover table is indexed by exactly that (Q, W) shape
    est_q2 = n1 * (n1 - 1) // 2
    execu = eng.resolve_engine(config.backend, mesh,
                               bucket_min=config.bucket_min,
                               shard=config.shard,
                               block_w=config.block_w,
                               autotune=config.autotune,
                               compact=config.compact,
                               hints=(max(est_q2, 1), max(w, 1)))
    stats["backend"] = execu.name
    stats["backend_requested"] = config.backend
    # partition -> device round robin (mesh-mapped backends' pair axis)
    part_to_dev = np.arange(eff_p, dtype=np.int64) % max(execu.n_devices, 1)

    # balance of the *estimated* class work that drove partitioning (the
    # pair_work model the partitioners optimized), not a uniform per-pair
    # weight — so the reported efficiency reflects the actual assignment.
    # Recorded up front so every return path (max_k=1, single frequent
    # item, full run) carries the same stats shape.
    if n_classes > 0:
        pstats = partition_stats(table, est, eff_p)
        stats["partition_balance"] = {
            **{k_: v for k_, v in pstats.items() if k_ != "loads"},
            "estimated_loads": pstats["loads"].tolist(),
        }

    lvl1_partition = np.concatenate([table, [table[-1] if n_classes else 0]])[:n1] if n1 else np.zeros(0, np.int64)
    store.add_level(
        LevelRecord(
            k=1,
            parent=np.full(n1, -1, np.int64),
            item_rank=np.arange(n1, dtype=np.int64),
            support=db.supports.astype(np.int64),
            partition=lvl1_partition,
        )
    )
    # max_k bounds every level, including 2: with max_k=1 the frequent items
    # are the whole answer (the regression was recording level 2 regardless)
    max_k = n1 if config.max_k is None else config.max_k
    if n1 < 2 or max_k < 2:
        stats.update(execu.stats())
        return _finish(store, db, stats, config, t_start)

    # place the level-1 frontier the way the backend carries it, once —
    # the chunked no-tri-matrix path below expands the same frontier many
    # times, and per-call placement (a word-axis reshard for tidsharded)
    # would repeat for every chunk
    bitmaps = execu.prepare_frontier(jax.device_put(db.bitmaps))
    diffsets = config.use_diffsets

    # ---- Phase 2: triangular matrix (2-itemset counts) --------------------
    t0 = time.perf_counter()
    tri = config.tri_matrix
    if tri is None:
        tri = n1 <= config.tri_matrix_max_items  # paper's BMS1/BMS2 opt-out
    stats["tri_matrix"] = bool(tri)

    sup1 = db.supports.astype(np.int32)
    mode2 = eng.MODE_TID_TO_DIFF if diffsets else eng.MODE_TIDSET
    if tri:
        counts2 = cooccurrence_counts(bitmaps)
        iu, ju, _ = frequent_pairs(counts2, abs_min_sup)
        # materialize bitmaps only for the survivors; every pre-filtered pair
        # must pass the engine's threshold again
        res = execu.expand(
            bitmaps, iu.astype(np.int32), ju.astype(np.int32), sup1[iu],
            mode=mode2, min_sup=abs_min_sup,
            device_of_pair=part_to_dev[table[iu]] if iu.size else None,
        )
        # the level-2 LevelRecord below aligns iu/ju (all pre-filtered
        # pairs) with res.supports (survivors only) on the assumption that
        # the two sets are identical; a corrupt triangular count matrix
        # breaks that silently, misaligning every deeper level.  Same
        # contract as the streaming miner's cached-count check — a real
        # exception, not an ``assert``, so it fires under ``python -O``.
        if iu.size and not res.mask.all():
            bad = np.nonzero(~res.mask)[0]
            raise RuntimeError(
                f"triangular-matrix co-occurrence counts disagree with the "
                f"engine on {bad.size}/{res.mask.size} level-2 pair(s) "
                f"(first: item ranks {int(iu[bad[0]])},{int(ju[bad[0]])}) — "
                f"the tri-matrix pass is corrupt")
        sup2 = res.supports.astype(np.int32)
        lvl_bitmaps = res.bitmaps
    else:
        # chunked all-pairs (the paper's no-tri-matrix path for BMS datasets)
        iu_all, ju_all = np.triu_indices(n1, k=1)
        keep_i, keep_j, keep_s, keep_bm = [], [], [], []
        for s in range(0, iu_all.shape[0], config.chunk_pairs):
            ic = iu_all[s: s + config.chunk_pairs].astype(np.int32)
            jc = ju_all[s: s + config.chunk_pairs].astype(np.int32)
            res = execu.expand(
                bitmaps, ic, jc, sup1[ic],
                mode=mode2, min_sup=abs_min_sup,
                device_of_pair=part_to_dev[table[ic]] if ic.size else None,
            )
            if res.mask.any():
                keep_i.append(ic[res.mask]); keep_j.append(jc[res.mask])
                keep_s.append(res.supports.astype(np.int32))
                # chunks are concatenated into one frontier: strip the
                # engine's rung padding so survivor rows stay contiguous
                keep_bm.append(res.bitmaps[: int(res.mask.sum())])
        if keep_i:
            iu = np.concatenate(keep_i).astype(np.int64)
            ju = np.concatenate(keep_j).astype(np.int64)
            sup2 = np.concatenate(keep_s)
            lvl_bitmaps = jnp.concatenate(keep_bm, axis=0)
        else:
            iu = ju = np.zeros(0, np.int64); sup2 = np.zeros(0, np.int32)
            lvl_bitmaps = jnp.zeros((0, w), jnp.uint32)
    stats["phase_s"]["tri_matrix"] = time.perf_counter() - t0

    parent = iu.copy()
    item_rank = ju.copy()
    class_id = iu.copy()
    partition = table[iu] if iu.size else np.zeros(0, np.int64)
    support = sup2.astype(np.int64)
    store.add_level(LevelRecord(k=2, parent=parent, item_rank=item_rank,
                                support=support, partition=partition))

    # ---- Phase 3/4: level-wise Bottom-Up -----------------------------------
    t0 = time.perf_counter()
    mode_k = eng.MODE_DIFFSET if diffsets else eng.MODE_TIDSET

    on_level = None
    if config.checkpoint_dir and config.checkpoint_every_level:
        from .lineage import save_mining_checkpoint
        # resume metadata: everything resume_mine needs that is not derivable
        # from the frontier arrays themselves (DESIGN.md §10)
        ckpt_meta = {"abs_min_sup": int(abs_min_sup), "engine_mode": int(mode_k),
                     "max_k": int(max_k), "eff_p": int(eff_p),
                     "use_diffsets": bool(diffsets)}

        def on_level(k, class_id, item_rank, partition, support, lvl_bitmaps):
            # slice the rung padding off on device before the host transfer
            save_mining_checkpoint(config.checkpoint_dir, store, k, class_id,
                                   item_rank, partition, support,
                                   jax.device_get(lvl_bitmaps[: support.shape[0]]),
                                   meta=ckpt_meta)

    run_bottom_up(execu, store, lvl_bitmaps, class_id, item_rank, partition,
                  support, abs_min_sup=abs_min_sup, mode=mode_k,
                  max_k=max_k, part_to_dev=part_to_dev,
                  on_level=on_level)
    stats["phase_s"]["bottom_up"] = time.perf_counter() - t0

    stats.update(execu.stats())
    return _finish(store, db, stats, config, t_start)


def resume_mine(
    config: EclatConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> EclatResult:
    """Continue a batch mine from its deepest per-level checkpoint.

    Reads the newest ``mining_ckpt_k*.npz`` under ``config.checkpoint_dir``
    (written by ``mine()`` with ``checkpoint_every_level=True``), rebuilds
    the store and frontier, and resumes ``run_bottom_up`` from the
    checkpointed level.  The engine is resolved fresh from *this* process's
    ``config.backend`` / ``config.shard`` / ``mesh`` — restore onto fewer
    devices, a different grid factorization, or a single device, and the
    frontier is re-placed by ``prepare_frontier`` under the new specs
    (DESIGN.md §10): the remaining levels come out bit-exact because every
    backend is bit-exact on the same frontier.  The original transactions
    are not needed; ``EclatResult.db`` is ``None`` on a resumed run.
    """
    from .lineage import (latest_mining_checkpoint, load_mining_checkpoint,
                          save_mining_checkpoint)

    if not config.checkpoint_dir:
        raise ValueError("resume_mine needs config.checkpoint_dir")
    t_start = time.perf_counter()
    path = latest_mining_checkpoint(config.checkpoint_dir)
    store, fr = load_mining_checkpoint(path)
    meta = fr.get("meta") or {}
    if "abs_min_sup" not in meta:
        raise ValueError(
            f"{path} predates resume metadata — re-run the original mine "
            f"with this version to write a resumable checkpoint")
    abs_min_sup = int(meta["abs_min_sup"])
    mode_k = int(meta["engine_mode"])
    max_k = int(meta["max_k"])
    eff_p = int(meta["eff_p"])
    stats: dict = {"variant": config.variant, "phase_s": {},
                   "abs_min_sup": abs_min_sup,
                   "resumed_from": path, "resume_k": int(fr["k"])}

    execu = eng.resolve_engine(config.backend, mesh,
                               bucket_min=config.bucket_min,
                               shard=config.shard,
                               block_w=config.block_w,
                               autotune=config.autotune,
                               compact=config.compact)
    stats["backend"] = execu.name
    stats["backend_requested"] = config.backend
    part_to_dev = np.arange(eff_p, dtype=np.int64) % max(execu.n_devices, 1)
    lvl_bitmaps = execu.prepare_frontier(jax.device_put(fr["bitmaps"]))

    on_level = None
    if config.checkpoint_every_level:
        def on_level(k, class_id, item_rank, partition, support, lvl_bitmaps):
            save_mining_checkpoint(config.checkpoint_dir, store, k, class_id,
                                   item_rank, partition, support,
                                   jax.device_get(lvl_bitmaps[: support.shape[0]]),
                                   meta=meta)

    t0 = time.perf_counter()
    run_bottom_up(execu, store, lvl_bitmaps,
                  class_id=np.asarray(fr["class_id"]),
                  item_rank=np.asarray(fr["item_rank"]),
                  partition=np.asarray(fr["partition"]),
                  support=np.asarray(fr["support"]).astype(np.int64),
                  abs_min_sup=abs_min_sup, mode=mode_k, max_k=max_k,
                  part_to_dev=part_to_dev, on_level=on_level)
    stats["phase_s"]["bottom_up"] = time.perf_counter() - t0
    stats.update(execu.stats())
    return _finish(store, None, stats, config, t_start)
