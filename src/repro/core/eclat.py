"""RDD-Eclat on JAX: the paper's five variants (plus a beyond-paper sixth).

Execution model (see DESIGN.md §2): the host process plays the Spark driver —
it owns data-dependent control flow (class segmentation, survivor compaction,
checkpointing) — while devices execute fixed-shape batched AND+popcount over
bucket-padded pair lists (the executor tasks).  Equivalence classes are
assigned to partitions once, from their 1-length prefix, and descendants
never migrate: the mining is communication-free after partitioning, exactly
the property the paper engineers on Spark.

Variants:
  v1  vertical build via scatter, no filtering, default partitioner
  v2  + filtered transactions (bitmap column compaction)
  v3  + accumulator-built vertical DB (psum path)
  v4  v3 + hash partitioner (p user-set)
  v5  v3 + reverse-hash partitioner
  v6  (beyond paper) v3 + greedy-LPT partitioner, optional dEclat diffsets
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.compat import shard_map
from . import bitmap as bm
from .accumulator import build_vertical_accumulated
from .equivalence import class_segments, pair_work, segment_pairs
from .itemsets import ItemsetStore, LevelRecord
from .partitioners import assign_partitions, partition_stats
from .triangular import cooccurrence_counts, frequent_pairs
from .vertical import VerticalDB, build_vertical, filter_transactions, filtering_reduction

__all__ = ["EclatConfig", "EclatResult", "mine", "VARIANTS"]

VARIANTS: Dict[str, dict] = {
    "v1": dict(filter_txns=False, accumulator=False, partitioner="default"),
    "v2": dict(filter_txns=True, accumulator=False, partitioner="default"),
    "v3": dict(filter_txns=True, accumulator=True, partitioner="default"),
    "v4": dict(filter_txns=True, accumulator=True, partitioner="hash"),
    "v5": dict(filter_txns=True, accumulator=True, partitioner="reverse_hash"),
    "v6": dict(filter_txns=True, accumulator=True, partitioner="greedy"),
}


@dataclasses.dataclass
class EclatConfig:
    min_sup: float                      # fraction (<1) or absolute count (>=1)
    variant: str = "v4"
    p: int = 10                         # partitions for v4/v5/v6 (paper: p=10)
    tri_matrix: Optional[bool] = None   # None = auto (paper's triMatrixMode)
    tri_matrix_max_items: int = 4096    # auto threshold (paper: item-id range)
    use_diffsets: bool = False          # v6 only (dEclat)
    backend: str = "batched"            # batched | sharded
    max_k: Optional[int] = None
    bucket_min: int = 1024              # pair-buffer bucket floor
    chunk_pairs: int = 1 << 18          # level-2 chunking when tri-matrix off
    checkpoint_dir: Optional[str] = None
    checkpoint_every_level: bool = False

    def resolve_min_sup(self, n_txn: int) -> int:
        if self.min_sup >= 1:
            return int(self.min_sup)
        return max(1, int(math.ceil(self.min_sup * n_txn)))


@dataclasses.dataclass
class EclatResult:
    store: ItemsetStore
    db: VerticalDB
    stats: dict

    @property
    def counts(self) -> List[int]:
        return self.store.counts

    @property
    def total(self) -> int:
        return self.store.total

    def itemsets(self):
        return self.store.itemsets()

    def support_map(self):
        return self.store.support_map()


# ---------------------------------------------------------------------------
# device executors
# ---------------------------------------------------------------------------

def _bucket(n: int, floor: int) -> int:
    b = max(int(floor), 1)
    while b < n:
        b <<= 1
    return b


@jax.jit
def _pairs_tidset(bitmaps, left, right):
    a = jnp.take(bitmaps, left, axis=0)
    b = jnp.take(bitmaps, right, axis=0)
    inter = jnp.bitwise_and(a, b)
    return inter, jax.lax.population_count(inter).astype(jnp.int32).sum(-1)


@jax.jit
def _pairs_diffset(bitmaps, left, right, sup_left):
    """dEclat: d(Pab) = d(Pb) \\ d(Pa); sup = sup(Pa) - |d(Pab)|."""
    a = jnp.take(bitmaps, left, axis=0)
    b = jnp.take(bitmaps, right, axis=0)
    diff = jnp.bitwise_and(b, jnp.bitwise_not(a))
    return diff, sup_left - jax.lax.population_count(diff).astype(jnp.int32).sum(-1)


@jax.jit
def _pairs_tid_to_diff(bitmaps, left, right, sup_left):
    """Tidset -> diffset switch level: d(ij) = t(i) \\ t(j)."""
    a = jnp.take(bitmaps, left, axis=0)
    b = jnp.take(bitmaps, right, axis=0)
    diff = jnp.bitwise_and(a, jnp.bitwise_not(b))
    return diff, sup_left - jax.lax.population_count(diff).astype(jnp.int32).sum(-1)


class _Executor:
    """Runs padded pair batches; batched (1-device) or shard_map (D devices)."""

    def __init__(self, cfg: EclatConfig, mesh: Optional[jax.sharding.Mesh], axis: str = "data"):
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.n_intersections = 0
        self.n_padded = 0
        self.device_pair_counts: List[np.ndarray] = []
        if mesh is not None:
            d = mesh.shape[axis]

            def _local(bitmaps, left, right, sup_left, mode):
                # left/right/sup_left arrive as this device's (qmax,) slice
                if mode == 0:
                    return _pairs_tidset(bitmaps, left, right)
                if mode == 1:
                    return _pairs_tid_to_diff(bitmaps, left, right, sup_left)
                return _pairs_diffset(bitmaps, left, right, sup_left)

            self._sharded = {
                mode: jax.jit(
                    shard_map(
                        lambda bms, l, r, s, _m=mode: _local(bms, l, r, s, _m),
                        mesh=mesh,
                        in_specs=(P(), P(axis), P(axis), P(axis)),
                        out_specs=(P(axis), P(axis)),
                    )
                )
                for mode in (0, 1, 2)
            }
            self.n_devices = d
        else:
            self.n_devices = 1

    def run(self, bitmaps, left, right, sup_left, device_of_pair, mode: int):
        """mode: 0=tidset AND, 1=tidset->diffset, 2=diffset.

        Returns (out_bitmaps, supports) aligned with the input pair order.
        """
        q = left.shape[0]
        self.n_intersections += int(q)
        if self.mesh is None:
            qb = _bucket(q, self.cfg.bucket_min)
            lpad = np.zeros(qb, np.int32)
            rpad = np.zeros(qb, np.int32)
            spad = np.zeros(qb, np.int32)
            lpad[:q], rpad[:q], spad[:q] = left, right, sup_left
            if mode == 0:
                out, sup = _pairs_tidset(bitmaps, jnp.asarray(lpad), jnp.asarray(rpad))
            elif mode == 1:
                out, sup = _pairs_tid_to_diff(bitmaps, jnp.asarray(lpad), jnp.asarray(rpad), jnp.asarray(spad))
            else:
                out, sup = _pairs_diffset(bitmaps, jnp.asarray(lpad), jnp.asarray(rpad), jnp.asarray(spad))
            self.n_padded += qb - q
            return out, np.asarray(sup)[:q], np.arange(q)

        # sharded: order pairs by device, pad each device block to the bucket
        d = self.n_devices
        order = np.argsort(device_of_pair, kind="stable")
        counts = np.bincount(device_of_pair, minlength=d)
        self.device_pair_counts.append(counts)
        qmax = _bucket(int(counts.max()) if q else 1, self.cfg.bucket_min)
        lpad = np.zeros((d, qmax), np.int32)
        rpad = np.zeros((d, qmax), np.int32)
        spad = np.zeros((d, qmax), np.int32)
        slot_of_pair = np.empty(q, np.int64)
        off = 0
        for dev in range(d):
            c = int(counts[dev])
            idx = order[off: off + c]
            lpad[dev, :c] = left[idx]
            rpad[dev, :c] = right[idx]
            spad[dev, :c] = sup_left[idx]
            slot_of_pair[idx] = dev * qmax + np.arange(c)
            off += c
        self.n_padded += d * qmax - q
        out, sup = self._sharded[mode](
            bitmaps,
            jnp.asarray(lpad.reshape(d * qmax)),
            jnp.asarray(rpad.reshape(d * qmax)),
            jnp.asarray(spad.reshape(d * qmax)),
        )
        return out, np.asarray(sup).reshape(-1)[slot_of_pair], slot_of_pair


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _build_db(transactions, n_items, abs_min_sup, spec, mesh) -> Tuple[VerticalDB, dict]:
    info: dict = {}
    if spec["accumulator"]:
        db = build_vertical_accumulated(
            transactions, n_items, abs_min_sup, order="support_asc",
            mesh=mesh if mesh is not None else None,
        )
    else:
        db = build_vertical(transactions, n_items, abs_min_sup, order="support_asc")
    if spec["filter_txns"]:
        before = db
        db = filter_transactions(db)
        info["filter_reduction"] = filtering_reduction(before, db)
    return db, info


def mine(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    config: EclatConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> EclatResult:
    """Mine all frequent itemsets.  ``mesh`` enables the sharded backend."""
    spec = VARIANTS[config.variant]
    t_start = time.perf_counter()
    stats: dict = {"variant": config.variant, "phase_s": {}}

    n_txn = len(transactions)
    abs_min_sup = config.resolve_min_sup(n_txn)
    stats["abs_min_sup"] = abs_min_sup

    # ---- Phase 1 (+2 filtering / +3 accumulator): vertical DB -------------
    t0 = time.perf_counter()
    db, info = _build_db(transactions, n_items, abs_min_sup, spec, mesh)
    stats.update(info)
    stats["phase_s"]["vertical"] = time.perf_counter() - t0
    n1, w = db.n_items, db.n_words
    stats["n_freq_items"] = n1
    stats["n_words"] = w

    store = ItemsetStore(db.items)
    # partition table over 1-length-prefix classes (class rank r, r < n1-1)
    n_classes = max(n1 - 1, 0)
    sizes1 = (n1 - 1 - np.arange(n_classes)).clip(min=0)
    est = pair_work(sizes1 + 1, w)  # +1: member count of class r is n1-1-r
    eff_p = config.p if spec["partitioner"] in ("hash", "reverse_hash", "greedy") else max(n_classes, 1)
    table = assign_partitions(n_classes, spec["partitioner"], eff_p, work=est)
    n_dev = mesh.shape["data"] if mesh is not None else 1
    device_of_partition = (table % max(n_dev, 1)) if spec["partitioner"] == "default" else None
    # partition -> device round robin
    part_to_dev = np.arange(eff_p, dtype=np.int64) % max(n_dev, 1)

    lvl1_partition = np.concatenate([table, [table[-1] if n_classes else 0]])[:n1] if n1 else np.zeros(0, np.int64)
    store.add_level(
        LevelRecord(
            k=1,
            parent=np.full(n1, -1, np.int64),
            item_rank=np.arange(n1, dtype=np.int64),
            support=db.supports.astype(np.int64),
            partition=lvl1_partition,
        )
    )
    if n1 < 2:
        stats["total_s"] = time.perf_counter() - t_start
        return EclatResult(store=store, db=db, stats=stats)

    execu = _Executor(config, mesh)
    bitmaps = jnp.asarray(db.bitmaps)
    diffsets = config.use_diffsets and config.variant == "v6"

    # ---- Phase 2: triangular matrix (2-itemset counts) --------------------
    t0 = time.perf_counter()
    tri = config.tri_matrix
    if tri is None:
        tri = n1 <= config.tri_matrix_max_items  # paper's BMS1/BMS2 opt-out
    stats["tri_matrix"] = bool(tri)

    sup1 = db.supports.astype(np.int32)
    if tri:
        counts2 = cooccurrence_counts(bitmaps)
        iu, ju, sup2 = frequent_pairs(counts2, abs_min_sup)
        # materialize bitmaps only for the survivors
        mode = 1 if diffsets else 0
        out, sup_chk, slots = execu.run(
            bitmaps, iu.astype(np.int32), ju.astype(np.int32), sup1[iu],
            part_to_dev[table[iu]] if iu.size else np.zeros(0, np.int64), mode,
        )
        lvl_bitmaps = jnp.take(out.reshape(-1, w), jnp.asarray(slots, jnp.int32), axis=0)
        sup2 = sup_chk
        keep = sup2 >= abs_min_sup  # all true by construction, keeps code uniform
        iu, ju, sup2, lvl_bitmaps = iu[keep], ju[keep], sup2[keep], lvl_bitmaps[jnp.asarray(np.nonzero(keep)[0])]
    else:
        # chunked all-pairs (the paper's no-tri-matrix path for BMS datasets)
        iu_all, ju_all = np.triu_indices(n1, k=1)
        mode = 1 if diffsets else 0
        keep_i, keep_j, keep_s, keep_bm = [], [], [], []
        for s in range(0, iu_all.shape[0], config.chunk_pairs):
            ic = iu_all[s: s + config.chunk_pairs].astype(np.int32)
            jc = ju_all[s: s + config.chunk_pairs].astype(np.int32)
            out, sup, slots = execu.run(
                bitmaps, ic, jc, sup1[ic],
                part_to_dev[table[ic]] if ic.size else np.zeros(0, np.int64), mode,
            )
            m = sup >= abs_min_sup
            if m.any():
                keep_i.append(ic[m]); keep_j.append(jc[m]); keep_s.append(sup[m])
                keep_bm.append(jnp.take(out.reshape(-1, w), jnp.asarray(slots[m], jnp.int32), axis=0))
        if keep_i:
            iu = np.concatenate(keep_i).astype(np.int64)
            ju = np.concatenate(keep_j).astype(np.int64)
            sup2 = np.concatenate(keep_s)
            lvl_bitmaps = jnp.concatenate(keep_bm, axis=0)
        else:
            iu = ju = np.zeros(0, np.int64); sup2 = np.zeros(0, np.int32)
            lvl_bitmaps = jnp.zeros((0, w), jnp.uint32)
    stats["phase_s"]["tri_matrix"] = time.perf_counter() - t0

    parent = iu.copy()
    item_rank = ju.copy()
    class_id = iu.copy()
    partition = table[iu] if iu.size else np.zeros(0, np.int64)
    support = sup2.astype(np.int64)
    store.add_level(LevelRecord(k=2, parent=parent, item_rank=item_rank,
                                support=support, partition=partition))

    # ---- Phase 3/4: level-wise Bottom-Up -----------------------------------
    t0 = time.perf_counter()
    k = 2
    max_k = config.max_k or n1
    while support.shape[0] and k < max_k:
        starts, sizes = class_segments(class_id)
        left, right = segment_pairs(starts, sizes)
        if left.size == 0:
            break
        mode = 2 if diffsets else 0
        dev = part_to_dev[partition[left]]
        out, sup, slots = execu.run(
            lvl_bitmaps, left.astype(np.int32), right.astype(np.int32),
            support[left].astype(np.int32), dev, mode,
        )
        m = sup >= abs_min_sup
        k += 1
        if not m.any():
            break
        sel = np.nonzero(m)[0]
        new_bitmaps = jnp.take(out.reshape(-1, w), jnp.asarray(slots[sel], jnp.int32), axis=0)
        parent = left[sel]
        item_rank_new = item_rank[right[sel]]
        class_id_new = left[sel]
        partition_new = partition[left[sel]]
        support_new = sup[sel].astype(np.int64)
        store.add_level(LevelRecord(k=k, parent=parent, item_rank=item_rank_new,
                                    support=support_new, partition=partition_new))
        lvl_bitmaps = new_bitmaps
        item_rank, class_id, partition, support = item_rank_new, class_id_new, partition_new, support_new
        if config.checkpoint_dir and config.checkpoint_every_level:
            from .lineage import save_mining_checkpoint
            save_mining_checkpoint(config.checkpoint_dir, store, k, class_id,
                                   item_rank, partition, support, np.asarray(lvl_bitmaps))
    stats["phase_s"]["bottom_up"] = time.perf_counter() - t0

    # ---- balance bookkeeping ----------------------------------------------
    lvl2 = store.levels[1] if len(store.levels) > 1 else None
    if lvl2 is not None and lvl2.partition.size:
        work = np.ones_like(lvl2.partition, dtype=np.float64) * w
        stats["partition_balance"] = {
            k_: v for k_, v in partition_stats(lvl2.partition, work, eff_p).items() if k_ != "loads"
        }
    if execu.device_pair_counts:
        per_dev = np.sum(execu.device_pair_counts, axis=0)
        stats["device_balance"] = {
            "pairs_per_device": per_dev.tolist(),
            "padding_efficiency": float(per_dev.sum() / (per_dev.max() * per_dev.shape[0]))
            if per_dev.max() > 0 else 1.0,
        }
    stats["n_intersections"] = execu.n_intersections
    stats["n_padded"] = execu.n_padded
    stats["total_s"] = time.perf_counter() - t_start
    return EclatResult(store=store, db=db, stats=stats)
