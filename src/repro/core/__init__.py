"""repro.core — RDD-Eclat (the paper's contribution) on JAX.

Public surface:
  mine / EclatConfig / EclatResult     level-wise RDD-Eclat, variants v1..v6
  make_engine / available_backends      pluggable device-executor backends
  apriori_mine                          YAFIM-style Spark-Apriori baseline
  bruteforce_fim                        exact oracle for tests
  closed/maximal_itemsets, top_k_mine   workload modes (lineage post-filters)
  build_vertical / filter_transactions  vertical DB construction
  assign_partitions / partition_stats   equivalence-class partitioners
  recover_partition                     lineage-based partition recovery
  generate_rules                        ARM step 2
"""
from .apriori import AprioriResult, apriori_mine
from .eclat import VARIANTS, EclatConfig, EclatResult, mine, resume_mine
from .engine import (Engine, EngineState, LevelResult, available_backends,
                     engine_from_state, make_engine, register_backend)
from .itemsets import ItemsetStore, LevelRecord, generate_rules
from .lineage import (latest_mining_checkpoint, load_mining_checkpoint,
                      recover_partition, save_mining_checkpoint)
from .oracle import bruteforce_fim
from .postfilter import (WORKLOAD_MODES, TopKResult, closed_itemsets,
                         filter_mode, frequent_from_closed, maximal_itemsets,
                         top_k_mine)
from .partitioners import (
    PARTITIONERS,
    assign_partitions,
    default_partitioner,
    greedy_partitioner,
    hash_partitioner,
    pack_items,
    partition_stats,
    reverse_hash_partitioner,
)
from .vertical import VerticalDB, build_vertical, filter_transactions
from .accumulator import HostAccumulator, build_vertical_accumulated

__all__ = [
    "AprioriResult", "apriori_mine",
    "VARIANTS", "EclatConfig", "EclatResult", "mine", "resume_mine",
    "Engine", "EngineState", "LevelResult", "available_backends",
    "engine_from_state", "make_engine", "register_backend",
    "ItemsetStore", "LevelRecord", "generate_rules",
    "latest_mining_checkpoint", "load_mining_checkpoint",
    "recover_partition", "save_mining_checkpoint",
    "bruteforce_fim",
    "WORKLOAD_MODES", "TopKResult", "closed_itemsets", "filter_mode",
    "frequent_from_closed", "maximal_itemsets", "top_k_mine",
    "PARTITIONERS", "assign_partitions", "default_partitioner",
    "greedy_partitioner", "hash_partitioner", "pack_items", "partition_stats",
    "reverse_hash_partitioner",
    "VerticalDB", "build_vertical", "filter_transactions",
    "HostAccumulator", "build_vertical_accumulated",
]
