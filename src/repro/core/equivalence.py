"""Equivalence classes and the level-wise frontier.

The paper's Phase-3/4 builds 1-length-prefix equivalence classes and runs
Zaki's recursive Bottom-Up search inside each class.  JAX needs static
shapes, so recursion becomes *level-wise expansion with a host-driven loop*
(the Spark driver analogue): the device executes fixed-shape batched
AND+popcount over bucket-padded pair lists; the host owns the data-dependent
bookkeeping (class segmentation, survivor compaction, itemset reconstruction).

Class invariant used throughout: a candidate produced by joining members
``a < b`` of a class is assigned class id = (global row index of ``a``).
Rows are emitted in ascending (class, a, b) order, so every class is a
contiguous row segment at every level — exactly the prefix-sorted layout the
paper gets from lexicographic generation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np
import jax

__all__ = ["Frontier", "segment_pairs", "class_segments", "pair_work"]

_TRIU_CACHE: dict[int, Tuple[np.ndarray, np.ndarray]] = {}


def _triu_pairs(m: int) -> Tuple[np.ndarray, np.ndarray]:
    got = _TRIU_CACHE.get(m)
    if got is None:
        got = np.triu_indices(m, k=1)
        got = (got[0].astype(np.int64), got[1].astype(np.int64))
        _TRIU_CACHE[m] = got
    return got


@dataclasses.dataclass
class Frontier:
    """One level of the search lattice.

    k:          itemset length of every row.
    parent:     (P,) row index into the previous frontier (-1 at level 1).
    item_rank:  (P,) rank (in the frequent-item total order) of the last item.
    support:    (P,) int64 supports.
    partition:  (P,) partition id — inherited from the 1-length prefix class,
                so descendants never migrate (the paper's shuffle-free
                property).
    bitmaps:    (P, W) uint32 tidset (or diffset) rows, device-resident.
    class_id:   (P,) class identifier (= left-parent row index at creation).
    """

    k: int
    parent: np.ndarray
    item_rank: np.ndarray
    support: np.ndarray
    partition: np.ndarray
    class_id: np.ndarray
    bitmaps: jax.Array

    @property
    def size(self) -> int:
        return int(self.item_rank.shape[0])


def class_segments(class_id: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Start offsets and sizes of the contiguous class segments."""
    if class_id.shape[0] == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    change = np.nonzero(np.diff(class_id))[0] + 1
    starts = np.concatenate([[0], change]).astype(np.int64)
    ends = np.concatenate([change, [class_id.shape[0]]]).astype(np.int64)
    return starts, ends - starts


def segment_pairs(starts: np.ndarray, sizes: np.ndarray):
    """All within-class join pairs (global row indices), class-ordered.

    Returns (left, right) with left < right row indices; candidates are
    ``itemset(left) ∪ {last_item(right)}`` per Algorithm 1.
    """
    lefts: List[np.ndarray] = []
    rights: List[np.ndarray] = []
    for s, m in zip(starts.tolist(), sizes.tolist()):
        if m < 2:
            continue
        li, ri = _triu_pairs(int(m))
        lefts.append(li + s)
        rights.append(ri + s)
    if not lefts:
        z = np.zeros(0, np.int64)
        return z, z.copy()
    return np.concatenate(lefts), np.concatenate(rights)


def pair_work(sizes: np.ndarray, n_words: int) -> np.ndarray:
    """Per-class first-expansion work estimate in word-ops: C(m,2) * W."""
    m = sizes.astype(np.float64)
    return (m * (m - 1) / 2.0) * float(n_words)
