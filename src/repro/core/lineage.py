"""Lineage-based fault tolerance for mining jobs.

Spark recovers a lost RDD partition by replaying its lineage.  Here the
lineage of partition ``pid`` is explicit and tiny: the immutable frequent-item
vertical bitmap + the class->partition table.  ``recover_partition`` replays
exactly the classes owned by ``pid`` and reproduces its subtree bit-for-bit
(tested in tests/test_lineage.py).  ``save/load_mining_checkpoint`` provide
the HDFS-persistence analogue: a restartable snapshot of (found levels,
current frontier), written atomically.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from .equivalence import class_segments, segment_pairs
from .itemsets import ItemsetStore, LevelRecord
from .vertical import VerticalDB

__all__ = [
    "recover_partition",
    "save_mining_checkpoint",
    "load_mining_checkpoint",
    "latest_mining_checkpoint",
]


def recover_partition(
    db: VerticalDB,
    table: np.ndarray,
    pid: int,
    abs_min_sup: int,
    max_k: Optional[int] = None,
) -> Dict[Tuple[int, ...], int]:
    """Recompute every frequent itemset owned by partition ``pid``.

    Deterministic replay from lineage inputs only — no state from the failed
    worker is needed.  Returns {itemset: support} for itemsets of length >= 2
    whose 1-length prefix class is assigned to ``pid``.
    """
    from .engine import MODE_TIDSET, make_engine  # replay via the engine interface

    n1 = db.n_items
    owned = np.nonzero(np.asarray(table) == pid)[0]
    out: Dict[Tuple[int, ...], int] = {}
    bitmaps = jnp.asarray(db.bitmaps)
    execu = make_engine("jnp", bucket_min=64)
    for rank in owned.tolist():
        # class [rank]: members rank+1..n1-1
        members = np.arange(rank + 1, n1, dtype=np.int32)
        if members.size == 0:
            continue
        left = np.full(members.shape, rank, np.int32)
        res = execu.expand(bitmaps, left, members,
                           np.zeros(members.shape[0], np.int32),
                           mode=MODE_TIDSET, min_sup=abs_min_sup)
        keep = res.mask
        frontier_bm = res.bitmaps
        frontier_items: List[Tuple[int, ...]] = [
            (int(db.items[rank]), int(db.items[j])) for j in members[keep]
        ]
        frontier_rank = members[keep]
        for iset, s in zip(frontier_items, res.supports):
            out[tuple(sorted(iset))] = int(s)
        k = 2
        class_id = np.zeros(len(frontier_items), np.int64)
        while len(frontier_items) and (max_k is None or k < max_k):
            starts, sizes = class_segments(class_id)
            l, r = segment_pairs(starts, sizes)
            if l.size == 0:
                break
            res = execu.expand(frontier_bm, l.astype(np.int32), r.astype(np.int32),
                               np.zeros(l.shape[0], np.int32),
                               mode=MODE_TIDSET, min_sup=abs_min_sup)
            k += 1
            if not res.mask.any():
                break
            sel = np.nonzero(res.mask)[0]
            new_items = [frontier_items[l[i]] + (int(db.items[frontier_rank[r[i]]]),) for i in sel]
            frontier_bm = res.bitmaps
            frontier_rank = frontier_rank[r[sel]]
            class_id = l[sel]
            frontier_items = new_items
            for iset, s in zip(frontier_items, res.supports):
                out[tuple(sorted(iset))] = int(s)
    return out


def save_mining_checkpoint(
    directory: str,
    store: ItemsetStore,
    k: int,
    class_id: np.ndarray,
    item_rank: np.ndarray,
    partition: np.ndarray,
    support: np.ndarray,
    bitmaps: np.ndarray,
    meta: Optional[dict] = None,
) -> str:
    """Atomic snapshot: levels found so far + live frontier at level ``k``.

    ``meta`` (JSON-able) records what a blind resume needs — the resolved
    ``abs_min_sup``, engine mode, ``max_k`` and partition count — so
    :func:`repro.core.eclat.resume_mine` can continue the run without the
    original transactions (DESIGN.md §10)."""
    os.makedirs(directory, exist_ok=True)
    payload = {
        "k": np.asarray(k),
        "class_id": class_id,
        "item_rank": item_rank,
        "partition": partition,
        "support": support,
        "bitmaps": bitmaps,
        "item_ids": store._item_ids,
        "n_levels": np.asarray(len(store.levels)),
        "meta": np.asarray(json.dumps(meta or {})),
    }
    for i, lvl in enumerate(store.levels):
        payload[f"lvl{i}_parent"] = lvl.parent
        payload[f"lvl{i}_item_rank"] = lvl.item_rank
        payload[f"lvl{i}_support"] = lvl.support
        payload[f"lvl{i}_partition"] = lvl.partition
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **payload)
    final = os.path.join(directory, f"mining_ckpt_k{k}.npz")
    os.replace(tmp, final)
    return final


def load_mining_checkpoint(path: str):
    """Restore (store, frontier dict) from a snapshot."""
    z = np.load(path)
    store = ItemsetStore(z["item_ids"])
    for i in range(int(z["n_levels"])):
        store.add_level(
            LevelRecord(
                k=i + 1,
                parent=z[f"lvl{i}_parent"],
                item_rank=z[f"lvl{i}_item_rank"],
                support=z[f"lvl{i}_support"],
                partition=z[f"lvl{i}_partition"],
            )
        )
    frontier = dict(
        k=int(z["k"]),
        class_id=z["class_id"],
        item_rank=z["item_rank"],
        partition=z["partition"],
        support=z["support"],
        bitmaps=z["bitmaps"],
        meta=(json.loads(str(z["meta"])) if "meta" in z.files else {}),
    )
    return store, frontier


def latest_mining_checkpoint(directory: str) -> str:
    """The deepest ``mining_ckpt_k*.npz`` in ``directory`` (the per-level
    checkpoints are cumulative: the deepest one carries every found level
    plus the live frontier)."""
    best, best_k = None, -1
    if os.path.isdir(directory):
        for name in os.listdir(directory):
            m = re.fullmatch(r"mining_ckpt_k(\d+)\.npz", name)
            if m and int(m.group(1)) > best_k:
                best_k = int(m.group(1))
                best = os.path.join(directory, name)
    if best is None:
        raise FileNotFoundError(
            f"no mining checkpoint (mining_ckpt_k*.npz) under {directory!r}")
    return best
