"""Workload modes on top of the mined lattice: closed / maximal / top-k.

The paper mines *all* frequent itemsets.  Production consumers rarely want
the full lattice — they want its non-redundant frontier (closed itemsets:
the smallest set that still determines every frequent support), its outline
(maximal itemsets: the longest patterns), or simply "the k strongest
patterns" without having to guess a support threshold at all.  All three
are derivable from the level records the engine already produces, so they
run as host-side post-filters on the ``ItemsetStore`` lineage — no new
device code, every backend (jnp / pallas / sharded / tidsharded / grid)
gets them for free, and the bit-exactness contract carries over
(DESIGN.md §9).

Definitions (over the *mined* lattice — with ``max_k`` set, "closed"
means closed among itemsets of length <= max_k):

  closed    X with no proper frequent superset of equal support.  Lossless:
            :func:`frequent_from_closed` reconstructs every frequent
            itemset's support as the max over its closed supersets.
  maximal   X with no proper frequent superset at all.  maximal ⊆ closed.
  top-k     the k highest-support itemsets, found by an adaptive min_sup
            ladder (:func:`top_k_mine`) — no user threshold; ties broken
            deterministically by (support desc, length asc, items lex asc).

Anti-monotonicity makes the immediate-superset check sufficient: if any
proper superset of X has sup(X), some superset with exactly one more item
does too (supports only fall along the lattice), so each k-itemset only
has to look at its (k-1)-subsets' records — O(total · k) overall.
"""
from __future__ import annotations

import dataclasses
from itertools import combinations
from typing import Dict, List, Optional, Sequence, Tuple

Itemset = Tuple[int, ...]
SupportMap = Dict[Itemset, int]

__all__ = ["closed_itemsets", "maximal_itemsets", "frequent_from_closed",
           "filter_mode", "TopKResult", "top_k_mine", "WORKLOAD_MODES"]

WORKLOAD_MODES = ("all", "closed", "maximal")


def _immediate_subsets(itemset: Itemset):
    """All (k-1)-subsets of a sorted k-tuple, still sorted."""
    for drop in range(len(itemset)):
        yield itemset[:drop] + itemset[drop + 1:]


def closed_itemsets(support_map: SupportMap) -> SupportMap:
    """The closed subset of a frequent-itemset map.

    One pass over the map marks, for every itemset, the immediate subsets
    whose support it ties — those subsets have a proper superset of equal
    support and are exactly the non-closed ones.
    """
    non_closed: set = set()
    for itemset, sup in support_map.items():
        if len(itemset) < 2:
            continue
        for sub in _immediate_subsets(itemset):
            if support_map.get(sub) == sup:
                non_closed.add(sub)
    return {s: v for s, v in support_map.items() if s not in non_closed}


def maximal_itemsets(support_map: SupportMap) -> SupportMap:
    """The maximal subset: itemsets with no frequent proper superset."""
    non_maximal: set = set()
    for itemset in support_map:
        if len(itemset) < 2:
            continue
        for sub in _immediate_subsets(itemset):
            non_maximal.add(sub)
    return {s: v for s, v in support_map.items() if s not in non_maximal}


def frequent_from_closed(closed_map: SupportMap) -> SupportMap:
    """Reconstruct the full frequent map from its closed representation.

    sup(X) = max{ sup(C) : C closed, X ⊆ C } — the closure operator.
    Exponential in the longest closed itemset (it enumerates subsets), so
    this is a verification/serving utility for the itemset lengths real
    databases produce, not an engine path.
    """
    out: SupportMap = {}
    for closed, sup in closed_map.items():
        for r in range(1, len(closed) + 1):
            for sub in combinations(closed, r):
                if out.get(sub, -1) < sup:
                    out[sub] = sup
    return out


def filter_mode(support_map: SupportMap, mode: str) -> SupportMap:
    """Apply a workload mode ("all" | "closed" | "maximal") to a mined map."""
    if mode == "all":
        return dict(support_map)
    if mode == "closed":
        return closed_itemsets(support_map)
    if mode == "maximal":
        return maximal_itemsets(support_map)
    raise ValueError(f"unknown workload mode {mode!r}; "
                     f"expected one of {WORKLOAD_MODES}")


# ---------------------------------------------------------------------------
# top-k: the thresholdless serving mode
# ---------------------------------------------------------------------------

def topk_sort_key(entry: Tuple[Itemset, int]):
    """Deterministic total order for top-k: support desc, then shorter
    itemsets first, then items lexicographically."""
    itemset, sup = entry
    return (-int(sup), len(itemset), itemset)


@dataclasses.dataclass
class TopKResult:
    """Outcome of :func:`top_k_mine`."""

    itemsets: List[Tuple[Itemset, int]]   # exactly k, or all if fewer exist
    k: int
    abs_min_sup: int                      # the rung the answer was read at
    ladder: List[dict]                    # per rung: abs_min_sup, n_found
    stats: dict


def top_k_mine(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    k: int,
    config=None,
    mesh=None,
    min_len: int = 1,
) -> TopKResult:
    """Mine the k highest-support itemsets without a user threshold.

    Adaptive min_sup ladder, seeded from the data: the first rung is the
    k-th largest *singleton* support — at that threshold at least k
    singletons (hence >= k itemsets) are frequent, so on the default
    ``min_len=1`` the ladder terminates after one mine() even on dense
    databases where a naive "start at 50%" rung would enumerate an
    astronomically large lattice (chess at min_sup=0.5 is the classic
    blow-up).  When a rung still comes back short (fewer than k itemsets of
    length >= ``min_len``), the threshold halves until it holds or reaches
    1 (the lattice is then complete and fewer than k exist).  Correctness:
    once >= k itemsets clear rung ``s``, the k-th best support is >= s, so
    nothing below the rung can displace the answer.

    ``config`` is an :class:`~repro.core.eclat.EclatConfig` template whose
    ``min_sup``/``mode`` are overridden per rung — variant, backend, shard
    and mesh plumb through unchanged, so top-k runs on any engine backend.
    """
    from . import bitmap as bm             # late: postfilter <- eclat cycle
    from .eclat import EclatConfig, mine

    if k < 1:
        raise ValueError(f"top-k needs k >= 1, got {k}")
    if min_len < 1:
        raise ValueError(f"min_len must be >= 1, got {min_len}")
    n_txn = len(transactions)
    template = config if config is not None else EclatConfig(min_sup=1)

    sup1 = bm.support_np(bm.pack_transactions(transactions, n_items))
    present = sup1[sup1 > 0]
    if present.size >= k:
        # k-th largest singleton support: >= k singleton itemsets clear it
        abs_ms = int(sorted(present.tolist(), reverse=True)[k - 1])
    else:
        # fewer than k items ever occur; only deeper combinations (or
        # nothing) can fill the answer — enumerate the complete lattice
        abs_ms = 1
    abs_ms = max(1, abs_ms)
    ladder: List[dict] = []
    while True:
        cfg = dataclasses.replace(template, min_sup=int(abs_ms), mode="all")
        res = mine(transactions, n_items, cfg, mesh=mesh)
        found = [(s, v) for s, v in res.support_map().items()
                 if len(s) >= min_len]
        ladder.append({"abs_min_sup": int(abs_ms), "n_found": len(found)})
        if len(found) >= k or abs_ms <= 1:
            break
        abs_ms = max(1, abs_ms // 2)

    ordered = sorted(found, key=topk_sort_key)[:k]
    return TopKResult(
        itemsets=ordered, k=k, abs_min_sup=int(abs_ms), ladder=ladder,
        stats={"rungs": len(ladder), "backend": res.stats.get("backend"),
               "variant": res.stats.get("variant"),
               "n_found_final": len(found)},
    )
