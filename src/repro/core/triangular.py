"""Triangular-matrix 2-itemset counting (paper Phase-2).

The paper updates a shared upper-triangular ``long[]`` through a Spark
accumulator while streaming the horizontal DB.  With packed bitmaps the whole
matrix is a popcount co-occurrence product

    C[i, j] = sum_w popcount(B[i, w] & B[j, w])

which is the ``repro.kernels.trimatrix`` Pallas kernel on TPU.  On the CPU
host (this container) we use the blocked jnp path below; ``repro.kernels``
tests assert the kernel matches it bit-exactly in interpret mode.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from functools import partial

__all__ = ["cooccurrence_counts", "frequent_pairs"]


@partial(jax.jit, static_argnames=("block",))
def _cooc_block(bitmaps: jax.Array, row_start: jax.Array, block: int) -> jax.Array:
    """Counts for rows [row_start, row_start+block) against all rows."""
    rows = jax.lax.dynamic_slice_in_dim(bitmaps, row_start, block, axis=0)
    inter = jnp.bitwise_and(rows[:, None, :], bitmaps[None, :, :])
    return jax.lax.population_count(inter).astype(jnp.int32).sum(-1)


@partial(jax.jit, static_argnames=("pad",))
def _pad_rows(bitmaps: jax.Array, pad: int) -> jax.Array:
    # the fill constant is baked in at trace time — a bare jnp.pad at the
    # call site would dispatch it as an implicit host scalar, tripping the
    # steady-state transfer guard (staticcheck SH002)
    return jnp.pad(bitmaps, ((0, pad), (0, 0)))


def cooccurrence_counts(bitmaps, block: int = 64) -> np.ndarray:
    """Full (n, n) co-occurrence count matrix, computed in row blocks so the
    (block, n, W) intermediate stays cache/VMEM sized."""
    if not isinstance(bitmaps, jax.Array):
        # explicit upload (staticcheck RS005): callers on the slide hot path
        # hand device arrays in; host arrays are device_put once, up front
        bitmaps = jax.device_put(np.ascontiguousarray(bitmaps))
    n = bitmaps.shape[0]
    if n == 0:
        return np.zeros((0, 0), np.int32)
    # bucket-pad rows (power of two) so repeated calls with nearby n reuse
    # the same compiled block kernel
    target = block
    while target < n:
        target <<= 1
    pad = target - n
    bitmaps_p = _pad_rows(bitmaps, pad) if pad else bitmaps
    out = []
    for s in range(0, n + pad, block):
        out.append(jax.device_get(
            _cooc_block(bitmaps_p, jax.device_put(np.int32(s)), block))[:, :n])
    return np.concatenate(out, axis=0)[:n]


def frequent_pairs(counts: np.ndarray, min_sup: int):
    """Upper-triangular (i < j) index pairs with count >= min_sup."""
    n = counts.shape[0]
    iu, ju = np.triu_indices(n, k=1)
    keep = counts[iu, ju] >= int(min_sup)
    return iu[keep].astype(np.int64), ju[keep].astype(np.int64), counts[iu, ju][keep]
