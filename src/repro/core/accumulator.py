"""Spark-accumulator analogue (EclatV3's vertical-DB build).

Spark accumulators are add-only shared variables merged associatively on the
driver.  The SPMD analogue is a per-shard partial value combined with an
associative collective — ``psum`` (bit-disjoint partials make add == or) or an
explicit OR tree on the host.  EclatV3 builds the (item -> tidset) hashmap as
an accumulator; here each shard owns a contiguous block of transaction ids,
scatters its own bits into a zero-initialised packed matrix, and the partials
are OR-merged.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.compat import shard_map
from . import bitmap as bm
from .vertical import VerticalDB, sort_items

__all__ = ["HostAccumulator", "build_vertical_accumulated"]


class HostAccumulator:
    """Add-only accumulator with an associative merge, driver-readable only
    (mirrors the Spark contract: workers add, driver reads)."""

    def __init__(self, zero, merge):
        self._value = zero
        self._merge = merge
        self._adds = 0

    def add(self, partial) -> None:
        self._value = self._merge(self._value, partial)
        self._adds += 1

    def value(self):
        return self._value

    @property
    def n_adds(self) -> int:
        return self._adds


def _partial_bitmap(chunk: Sequence[Sequence[int]], tid_offset: int, n_items: int, w: int) -> np.ndarray:
    packed = np.zeros((n_items, w), dtype=np.uint64)
    for local, items in enumerate(chunk):
        tid = tid_offset + local
        for it in set(int(i) for i in items):
            packed[it, tid // bm.WORD_BITS] |= np.uint64(1) << np.uint64(tid % bm.WORD_BITS)
    return packed.astype(np.uint32)


def build_vertical_accumulated(
    transactions: Sequence[Sequence[int]],
    n_items: int,
    min_sup: int,
    order: str = "support_asc",
    n_shards: int = 4,
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = "data",
) -> VerticalDB:
    """EclatV3 Phase-3: accumulator-built vertical DB.

    Host mode (``mesh=None``) partitions the transactions into ``n_shards``
    chunks whose partial bitmaps are OR-merged through a
    :class:`HostAccumulator`.  Device mode runs the merge as a
    ``shard_map``+``psum`` (partials are bit-disjoint, so add == or) over the
    given mesh axis — the honest multi-chip path.
    """
    n_txn = len(transactions)
    w = bm.n_words(n_txn)
    if mesh is not None:
        d = mesh.shape[axis]
        bounds = np.linspace(0, n_txn, d + 1).astype(int)
        partials = np.stack(
            [
                _partial_bitmap(transactions[bounds[i]: bounds[i + 1]], int(bounds[i]), n_items, w)
                for i in range(d)
            ]
        )

        def _merge(part):  # part: (1, n_items, w) per shard
            return jax.lax.psum(part[0], axis)

        merged = jax.jit(
            shard_map(
                _merge, mesh=mesh, in_specs=P(axis, None, None), out_specs=P()
            )
        )(jnp.asarray(partials))
        packed = np.asarray(merged).astype(np.uint32)
    else:
        n_shards = max(1, min(n_shards, max(n_txn, 1)))
        bounds = np.linspace(0, n_txn, n_shards + 1).astype(int)
        acc = HostAccumulator(
            zero=np.zeros((n_items, w), dtype=np.uint32), merge=np.bitwise_or
        )
        for i in range(n_shards):
            acc.add(_partial_bitmap(transactions[bounds[i]: bounds[i + 1]], int(bounds[i]), n_items, w))
        packed = acc.value()

    supports = bm.support_np(packed)
    freq_mask = supports >= int(min_sup)
    items = np.nonzero(freq_mask)[0].astype(np.int64)
    packed = packed[freq_mask]
    supports = supports[freq_mask]
    perm = sort_items(items, supports, order)
    return VerticalDB(
        bitmaps=packed[perm], items=items[perm], supports=supports[perm],
        n_txn=n_txn, order=order,
    )
