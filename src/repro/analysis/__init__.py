"""repro.analysis — roofline derivation from compiled HLO."""
from .hlo_parse import CollectiveStats, parse_collectives
from .roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, CellReport,
                       RooflineTerms, model_flops, roofline_terms)

__all__ = ["CollectiveStats", "parse_collectives", "HBM_BW", "LINK_BW",
           "PEAK_FLOPS", "CellReport", "RooflineTerms", "model_flops",
           "roofline_terms"]
