"""EXPERIMENTS.md table generation: §Dry-run / §Roofline from reports/,
§Headline from BENCH_headline.json, §FIM engine from BENCH_engine.json,
§Streaming from BENCH_streaming.json, §Shard-scale from
BENCH_shardscale.json, §Grid-scale from BENCH_gridscale.json,
§Kernel-tune from BENCH_kerneltune.json, §Serving from
BENCH_serving.json."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

__all__ = ["load_reports", "load_bench", "roofline_table", "dryrun_table",
           "perf_log_table", "fim_table", "streaming_table",
           "shardscale_table", "gridscale_table", "headline_table",
           "kerneltune_table"]

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_reports(directory: str = "reports/dryrun") -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def _fmt_ms(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s*1e3:.2f}ms"


def _bottleneck_note(r: dict) -> str:
    """One sentence: what would move the dominant term down (per-cell)."""
    t = r["terms"]
    dom = t["dominant"]
    kind = r.get("kind", "")
    arch = r["arch"]
    coll = r.get("collective_bytes", {})
    biggest = max(coll, key=coll.get) if coll else ""
    if dom == "collective":
        if "grok" in arch:
            return ("expert fission + EP removes the tp2d partial-sum ARs "
                    "(§Perf: 15.7x)")
        if "llama4" in arch:
            return ("grouped-local dispatch halves the a2a; next: overlap "
                    "a2a with expert GEMMs (§Perf)")
        if kind == "train":
            return (f"dominant {biggest}: narrower model axis (less TP) or "
                    "SP/mlp_dp to trade activation ARs for weight-grad ARs "
                    "(§Perf command-r)")
        if kind == "prefill":
            return ("all-gather of TP activations: sequence-parallel residual "
                    "+ bf16 collectives")
        return "decode collectives are per-layer score reductions; fuse via "\
               "a decode kernel with local softmax partials"
    if dom == "memory":
        if kind == "decode":
            return ("decode is weight/KV-read bound by construction; int8 KV "
                    "+ wider batch raises arithmetic intensity")
        if kind == "train":
            return ("bytes dominated by activation traffic: bigger fused "
                    "blocks (Pallas flash path on TPU) + remat=full")
        return "flash tiling (kernels/flash_attention) cuts score-matrix traffic"
    return "compute-bound: increase per-chip batch or reduce redundant flops"


def roofline_table(reports: List[dict], mesh: str = "single") -> str:
    """Markdown: per (arch x shape) three roofline terms + diagnosis."""
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "compute frac | MODEL/HLO | peak GB/dev | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    by_key = {}
    for r in reports:
        if r.get("mesh") != mesh:
            continue
        by_key[(r["arch"], r["shape"])] = r
    archs = sorted({k[0] for k in by_key})
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = by_key.get((arch, shape))
            if r is None:
                continue
            if r.get("status") == "skipped":
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | — | "
                            f"skipped: {r['reason'].split(';')[0].split('—')[0].strip()} |")
                continue
            if r.get("status") != "ok":
                rows.append(f"| {arch} | {shape} | ERROR | | | | | | | "
                            f"{r.get('error','')[:60]} |")
                continue
            t = r["terms"]
            ratio = r["hlo_model_ratio"]
            rows.append(
                f"| {arch} | {shape} | {_fmt_ms(t['compute_s'])} | "
                f"{_fmt_ms(t['memory_s'])} | {_fmt_ms(t['collective_s'])} | "
                f"{t['dominant']} | {t['compute_fraction']:.3f} | "
                f"{1.0/ratio if ratio else 0:.2f} | "
                f"{r['memory']['peak_gb']:.2f} | {_bottleneck_note(r)} |")
    return "\n".join(rows)


def dryrun_table(reports: List[dict]) -> str:
    """Markdown: compile status / memory / collective schedule per cell+mesh."""
    rows = [
        "(multi-pod rows are the compile/sharding proof and report RAW HLO "
        "collective counts — scan bodies counted once, so wire bytes are "
        "not comparable to the calibrated single-pod rows.)\n",
        "| arch | shape | mesh | status | compile s | peak GB/dev | "
        "arg GB | temp GB | collectives (count) | wire GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(reports, key=lambda r: (r["arch"],
                                            SHAPE_ORDER.index(r["shape"])
                                            if r["shape"] in SHAPE_ORDER else 9,
                                            r.get("mesh", ""))):
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped | — | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | — | — | — | — | — | — |")
            continue
        colls = ", ".join(f"{k}:{v}" for k, v in
                          sorted(r["collective_counts"].items()) if v)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f} | {r['memory']['peak_gb']:.2f} | "
            f"{r['memory']['argument_gb']:.2f} | {r['memory']['temp_gb']:.2f} | "
            f"{colls} | {r['wire_bytes_per_device']/1e9:.2f} |")
    return "\n".join(rows)


def load_bench(path: str) -> Optional[dict]:
    """One recorded BENCH_*.json artifact, or None if absent."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def headline_table(bench: dict) -> str:
    """Markdown: the Apriori-vs-Eclat scaling study (BENCH_headline.json) —
    the paper's headline claim, checksum-verified per cell."""
    rows = [
        f"Dataset {bench['dataset']}, min_sup={bench['min_sup']}, jax "
        f"backend `{bench['jax_backend']}`"
        + (", smoke scale" if bench.get("smoke") else "")
        + ".  Every cell below mined the **checksum-identical** "
        "(itemset, support) set as the Apriori baseline — `apriori_mine` "
        "is the differential oracle, and any divergence fails the bench "
        "and CI.  Speedups are Apriori wall / Eclat wall at the same "
        "scale (>1 = Eclat faster).\n",
    ]
    for s in bench["scales"]:
        rows.append(
            f"**x{s['scale']}** ({s['n_txn']} txns): Apriori "
            f"{s['apriori']['wall_s']*1e3:.0f}ms, "
            f"{s['apriori']['itemsets']} itemsets, levels "
            f"{s['apriori']['levels']}.\n")
        rows.append("| variant | "
                    + " | ".join(f"{n}-dev wall | {n}-dev speedup"
                                 for n in bench["mesh_sizes"]) + " |")
        rows.append("|---|" + "---|" * 2 * len(bench["mesh_sizes"]))
        for v in bench["variants"]:
            cells = []
            for n in bench["mesh_sizes"]:
                c = s["eclat"][str(n)][v]
                cells.append(f"{c['wall_s']*1e3:.0f}ms")
                cells.append(f"x{c['speedup_vs_apriori']:.2f}")
            rows.append(f"| {v} | " + " | ".join(cells) + " |")
        b = s["best"]
        rows.append(f"\nBest at this scale: **{b['variant']}** on "
                    f"{b['mesh']} device(s), **x{b['speedup']:.2f}** vs "
                    f"Apriori.\n")
    rows.append(
        f"Across all scales/meshes/variants: speedup range "
        f"**x{bench['speedup_min']:.2f} – x{bench['speedup_max']:.2f}**, "
        f"checksums identical: **{bench['checksums_identical']}**.")
    return "\n".join(rows)


def fim_table(bench: dict) -> str:
    """Markdown: per-backend mining trajectory out of BENCH_engine.json."""
    rows = [
        f"Dataset {bench['dataset']} x{bench['scale']} "
        f"({bench['n_txn']} txns, {bench['n_items']} items), "
        f"min_sup={bench['min_sup']}, jax backend `{bench['jax_backend']}`"
        + (", smoke scale.\n" if bench.get("smoke") else ".\n"),
        "| backend | executed path | mine wall | itemsets | intersections/s | "
        "padding eff | micro pairs/s |",
        "|---|---|---|---|---|---|---|",
    ]
    for name, b in bench["backends"].items():
        rows.append(
            f"| {name} | {b['executed_path']} | {b['mine_wall_s']*1e3:.1f}ms | "
            f"{b['itemsets']} | {b['intersections_per_s']:.0f} | "
            f"{b['padding_efficiency']:.3f} | {b['micro_pairs_per_s']:.0f} |")
    rows.append(f"\nFused speedup vs jnp reference: "
                f"**{bench['fused_speedup_vs_jnp']:.2f}x**")
    return "\n".join(rows)


def streaming_table(bench: dict) -> str:
    """Markdown: incremental vs full re-mine latency (BENCH_streaming.json)."""
    rows = [
        f"Sliding {bench['dataset']} stream, min_sup={bench['min_sup']}, "
        f"backend `{bench['backend']}`; every timed slide asserts the "
        "incremental and full support maps are identical"
        + (" (smoke scale).\n" if bench.get("smoke") else ".\n"),
        "| window (txns) | blocks | slides | itemsets | incremental/slide | "
        "full re-mine/slide | speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for w in bench["windows"]:
        rows.append(
            f"| {w['window_txns']} | {w['n_blocks']}x{w['block_txns']} | "
            f"{w['n_slides']} | {w['itemsets']} | {w['incremental_ms']:.1f}ms | "
            f"{w['full_ms']:.1f}ms | x{w['speedup']:.2f} |")
    note = (" (incremental wins everywhere it is measured)"
            if bench["min_speedup"] > 1.0 else
            " (**regression: incremental loses at some window size**)")
    rows.append(f"\nMinimum speedup across window sizes: "
                f"**x{bench['min_speedup']:.2f}**{note}.")
    return "\n".join(rows)


def shardscale_table(bench: dict) -> str:
    """Markdown: word-sharded parity + per-device memory vs mesh size
    (BENCH_shardscale.json, DESIGN.md §7)."""
    rows = [
        f"Dataset {bench['dataset']} x{bench['scale']} ({bench['n_txn']} "
        f"txns), min_sup={bench['min_sup']}, jax backend "
        f"`{bench['jax_backend']}`"
        + (", smoke scale.\n" if bench.get("smoke") else ".\n"),
        "Batch parity — tidsharded (4-device mesh, `P(None, \"data\")` "
        "frontier) vs jnp vs pallas:\n",
        "| variant | itemsets | bit-identical | tidsharded wall | jnp wall |",
        "|---|---|---|---|---|",
    ]
    for v in ("v1", "v2", "v3", "v4", "v5", "v6"):
        p = bench["parity"][v]
        rows.append(f"| {v} | {p['itemsets']} | {p['identical']} | "
                    f"{p['wall_s']['tidsharded']*1e3:.0f}ms | "
                    f"{p['wall_s']['jnp']*1e3:.0f}ms |")
    s = bench["parity"]["streaming"]
    rows.append(
        f"\nStreaming: {s['slides']} slides on a word-sharded ring "
        f"(`{s['ring_spec']}`, {s['ring_bytes_per_device']} bytes/device of "
        f"{s['ring_bytes_total']} total), engine `{s['engine']}`, "
        f"bit-identical with batch re-mine: **{s['identical']}**.\n")
    rows += [
        "Per-device frontier bytes vs mesh size (same expansion, identical "
        "support checksums):\n",
        "| devices | level bitmap/device | level bitmap total | DB bitmap/device | survivors |",
        "|---|---|---|---|---|",
    ]
    for m in bench["memory"]:
        rows.append(
            f"| {m['n_devices']} | {m['level_bitmap_bytes_per_device']} | "
            f"{m['level_bitmap_bytes_total']} | "
            f"{m['db_bitmap_bytes_per_device']} | {m['survivors']} |")
    rows.append(f"\nPer-device reduction at 4 devices: "
                f"**x{bench['per_device_reduction_4dev']:.2f}** "
                f"(supports identical: {bench['memory_supports_identical']}).")
    return "\n".join(rows)


def gridscale_table(bench: dict) -> str:
    """Markdown: 2D grid parity + per-axis placement vs the 1D modes
    (BENCH_gridscale.json, DESIGN.md §8)."""
    n_class, n_data = bench["grid"]
    rows = [
        f"Dataset {bench['dataset']} x{bench['scale']} ({bench['n_txn']} "
        f"txns), min_sup={bench['min_sup']}, jax backend "
        f"`{bench['jax_backend']}`, {n_class}x{n_data} (class x data) grid"
        + (", smoke scale.\n" if bench.get("smoke") else ".\n"),
        "Batch parity — grid engine (`P(None, \"data\")` frontier, pairs "
        "over the class axis) vs jnp:\n",
        "| variant | itemsets | bit-identical | grid wall | jnp wall |",
        "|---|---|---|---|---|",
    ]
    for v in ("v1", "v2", "v3", "v4", "v5", "v6"):
        p = bench["parity"][v]
        rows.append(f"| {v} | {p['itemsets']} | {p['identical']} | "
                    f"{p['wall_s']['grid']*1e3:.0f}ms | "
                    f"{p['wall_s']['jnp']*1e3:.0f}ms |")
    s = bench["parity"]["streaming"]
    rows.append(
        f"\nStreaming: {s['slides']} slides on a grid-placed ring "
        f"(`{s['ring_spec']}`, {s['ring_bytes_per_device']} bytes/device of "
        f"{s['ring_bytes_total']} total), engine `{s['engine']}`, "
        f"bit-identical with batch re-mine: **{s['identical']}**.\n")
    rows += [
        "Per-device placement — the same level expansion through the three "
        "mesh mappings (identical support checksums):\n",
        "| mode | frontier bytes/device | pairs/device | survivors |",
        "|---|---|---|---|",
    ]
    for mode in ("pairs", "words", "grid"):
        m = bench["placement"][mode]
        rows.append(f"| {mode} | {m['frontier_bytes_per_device']} | "
                    f"{m['pairs_per_device']} | {m['survivors']} |")
    rows.append(
        f"\nGrid vs the 1D modes: frontier bytes/device "
        f"**x{bench['frontier_reduction_vs_pairs']:.2f}** lower than "
        f"`pairs` (~n_data={n_data}) and pair work/device "
        f"**x{bench['pairwork_reduction_vs_words']:.2f}** lower than "
        f"`words` (~n_class={n_class}), at identical supports: "
        f"{bench['placement_supports_identical']}.")
    return "\n".join(rows)


def kerneltune_table(bench: dict) -> str:
    """Markdown: autotune sweep + tuned-vs-default gate + the measured
    backend crossover behind `resolve_engine("auto")`
    (BENCH_kerneltune.json, DESIGN.md §6)."""
    rows = [
        f"Jax backend `{bench['jax_backend']}`"
        + (", smoke scale" if bench.get("smoke") else "")
        + f"; autotune cache `{bench.get('autotune_cache', '?')}`.\n",
        "Autotune sweep — per shape class, steady-state seconds per "
        "candidate tile width (compile excluded; off-TPU the fused path "
        "has no tile knob, so the candidate list honestly collapses):\n",
        "| shape class | candidates | tuned block_w | model pick | agrees "
        "| steady |",
        "|---|---|---|---|---|---|",
    ]
    for s in bench.get("shapes", []):
        rows.append(
            f"| `{s['key']}` | {len(s['candidates'])} "
            f"| {s['tuned_block_w']} | {s['model_pick']} "
            f"| {s['model_agrees']} | {_fmt_ms(s['steady_s'])} |")
    tvd = bench.get("tuned_vs_default")
    if tvd:
        rows.append(
            f"\nTuned vs default (`block_w=512`, legacy two-dispatch "
            f"compaction) on {tvd['dataset']} x{tvd['scale']} "
            f"({tvd['n_txn']} txns): {_fmt_ms(tvd['default_wall_s'])} -> "
            f"{_fmt_ms(tvd['tuned_wall_s'])} "
            f"(**x{tvd['speedup']:.2f}**), itemset checksums identical: "
            f"**{tvd['checksums_match']}** (`{tvd['itemset_checksum']}`).\n")
    cells = bench.get("crossover", [])
    if cells:
        rows += [
            "Measured backend crossover — the dispatch table "
            "`resolve_engine(\"auto\")` loads (steady-state expand(), "
            "best backend per cell):\n",
            "| Q | W | best single-device | best mesh | fused vs jnp |",
            "|---|---|---|---|---|",
        ]
        for c in cells:
            rows.append(
                f"| {c['q']} | {c['w']} | `{c['best_single']}` "
                f"| `{c['best_mesh']}` "
                f"| x{c['speedup_fused_vs_jnp']:.2f} |")
    return "\n".join(rows)


def serving_table(bench: dict) -> str:
    """Markdown: query storms at the async admission front end
    (BENCH_serving.json, DESIGN.md §11)."""
    rows = [
        f"Query storms against `ServingFrontend` on a sliding "
        f"{bench['dataset']} stream (min_sup={bench['min_sup']}, backend "
        f"`{bench['backend']}`); the writer slides windows underneath while "
        f"client threads storm the bounded admission queue, and every served "
        f"answer is replayed synchronously at its stamped `window_version` — "
        f"checksum divergence aborts the bench"
        + (" (smoke scale).\n" if bench.get("smoke") else ".\n"),
        "| storm | window (txns) | itemsets | p50 | p99 | qps | batch | "
        "cache hits | invalidated | verified |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for s in bench["storms"]:
        rows.append(
            f"| {s['n_queries']}q/{s['n_clients']}c/{s['slides']}sl | "
            f"{s['window_txns']} | {s['itemsets']} | {s['p50_ms']:.2f}ms | "
            f"{s['p99_ms']:.2f}ms | {s['qps']:.0f} | {s['mean_batch']:.1f} | "
            f"{s['cache_hit_rate']:.1%} | {s['stale_evicted']} | "
            f"{s['verified']}/{s['answered']} |")
    s = bench["storms"][-1]
    rows.append(
        f"\nDirect (unbatched, cache-off) baseline on the final window: "
        f"p50 {s['direct_p50_ms']:.2f}ms / p99 {s['direct_p99_ms']:.2f}ms "
        f"per query — the served answer path amortizes to "
        f"**x{s['amortization']:.2f}** via version-keyed caching + batching.")
    note = ("all answers bit-identical with the synchronous path"
            if bench["all_identical"] else
            "**divergence recorded — serving path is wrong**")
    rows.append(f"\nBit-identity gate: **{note}**.")
    return "\n".join(rows)


def perf_log_table(entries: List[dict]) -> str:
    rows = [
        "| cell | iter | hypothesis | change | before (dom) | after (dom) | verdict |",
        "|---|---|---|---|---|---|---|",
    ]
    for e in entries:
        rows.append(
            f"| {e['cell']} | {e['iter']} | {e['hypothesis']} | {e['change']} | "
            f"{e['before']} | {e['after']} | {e['verdict']} |")
    return "\n".join(rows)
