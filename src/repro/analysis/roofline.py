"""Roofline derivation from the compiled dry-run artifact.

Hardware model (TPU v5e, per assignment):
    peak_flops = 197e12   bf16 FLOP/s per chip
    hbm_bw     = 819e9    B/s per chip
    link_bw    = 50e9     B/s per ICI link

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on the
per-device program:

    compute    = device_FLOPs / peak_flops
    memory     = device_bytes / hbm_bw
    collective = device_wire_bytes / link_bw

Scan calibration: XLA's HloCostAnalysis (and a textual collective count)
visits a while-loop body ONCE, so a scanned 48-layer stage reports ~1 layer
of cost.  We therefore compile, per layer-kind k, two tiny depth variants
(full width, ShapeDtypeStruct only) whose patterns differ by exactly one
layer of kind k; the cost delta is that layer's true per-iteration cost and

    total = base + sum_k (count_k - base_count_k) * delta_k

reconstructs the full-depth cost exactly (stage bodies are homogeneous).
The full-size compile is still performed unconditionally — it is the
dry-run deliverable (memory_analysis / sharding proof); only FLOP/byte
totals use the calibrated reconstruction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

__all__ = ["PEAK_FLOPS", "HBM_BW", "LINK_BW", "RooflineTerms", "CellReport",
           "roofline_terms", "model_flops", "measure_compiled",
           "calibration_patterns", "intersect_cost", "VPU_LANES",
           "VPU_WORD_OPS"]

# Vector-unit model for the mining hot loop (the fused gather+AND+popcount
# kernel operates on uint32 words on the VPU, not the MXU): 8x128 lanes per
# cycle at ~940 MHz -> word-ops/s.  popcount + AND + the accumulator add is
# ~3 VPU ops per word.
VPU_LANES = 8 * 128
VPU_CLOCK = 0.94e9
VPU_WORD_OPS = VPU_LANES * VPU_CLOCK


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the step the MXUs could be busy if everything else
        overlapped perfectly — the roofline score for compute-bound cells."""
        return self.compute_s / max(self.bound_s, 1e-30)

    def to_dict(self):
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant,
                "compute_fraction": self.compute_fraction}


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collective_counts: Dict[str, int]
    collective_bytes: Dict[str, float]
    memory: Dict[str, float]
    terms: RooflineTerms
    model_flops_total: float
    hlo_model_ratio: float
    compile_s: float
    calibrated: bool
    notes: str = ""

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["terms"] = self.terms.to_dict()
        return d


def measure_compiled(compiled, n_devices: int):
    """Raw (uncalibrated) per-device cost/memory/collective measurements."""
    from .hlo_parse import parse_collectives
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = parse_collectives(text, n_devices)
    mem = compiled.memory_analysis()
    memory = {
        "argument_gb": mem.argument_size_in_bytes / 1e9,
        "output_gb": mem.output_size_in_bytes / 1e9,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "alias_gb": mem.alias_size_in_bytes / 1e9,
        "peak_gb": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 1e9,
    }
    return flops, nbytes, coll, memory


def roofline_terms(flops, nbytes, wire_bytes) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=nbytes / HBM_BW,
        collective_s=wire_bytes / LINK_BW,
    )


def intersect_cost(q: int, w: int, block_w: int, *,
                   ops_per_word: float = 3.0) -> RooflineTerms:
    """Roofline terms for one fused gather+AND+popcount level expansion.

    The kernel reads both parent rows once per word block and writes the
    intersection once, so per pair the HBM traffic is ``3 * w * 4`` bytes
    plus a per-block-step fixed overhead (the DMA descriptor + accumulator
    spill each of the ``ceil(w / block_w)`` grid steps pays — the term that
    penalizes tiny ``block_w``); compute is ``ops_per_word`` VPU word-ops
    per word (AND + popcount + accumulate).  A ``block_w`` wider than the
    lane-padded row is modeled as reading the padded row (the term that
    penalizes over-wide blocks on narrow frontiers).  Used by
    ``repro.kernels.autotune`` to order candidate tile widths before
    measuring: the model seeds the sweep, measurement decides it.
    """
    q = max(int(q), 1)
    w = max(int(w), 1)
    bw = max(int(block_w), 1)
    n_steps = -(-w // bw)                 # ceil: grid steps along the word axis
    w_padded = n_steps * bw               # zero-padded words actually streamed
    # 2 row reads + 1 intersection write, 4 bytes/word, plus ~512B of
    # per-step DMA/bookkeeping overhead per operand (3 operands)
    step_overhead_bytes = 3 * 512.0
    bytes_moved = q * (3.0 * w_padded * 4.0 + n_steps * step_overhead_bytes)
    word_ops = q * w_padded * ops_per_word
    return RooflineTerms(
        compute_s=word_ops / VPU_WORD_OPS,
        memory_s=bytes_moved / HBM_BW,
        collective_s=0.0,
    )


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for train, 2*N_active*D for
    inference (D = tokens processed this step), attention excluded — the
    reported HLO/MODEL ratio absorbs attention + remat overheads."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def calibration_patterns(cfg) -> Tuple[Tuple[str, ...], Dict[str, Tuple[str, ...]], Dict[str, int]]:
    """Base pattern (one layer per kind) + per-kind +1 variants + true counts."""
    pattern = cfg.layer_pattern()
    kinds: List[str] = []
    counts: Dict[str, int] = {}
    for k in pattern:
        counts[k] = counts.get(k, 0) + 1
        if k not in kinds:
            kinds.append(k)
    base = tuple(kinds)
    variants = {k: tuple(list(base) + [k]) for k in kinds}
    return base, variants, counts
