"""Parse collective traffic out of compiled (post-SPMD) HLO text.

``cost_analysis`` has no collective-bytes entry, so the roofline's collective
term is derived here: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute instruction contributes its result-shape
bytes, scaled by the ring-algorithm wire factor for its group size g:

    all-reduce        2 (g-1)/g   x bytes     (reduce-scatter + all-gather)
    all-gather          (g-1)/g   x bytes     (result bytes)
    reduce-scatter      (g-1)/g   x operand bytes ~= g x result bytes
    all-to-all          (g-1)/g   x bytes
    collective-permute  1         x bytes

Instructions inside while-loop bodies (scan stages) are counted once by this
textual pass — the roofline layer multiplies them back up with the
scan-calibration factors (see analysis/roofline.py).

Besides the aggregate :class:`CollectiveStats`, each collective is recorded
as a :class:`CollectiveInstr` (kind, bytes, replica-group size, source line)
— the ``staticcheck`` IR contract layer asserts per-instruction properties
(exactly one psum over the declared axis, group size == the reduce-axis
width) that aggregates can't express.

Unknown dtype tokens raise: silently skipping a dtype would under-count the
very traffic a byte budget is supposed to bound.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

__all__ = ["CollectiveStats", "CollectiveInstr", "parse_collectives",
           "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# shape-position tokens that carry no payload bytes (control deps etc.)
_ZERO_BYTE_TOKENS = {"token", "tuple", "opaque"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveInstr:
    """One collective instruction of the compiled module."""

    kind: str            # canonical: all-reduce / all-gather / ...
    op: str              # raw opcode (e.g. all-reduce-start)
    bytes_raw: float     # result-shape bytes, unscaled
    bytes_wire: float    # ring-scaled wire bytes
    group_size: int      # replica-group width the collective spans
    line: int            # 1-based line in the HLO text


@dataclasses.dataclass
class CollectiveStats:
    count: Dict[str, int]
    bytes_raw: Dict[str, float]       # result bytes, unscaled
    bytes_wire: Dict[str, float]      # ring-scaled wire bytes
    instrs: List[CollectiveInstr] = dataclasses.field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.bytes_wire.values())

    @property
    def total_count(self) -> int:
        return sum(self.count.values())


def _shape_bytes(sig: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype in _ZERO_BYTE_TOKENS:
            continue
        if dtype not in DTYPE_BYTES:
            raise ValueError(
                f"unknown HLO dtype token {dtype!r} in shape {sig!r} — "
                f"add its width to analysis.hlo_parse.DTYPE_BYTES so "
                f"collective byte accounting stays complete")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups,group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        if first:
            return max(len(first.split(",")), 1)
    return n_devices


def _wire_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    ring = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * ring
    if kind == "reduce-scatter":
        return float(g) * ring  # operand = g x result
    if kind == "collective-permute":
        return 1.0
    return ring  # all-gather / all-to-all


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    count: Dict[str, int] = {}
    braw: Dict[str, float] = {}
    bwire: Dict[str, float] = {}
    instrs: List[CollectiveInstr] = []
    for lineno, line in enumerate(hlo_text.splitlines(), start=1):
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        sig, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # paired with -start; count once
        nbytes = _shape_bytes(sig)
        g = _group_size(s, n_devices)
        count[kind] = count.get(kind, 0) + 1
        braw[kind] = braw.get(kind, 0.0) + nbytes
        bwire[kind] = bwire.get(kind, 0.0) + nbytes * _wire_factor(kind, g)
        instrs.append(CollectiveInstr(
            kind=kind, op=op, bytes_raw=nbytes,
            bytes_wire=nbytes * _wire_factor(kind, g),
            group_size=g, line=lineno))
    return CollectiveStats(count=count, bytes_raw=braw, bytes_wire=bwire,
                           instrs=instrs)
