"""Finding / report types shared by all three staticcheck layers.

Everything the gate emits — AST lint hits, IR contract violations, shape
audit regressions — is a :class:`Finding` with a stable rule id, so CI
failures name the rule (``RS004``, ``IR002``, ``SH001``) instead of handing
the reader a stack trace.  :class:`Report` aggregates them plus per-layer
summary counters and serializes to the JSON artifact CI publishes.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

__all__ = ["Finding", "Report", "SEVERITY_ERROR", "SEVERITY_WARNING"]

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"


@dataclasses.dataclass
class Finding:
    """One violation: ``rule`` is a stable id (RSnnn / IRnnn / SHnnn),
    ``path`` a repo-relative file or a symbolic target (``backend:grid``),
    ``line`` 0 when the finding has no source location."""

    rule: str
    path: str
    line: int
    message: str
    severity: str = SEVERITY_ERROR

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} [{self.severity}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    findings: List[Finding] = dataclasses.field(default_factory=list)
    summary: Dict[str, object] = dataclasses.field(default_factory=dict)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_WARNING]

    def to_json(self) -> dict:
        return {
            "summary": self.summary,
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "findings": [f.to_json() for f in self.findings],
        }

    def write(self, path: str) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")
