"""Layer 1: run the rule registry over Python source trees.

Pure-stdlib (``ast`` + ``tokenize`` levels of machinery only): importing
this module never imports jax, so the lint runs in any environment and in
a fraction of a second over the whole repo.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Union

from .report import Finding, SEVERITY_WARNING
from .rules import HOT_PATH_PRAGMA, HOT_PATHS, RULES, LintContext

__all__ = ["iter_python_files", "lint_file", "lint_paths", "SKIP_DIRS"]

SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude",
             "node_modules", "reports", "fixtures"}

_SUPPRESS_RE = re.compile(r"#\s*staticcheck:\s*disable=([A-Z0-9, ]+)")


def iter_python_files(root: str, subdirs: Sequence[str]) -> List[str]:
    """All ``.py`` files under ``root/<subdir>`` for each subdir, skipping
    ``SKIP_DIRS`` (which includes the committed must-fail ``fixtures/``)."""
    out: List[str] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        if os.path.isfile(base) and base.endswith(".py"):
            out.append(base)
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(out)


def _suppressions(lines: List[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {tok.strip() for tok in m.group(1).split(",")
                      if tok.strip()}
    return out


_PRAGMA_RE = re.compile(r"^\s*" + re.escape(HOT_PATH_PRAGMA) + r"\s*$",
                        re.MULTILINE)


def _hot_functions(relpath: str, source: str) -> Union[str, Set[str], None]:
    for suffix, names in HOT_PATHS.items():
        if relpath.endswith(suffix):
            return names
    if _PRAGMA_RE.search(source):
        return "*"
    return None


def _map_functions(tree: ast.AST) -> Dict[int, str]:
    """id(node) -> name of the innermost enclosing function def."""
    func_of: Dict[int, str] = {}

    def visit(node: ast.AST, current: Optional[str]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            current = node.name
        for child in ast.iter_child_nodes(node):
            if current is not None:
                func_of[id(child)] = current
            visit(child, current)

    visit(tree, None)
    return func_of


def _is_test_path(relpath: str) -> bool:
    parts = relpath.split("/")
    base = parts[-1]
    return ("tests" in parts[:-1] or base.startswith("test_")
            or base == "conftest.py")


def lint_file(path: str, root: Optional[str] = None,
              severity: str = "error",
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every registered rule over one file.

    ``severity`` overrides the emitted findings' severity (the warn-only
    tests/benchmarks zones pass ``"warning"``); ``rules`` restricts to a
    subset of rule ids.
    """
    relpath = os.path.relpath(path, root) if root else path
    relpath = relpath.replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=relpath)
    except SyntaxError as exc:
        return [Finding(rule="RS000", path=relpath, line=exc.lineno or 0,
                        message=f"file does not parse: {exc.msg}")]
    lines = source.splitlines()
    ctx = LintContext(
        path=relpath, tree=tree, lines=lines,
        suppressed=_suppressions(lines),
        is_test=_is_test_path(relpath),
        hot_functions=_hot_functions(relpath, source),
        func_of=_map_functions(tree))
    findings: List[Finding] = []
    for rule in RULES:
        if rules is not None and rule.id not in rules:
            continue
        findings.extend(rule.check(ctx))
    if severity == SEVERITY_WARNING:
        for f in findings:
            f.severity = SEVERITY_WARNING
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: Iterable[str], root: Optional[str] = None,
               severity: str = "error",
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for p in paths:
        out.extend(lint_file(p, root=root, severity=severity, rules=rules))
    return out
