"""The repo rule registry: this codebase's historical bug classes as lint rules.

Every rule id appeared as a real defect in PRs 1-9 (CHANGES.md) before it
became a rule; the fixtures under ``staticcheck/fixtures/`` are distilled
reproductions that the gate self-tests against (each fixture must fail its
rule, or the rule has rotted).

  RS001  bare ``assert`` guarding a runtime invariant in non-test code —
         stripped by ``python -O``, so the invariant silently vanishes in
         the optimized drivers CI runs; raise instead.
  RS002  ``np.empty`` for slot/index buffers: unwritten slots are garbage
         a later gather will happily read (the PR 4 slot-corruption bug).
  RS003  truthiness on int-or-None config fields (``max_k`` etc.):
         ``max_k or n`` coerces the valid value 0 into "unbounded"
         (the PR 6 ``max_k=0`` bug); compare against None.
  RS004  ``os.environ["XLA_..."] = ...`` overwrite: clobbers flags the
         caller already set; append to the existing value.
  RS005  implicit host<->device conversion (``jnp.asarray`` on host-mirror
         np state, ``np.asarray`` on device arrays) inside a registered
         streaming/serving hot path; only explicit ``jax.device_put`` /
         ``jax.device_get`` keep the steady state clean under
         ``jax.transfer_guard`` (the Layer-3 contract).

Suppression: append ``# staticcheck: disable=RSnnn`` (comma-separate for
several ids) to the flagged line or the line above it, next to a comment
that justifies why the rule does not apply.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Set, Union

from .report import Finding

__all__ = ["Rule", "RULES", "HOT_PATHS", "rule_ids", "LintContext",
           "INT_OR_NONE_CONFIG_FIELDS", "HOT_PATH_PRAGMA"]

# module-path suffix -> hot function names ("*" = every function in the
# file).  These are the steady-state loops the Layer-3 audit runs under
# transfer guards; RS005 keeps them statically free of implicit conversions.
HOT_PATHS: Dict[str, Union[str, Set[str]]] = {
    "repro/streaming/window.py": {"push"},
    "repro/streaming/miner.py": {"push", "mine_window", "advance"},
    "repro/core/engine.py": {"expand", "_compact", "_take"},
    "repro/core/triangular.py": {"cooccurrence_counts"},
    "repro/core/eclat.py": {"run_bottom_up"},
    # the serving read path answers from host snapshots by design: any
    # device conversion at all is a regression
    "repro/serving/snapshot.py": "*",
    "repro/serving/stream_query.py": "*",
}

# files outside the registry can declare themselves hot (the fixtures do)
HOT_PATH_PRAGMA = "# staticcheck: hot-path"

# config fields that are int-or-None where 0 is a *valid int*, not "unset"
INT_OR_NONE_CONFIG_FIELDS = {
    "max_k", "cand_chunk", "block_w", "top_k", "keep_versions",
    "kill_after", "checkpoint_every", "max_batches",
}

_JNP_NAMES = {"jnp"}
_NP_NAMES = {"np", "numpy"}
_JNP_CONVERSIONS = {"asarray", "array", "int32", "int64", "uint32",
                    "float32", "float64"}
_NP_CONVERSIONS = {"asarray"}
_INT_DTYPE_ATTRS = {"int8", "int16", "int32", "int64", "uint8", "uint16",
                    "uint32", "uint64", "intp", "int_", "longlong"}


@dataclasses.dataclass
class LintContext:
    """Everything one rule pass needs about one file."""

    path: str                       # repo-relative, forward slashes
    tree: ast.AST
    lines: List[str]                # raw source lines (1-indexed via [i-1])
    suppressed: Dict[int, Set[str]]  # line -> rule ids disabled there
    is_test: bool                   # tests/ or test_*.py / conftest.py
    hot_functions: Union[str, Set[str], None]   # "*" | set | None
    func_of: Dict[int, str]         # id(node) -> innermost enclosing def

    def enclosing(self, node: ast.AST) -> Optional[str]:
        return self.func_of.get(id(node))

    def in_hot_function(self, node: ast.AST) -> Optional[str]:
        fn = self.enclosing(node)
        if self.hot_functions == "*":
            return fn or "<module>"
        if fn is not None and self.hot_functions and \
                fn in self.hot_functions:
            return fn
        return None

    def is_suppressed(self, rule_id: str, line: int) -> bool:
        for ln in (line, line - 1):
            if rule_id in self.suppressed.get(ln, set()):
                return True
        return False


@dataclasses.dataclass
class Rule:
    id: str
    title: str
    rationale: str
    check: Callable[[LintContext], List[Finding]]


def _finding(ctx: LintContext, rule_id: str, node: ast.AST,
             message: str) -> List[Finding]:
    line = getattr(node, "lineno", 0)
    if ctx.is_suppressed(rule_id, line):
        return []
    return [Finding(rule=rule_id, path=ctx.path, line=line, message=message)]


def _dotted(node: ast.AST) -> str:
    """'os.environ.get' for nested Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# -- RS001 ------------------------------------------------------------------

def _check_rs001(ctx: LintContext) -> List[Finding]:
    if ctx.is_test:
        return []
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assert):
            out += _finding(
                ctx, "RS001", node,
                "bare `assert` guards a runtime invariant but is stripped "
                "under `python -O` (the CI optimized-build smokes); raise "
                "RuntimeError/ValueError with a diagnostic message instead")
    return out


# -- RS002 ------------------------------------------------------------------

def _is_int_dtype_expr(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Attribute) and node.attr in _INT_DTYPE_ATTRS:
        return True
    if isinstance(node, ast.Name) and node.id in _INT_DTYPE_ATTRS:
        return True
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.lstrip("u").lstrip("int").isdigit() or \
            node.value in _INT_DTYPE_ATTRS
    return False


def _check_rs002(ctx: LintContext) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "empty"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id in _NP_NAMES):
            continue
        if node.args and isinstance(node.args[0], ast.Constant) \
                and node.args[0].value == 0:
            continue  # zero-length: nothing to leave uninitialized
        dtype = node.args[1] if len(node.args) > 1 else None
        for kw in node.keywords:
            if kw.arg == "dtype":
                dtype = kw.value
        if not _is_int_dtype_expr(dtype):
            continue
        out += _finding(
            ctx, "RS002", node,
            "np.empty(...) integer slot/index buffer: any slot the fill "
            "loop misses is garbage that a later gather reads as a valid "
            "index (silently wrong supports); use np.zeros, or suppress "
            "with a justification that every slot is provably written")
    return out


# -- RS003 ------------------------------------------------------------------

def _truthiness_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id in INT_OR_NONE_CONFIG_FIELDS:
        return node.id
    if isinstance(node, ast.Attribute) and \
            node.attr in INT_OR_NONE_CONFIG_FIELDS:
        return node.attr
    return None


def _check_rs003(ctx: LintContext) -> List[Finding]:
    # dedup by source position: a BoolOp inside an if-test is reachable
    # both as the test and as a walked BoolOp node
    hits: Dict[tuple, ast.AST] = {}

    def mark(node: ast.AST):
        name = _truthiness_name(node)
        if name is not None:
            hits[(node.lineno, node.col_offset)] = node

    def mark_test(test: ast.AST):
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            mark_test(test.operand)
        elif isinstance(test, ast.BoolOp):
            for v in test.values:
                mark_test(v)
        else:
            mark(test)

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.If, ast.While, ast.IfExp)):
            mark_test(node.test)
        elif isinstance(node, ast.BoolOp):
            # `max_k or default`: every non-last operand is truthiness-tested
            for v in node.values[:-1]:
                mark(v)
    out: List[Finding] = []
    for _, node in sorted(hits.items()):
        name = _truthiness_name(node)
        out.extend(_finding(
            ctx, "RS003", node,
            f"truthiness on int-or-None field `{name}` treats the valid "
            f"value 0 as unset (`{name}=0` silently becomes unbounded); "
            f"compare `is None` / `is not None` explicitly"))
    return out


# -- RS004 ------------------------------------------------------------------

def _environ_key(node: ast.AST) -> Optional[str]:
    """The constant key of an ``os.environ[...]`` subscript, else None."""
    if not isinstance(node, ast.Subscript):
        return None
    if _dotted(node.value) not in ("os.environ", "environ"):
        return None
    sl = node.slice
    if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
        return sl.value
    return None


def _reads_same_key(rhs: ast.AST, key: str) -> bool:
    for sub in ast.walk(rhs):
        if _environ_key(sub) == key:
            return True
        if isinstance(sub, ast.Call) and \
                _dotted(sub.func) in ("os.environ.get", "environ.get") and \
                sub.args and isinstance(sub.args[0], ast.Constant) and \
                sub.args[0].value == key:
            return True
    return False


def _check_rs004(ctx: LintContext) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            key = _environ_key(tgt)
            if key is None or not key.startswith("XLA"):
                continue
            if _reads_same_key(node.value, key):
                continue
            out += _finding(
                ctx, "RS004", node,
                f"os.environ[{key!r}] overwritten — any value the caller "
                f"already exported (device counts, dump flags) is silently "
                f"clobbered; append: os.environ.get({key!r}, '') + ' ...'")
    return out


# -- RS005 ------------------------------------------------------------------

def _check_rs005(ctx: LintContext) -> List[Finding]:
    if not ctx.hot_functions:
        return []
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)):
            continue
        mod, attr = node.func.value.id, node.func.attr
        bad = (mod in _JNP_NAMES and attr in _JNP_CONVERSIONS) or \
              (mod in _NP_NAMES and attr in _NP_CONVERSIONS)
        if not bad:
            continue
        fn = ctx.in_hot_function(node)
        if fn is None:
            continue
        out += _finding(
            ctx, "RS005", node,
            f"implicit host<->device conversion `{mod}.{attr}` in hot path "
            f"`{fn}` — the steady-state slide/serve loop must only move "
            f"data via explicit jax.device_put / jax.device_get (the "
            f"Layer-3 transfer-guard contract)")
    return out


RULES: List[Rule] = [
    Rule("RS001", "bare assert guarding a runtime invariant",
         "python -O strips asserts; CI runs optimized-build smokes",
         _check_rs001),
    Rule("RS002", "np.empty for integer slot/index buffers",
         "unwritten slots are garbage later gathers read (PR 4 bug class)",
         _check_rs002),
    Rule("RS003", "truthiness on int-or-None config fields",
         "`max_k or n` coerces the valid 0 into unbounded (PR 6 bug class)",
         _check_rs003),
    Rule("RS004", "XLA env var overwritten instead of appended",
         "clobbers flags the caller exported",
         _check_rs004),
    Rule("RS005", "implicit host<->device conversion in a hot path",
         "only explicit transfers keep slides clean under transfer guards",
         _check_rs005),
]


def rule_ids() -> List[str]:
    return [r.id for r in RULES]
