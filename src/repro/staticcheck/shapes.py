"""Layer 3 — runtime-shape static audit (DESIGN.md §12).

The jit caches are only "static" if the set of compiled shapes is closed
under the half-pow2 bucket ladder: after warm-up, a steady-state slide or
mine level must never trigger XLA compilation, never move bytes to the
device implicitly (``jax.transfer_guard("disallow")`` enforced), and every
recorded pair-buffer padding must sit on a ladder rung.

Three runtime rules:

    SH001  steady-state XLA recompile (a shape escaped the bucket ladder)
    SH002  implicit host<->device transfer in the audited region
    SH003  recorded level padding off the bucket ladder

``audit_streaming`` drives ``StreamingMiner`` through warm-up slides and
then >= 5 audited slides; ``audit_mine`` runs batch ``mine()`` twice and
audits the second (cache-warm) run.  ``check_shape_fixture`` is the
must-fail self-test: a deliberately rung-less jit loop plus an implicit
np-array dispatch, which MUST produce findings or the audit layer has
rotted.
"""
from __future__ import annotations

import contextlib
import logging
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .report import Finding

__all__ = ["compile_log", "audit_streaming", "audit_mine",
           "check_shape_fixture", "SHAPE_FIXTURES"]


# ---------------------------------------------------------------------------
# compile-event capture
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def compile_log():
    """Yield a list that collects XLA "Finished compilation" log messages.

    ``jax.log_compiles`` routes compile events through the ``jax`` logger
    tree at WARNING; a handler on the parent logger sees every backend
    (dispatch and pjit/pxla) via propagation.
    """
    import jax

    records: List[str] = []

    class _Capture(logging.Handler):
        def emit(self, rec: logging.LogRecord) -> None:
            msg = rec.getMessage()
            if "Finished XLA compilation" in msg:
                records.append(msg)

    handler = _Capture(level=logging.DEBUG)
    parent = logging.getLogger("jax")
    parent.addHandler(handler)
    try:
        with jax.log_compiles(True):
            yield records
    finally:
        parent.removeHandler(handler)


# ---------------------------------------------------------------------------
# synthetic deterministic stream
# ---------------------------------------------------------------------------

def _batches(rng: np.random.Generator, n: int, *, n_items: int,
             block_txns: int) -> List[List[List[int]]]:
    """``n`` micro-batches with a planted frequent 4-itemset so mining goes
    deep (levels >= 4) while the bulk of each transaction stays random."""
    out = []
    for _ in range(n):
        batch = []
        for _ in range(block_txns):
            t = set(rng.choice(n_items, size=int(rng.integers(2, 8)),
                               replace=False).tolist())
            if rng.random() < 0.6:
                t |= {0, 1, 2, 3}
            batch.append(sorted(t))
        out.append(batch)
    return out


def _ladder_findings(level_padding: Sequence[Tuple[int, int]], floor: int,
                     n_pair_devices: int, target: str) -> List[Finding]:
    """SH003 for every recorded padding that is not a per-device ladder rung."""
    from ..core.engine import bucket_size

    findings = []
    d = max(int(n_pair_devices), 1)
    for q, padded in level_padding:
        per_dev = padded // d if padded % d == 0 else padded
        if bucket_size(per_dev, floor) != per_dev:
            findings.append(Finding(
                rule="SH003", path=target, line=0,
                message=f"level padding {padded} for q={q} is off the "
                        f"bucket ladder (per-device {per_dev}, floor {floor})"))
    return findings


# ---------------------------------------------------------------------------
# audits
# ---------------------------------------------------------------------------

def audit_streaming(backend: str = "pallas", shard: str = "pairs",
                    mesh=None, *, slides: int = 5, warmup: int = 6,
                    n_items: int = 48, n_blocks: int = 4,
                    block_txns: int = 128, min_sup: int = 8,
                    seed: int = 0) -> Tuple[List[Finding], dict]:
    """Shape-closure audit of ``slides`` steady-state window slides.

    Fills the window, runs ``warmup`` live slides to populate every jit /
    bucket cache, then audits ``slides`` more under ``transfer_guard`` with
    the compile log armed.  Returns ``(findings, summary)``.
    """
    import jax

    from ..streaming.miner import StreamConfig, StreamingMiner

    target = f"<runtime:streaming/{backend}/{shard}>"
    rng = np.random.default_rng(seed)
    # periodic stream: the window's steady state cycles with period
    # n_blocks + 1, so one warm cycle visits every distinct window state —
    # audited slides then replay states the jit caches have already seen.
    # Any compile past warm-up is therefore a genuine ladder escape, not
    # stream drift.
    period = n_blocks + 1
    distinct = _batches(rng, period, n_items=n_items, block_txns=block_txns)
    batches = [distinct[i % period]
               for i in range(n_blocks + warmup + slides)]
    cfg = StreamConfig(min_sup=min_sup, n_blocks=n_blocks,
                       block_txns=block_txns, backend=backend, shard=shard)
    miner = StreamingMiner(n_items, cfg, mesh=mesh)

    with compile_log() as warm_recs:
        for b in batches[: n_blocks + warmup]:
            miner.advance(b)

    findings: List[Finding] = []
    audited = 0
    itemsets = 0
    for b in batches[n_blocks + warmup:]:
        with compile_log() as recs:
            try:
                with jax.transfer_guard("disallow"):
                    res = miner.advance(b)
                itemsets = res.total
            except Exception as e:  # guard trip surfaces as XlaRuntimeError
                findings.append(Finding(
                    rule="SH002", path=target, line=0,
                    message=f"implicit host transfer in audited slide "
                            f"{audited}: {e}"))
                break
        for msg in recs:
            findings.append(Finding(
                rule="SH001", path=target, line=0,
                message=f"steady-state recompile in audited slide "
                        f"{audited}: {msg.strip()}"))
        audited += 1

    findings.extend(_ladder_findings(
        miner.engine.level_padding, miner.engine.buffers.floor,
        getattr(miner.engine, "n_devices", 1), target))
    summary = {
        "target": target, "warmup_slides": warmup,
        "warmup_compiles": len(warm_recs),
        "audited_slides": audited, "itemsets_last_slide": itemsets,
        "findings": len(findings),
    }
    return findings, summary


def audit_mine(backend: str = "pallas", *, min_levels: int = 3,
               n_txn: int = 512, n_items: int = 48,
               seed: int = 1) -> Tuple[List[Finding], dict]:
    """Shape-closure audit of a cache-warm batch ``mine()`` run.

    The first run compiles; the second identical run must dispatch entirely
    from cache with no implicit transfers.  The planted itemset guarantees
    the lattice is at least ``min_levels`` deep, so the audit covers the
    deep-expand path, not just the pair level.
    """
    import jax

    from ..core.eclat import EclatConfig, mine

    target = f"<runtime:mine/{backend}>"
    rng = np.random.default_rng(seed)
    txns = _batches(rng, 1, n_items=n_items, block_txns=n_txn)[0]
    cfg = EclatConfig(min_sup=0.25, variant="v3", backend=backend)
    mine(txns, n_items, cfg)                       # warm run: compiles here

    findings: List[Finding] = []
    with compile_log() as recs:
        try:
            with jax.transfer_guard("disallow"):
                res = mine(txns, n_items, cfg)
        except Exception as e:
            findings.append(Finding(
                rule="SH002", path=target, line=0,
                message=f"implicit host transfer in warm mine run: {e}"))
            res = None
    for msg in recs:
        findings.append(Finding(
            rule="SH001", path=target, line=0,
            message=f"recompile in cache-warm mine run: {msg.strip()}"))

    levels = len(res.counts) if res is not None else 0
    if res is not None and levels < min_levels:
        findings.append(Finding(
            rule="SH001", path=target, line=0,
            message=f"mine audit only reached {levels} levels "
                    f"(< {min_levels}) — audit lost its deep-expand "
                    f"coverage; re-tune the planted itemset"))
    summary = {
        "target": target, "levels": levels,
        "itemsets": res.total if res is not None else 0,
        "findings": len(findings),
    }
    return findings, summary


# ---------------------------------------------------------------------------
# must-fail fixture: the audit layer's own self-test
# ---------------------------------------------------------------------------

def check_shape_fixture() -> List[Finding]:
    """Run deliberately contract-breaking programs; MUST return findings.

    Three planted violations, one per rule:

      SH001  a jit dispatched over raw, un-bucketed growing shapes past its
             warm-up — every "steady-state" call compiles;
      SH002  a raw np array at jit dispatch under ``transfer_guard`` — the
             implicit h2d the explicit-``device_put`` discipline forbids;
      SH003  a recorded padding that sits between ladder rungs.
    """
    import jax

    target = "<fixture:shapes>"
    findings: List[Finding] = []

    def _grow(x):
        return x * 2 + 1

    jit_grow = jax.jit(_grow)
    jit_grow(jax.device_put(np.zeros(64, np.int32)))       # warm-up shape
    with compile_log() as recs:
        for n in (65, 66, 67):                             # rung-less growth
            jit_grow(jax.device_put(np.zeros(n, np.int32)))
    for msg in recs:
        findings.append(Finding(
            rule="SH001", path=target, line=0,
            message=f"fixture recompile (expected): {msg.strip()}"))

    try:
        with jax.transfer_guard("disallow"):
            jit_grow(np.zeros(64, np.int32))               # implicit h2d
        # reaching here means the guard did NOT fire — drop no finding, the
        # caller treats an empty list as a rotted fixture
    except Exception as e:
        findings.append(Finding(
            rule="SH002", path=target, line=0,
            message=f"fixture implicit transfer (expected): {e}"))

    findings.extend(_ladder_findings(
        [(5, 130)], floor=128, n_pair_devices=1, target=target))
    return findings


SHAPE_FIXTURES = ("shapes",)
