"""Layer 2: lowered-IR contract checker over the engine backends.

Every backend's per-level executor — plus the streaming ring's block write —
is lowered under a forced multi-device mesh, compiled, and its post-SPMD
HLO walked via ``analysis.hlo_parse``.  The DESIGN §7/§8 axis-ownership
contracts become static assertions against :data:`BUDGETS`:

  IR001  the collective *set* must match the declared one exactly —
         tidsharded/grid own exactly one ``psum`` (all-reduce) per level,
         jnp/pallas/sharded own none, and nothing may all-gather a
         frontier/window block;
  IR002  collective payload stays within the byte budget: the one psum
         carries partial *counts* — 4 bytes per padded pair — never
         bitmap words;
  IR003  the psum spans exactly the declared reduce axis: its replica
         groups are as wide as that axis, so class shards (grid) never
         mix their disjoint pair blocks.

Each contract has a committed must-fail fixture (:data:`CONTRACT_FIXTURES`)
— a deliberately wrong shard_map program whose HLO the same assertions must
reject, proving the checker still has teeth.

Imports jax lazily at call time so ``staticcheck`` Layer 1 stays
importable without a device runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from .report import Finding

__all__ = ["BUDGETS", "BACKENDS_CHECKED", "BackendBudget",
           "check_backend_contract", "check_ring_write_contract",
           "check_all_contracts", "CONTRACT_FIXTURES",
           "check_contract_fixture"]

BACKENDS_CHECKED = ("jnp", "pallas", "sharded", "tidsharded", "grid")


@dataclasses.dataclass
class BackendBudget:
    """Declared collective behaviour of one backend's level executor."""

    collectives: Dict[str, int]     # exact kind -> count in the lowered HLO
    reduce_axis: Optional[str]      # mesh axis the one psum spans (or None)
    bytes_per_pair: int = 4         # int32 partial counts cross the wire


BUDGETS: Dict[str, BackendBudget] = {
    # single-device executors and the communication-free pair-sharded
    # executor: the frontier is replicated, partial results never cross
    # devices — zero collectives (the paper's shuffle-free executor stage)
    "jnp": BackendBudget(collectives={}, reduce_axis=None),
    "pallas": BackendBudget(collectives={}, reduce_axis=None),
    "sharded": BackendBudget(collectives={}, reduce_axis=None),
    # word-sharded executors: exactly one psum of the (Q,) partial counts
    # over the word (data) axis per level — DESIGN §7 (tidsharded) and §8
    # (grid, where the psum must NOT span the class axis)
    "tidsharded": BackendBudget(collectives={"all-reduce": 1},
                                reduce_axis="data"),
    "grid": BackendBudget(collectives={"all-reduce": 1},
                          reduce_axis="data"),
}


def _require_devices(n: int = 2) -> None:
    import jax
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"the IR contract layer needs >= {n} devices "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count=4); "
            f"got {len(jax.devices())}")


def lower_backend_hlo(backend: str, *, rows: int = 32, words: int = 64,
                      qb: int = 256) -> Tuple[str, int, int, int]:
    """Lower one level expansion of ``backend`` and return
    ``(hlo_text, n_devices, per_shard_pairs, reduce_axis_width)``.

    Shapes sit on the engine's own ladder (``qb`` a rung multiple of the
    128 floor); the lowered executor is the exact jitted callable the
    engine dispatches in ``expand`` — not a re-implementation.
    """
    import numpy as np
    import jax
    from ..core import engine as eng
    from ..kernels.fused_intersect import (MODE_TIDSET,
                                           fused_intersect_compact,
                                           fused_intersect_compact_ref)
    from ..launch.mesh import make_data_mesh, make_grid_mesh

    def ivec(n):
        return jax.device_put(np.zeros(n, np.int32))

    msup = jax.device_put(np.int32(2))
    if backend in ("jnp", "pallas"):
        frontier = jax.device_put(np.zeros((rows, words), np.uint32))
        if backend == "jnp":
            fn = fused_intersect_compact_ref
        else:
            fn = jax.jit(lambda bms, l, r, s, m, nv, mode: (
                fused_intersect_compact(bms, l, r, s, m, nv, mode=mode)),
                static_argnames=("mode",))
        lowered = fn.lower(frontier, ivec(qb), ivec(qb), ivec(qb), msup,
                           jax.device_put(np.int32(qb)), mode=MODE_TIDSET)
        return lowered.compile().as_text(), 1, qb, 1

    _require_devices()
    if backend == "sharded":
        mesh = make_data_mesh()
        engine = eng.make_engine("sharded", mesh=mesh)
        d = engine.n_devices
        frontier = jax.device_put(np.zeros((rows, words), np.uint32))
        lowered = engine._sharded[MODE_TIDSET].lower(
            frontier, ivec(d * qb), ivec(d * qb), ivec(d * qb), msup)
        return lowered.compile().as_text(), d, qb, 1
    if backend == "tidsharded":
        mesh = make_data_mesh()
        engine = eng.make_engine("tidsharded", mesh=mesh)
        frontier = engine.prepare_frontier(
            jax.device_put(np.zeros((rows, words), np.uint32)))
        lowered = engine._sharded[MODE_TIDSET].lower(
            frontier, ivec(qb), ivec(qb), ivec(qb), msup,
            jax.device_put(np.int32(qb)))
        return (lowered.compile().as_text(), engine.n_shards, qb,
                engine.n_shards)
    if backend == "grid":
        mesh = make_grid_mesh()
        engine = eng.make_engine("grid", mesh=mesh)
        d = engine.n_class
        frontier = engine.prepare_frontier(
            jax.device_put(np.zeros((rows, words), np.uint32)))
        lowered = engine._sharded[MODE_TIDSET].lower(
            frontier, ivec(d * qb), ivec(d * qb), ivec(d * qb), msup)
        return (lowered.compile().as_text(), d * engine.n_shards, qb,
                engine.n_shards)
    raise ValueError(f"unknown backend {backend!r}; "
                     f"checked: {BACKENDS_CHECKED}")


def _assert_budget(target: str, hlo: str, n_devices: int,
                   per_shard_pairs: int, axis_width: int,
                   budget: BackendBudget) -> List[Finding]:
    """The shared contract assertions — run on real backends AND on the
    must-fail fixtures, so one code path proves both directions."""
    from ..analysis.hlo_parse import parse_collectives
    stats = parse_collectives(hlo, n_devices)
    findings: List[Finding] = []
    if stats.count != budget.collectives:
        findings.append(Finding(
            rule="IR001", path=target, line=0,
            message=f"collective set {stats.count or '{}'} does not match "
                    f"the declared {budget.collectives or '{}'} — every "
                    f"level may ship exactly the declared psum set and "
                    f"must never all-gather a frontier/window block"))
    budget_bytes = per_shard_pairs * budget.bytes_per_pair
    for kind, nbytes in stats.bytes_raw.items():
        if kind in budget.collectives and nbytes > budget_bytes:
            findings.append(Finding(
                rule="IR002", path=target, line=0,
                message=f"{kind} carries {int(nbytes)} bytes > the "
                        f"{budget_bytes}-byte count budget "
                        f"({per_shard_pairs} padded pairs x "
                        f"{budget.bytes_per_pair} B) — bitmap words are "
                        f"crossing the interconnect"))
    if budget.reduce_axis is not None:
        for instr in stats.instrs:
            if instr.kind != "all-reduce":
                continue
            if instr.group_size != axis_width:
                findings.append(Finding(
                    rule="IR003", path=target, line=instr.line,
                    message=f"psum replica groups span {instr.group_size} "
                            f"device(s) but the declared reduce axis "
                            f"{budget.reduce_axis!r} is {axis_width} wide "
                            f"— the reduction is mixing shards that own "
                            f"disjoint pair blocks (or missing some that "
                            f"share one)"))
    return findings


def check_backend_contract(backend: str) -> Tuple[List[Finding], dict]:
    """Lower one backend and assert its budget; returns (findings, info)."""
    hlo, n_dev, per_shard, axis_w = lower_backend_hlo(backend)
    budget = BUDGETS[backend]
    findings = _assert_budget(f"backend:{backend}", hlo, n_dev, per_shard,
                              axis_w, budget)
    from ..analysis.hlo_parse import parse_collectives
    stats = parse_collectives(hlo, n_dev)
    info = {"backend": backend, "n_devices": n_dev,
            "collectives": dict(stats.count),
            "wire_bytes": stats.total_wire_bytes,
            "declared": dict(budget.collectives)}
    return findings, info


def check_ring_write_contract() -> Tuple[List[Finding], dict]:
    """The streaming ring's sharded block write must lower with zero
    collectives: each shard overwrites only the written-span words it owns
    (a ``dynamic_update_slice`` on the sharded word axis regresses to an
    all-gather of the whole window — the bug this contract pins down)."""
    import numpy as np
    import jax
    from ..analysis.hlo_parse import parse_collectives
    from ..launch.mesh import make_data_mesh
    from ..streaming.window import WindowRing

    _require_devices()
    mesh = make_data_mesh()
    ring = WindowRing(32, 4, 128, keep_transactions=False, mesh=mesh)
    hlo = ring._write_sharded.lower(
        jax.ShapeDtypeStruct(ring.device.shape, ring.device.dtype,
                             sharding=ring.device.sharding),
        jax.ShapeDtypeStruct((ring.n_items, ring.wpb), np.dtype(np.uint32)),
        jax.ShapeDtypeStruct((), np.dtype(np.int32)),
    ).compile().as_text()
    n_dev = ring.n_shards
    stats = parse_collectives(hlo, n_dev)
    findings: List[Finding] = []
    if stats.total_count:
        findings.append(Finding(
            rule="IR001", path="streaming:ring_write", line=0,
            message=f"the ring block write lowered with collectives "
                    f"{stats.count} — a slide must touch only the word "
                    f"span each shard owns (zero collectives)"))
    info = {"target": "streaming:ring_write", "n_devices": n_dev,
            "collectives": dict(stats.count)}
    return findings, info


def check_all_contracts() -> Tuple[List[Finding], dict]:
    findings: List[Finding] = []
    summary: dict = {"backends": {}}
    for backend in BACKENDS_CHECKED:
        fs, info = check_backend_contract(backend)
        findings.extend(fs)
        summary["backends"][backend] = info
    fs, info = check_ring_write_contract()
    findings.extend(fs)
    summary["ring_write"] = info
    return findings, summary


# -- must-fail fixtures ------------------------------------------------------

def _fixture_extra_psum() -> Tuple[str, int, int, int, BackendBudget]:
    """Two psums per level where the contract declares one (IR001)."""
    import numpy as np
    import jax
    from ..dist.compat import shard_map
    from ..launch.mesh import make_data_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_data_mesh()
    d = int(mesh.shape["data"])

    def body(pop):
        total = jax.lax.psum(pop, "data")
        # a second, redundant reduction — the bug class this catches
        return total + jax.lax.psum(pop * 0, "data")

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),),
                           out_specs=P()))
    hlo = fn.lower(jax.ShapeDtypeStruct((d * 256,), np.dtype(np.int32))
                   ).compile().as_text()
    return hlo, d, 256, d, BUDGETS["tidsharded"]


def _fixture_frontier_allgather() -> Tuple[str, int, int, int, BackendBudget]:
    """A level that all-gathers the word-sharded frontier (IR001)."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from ..dist.compat import shard_map_unchecked
    from ..launch.mesh import make_data_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_data_mesh()
    d = int(mesh.shape["data"])

    def body(block):
        full = jax.lax.all_gather(block, "data", axis=1, tiled=True)
        return jnp.sum(jax.lax.population_count(full).astype(jnp.int32),
                       axis=1)

    fn = jax.jit(shard_map_unchecked(body, mesh=mesh,
                                     in_specs=(P(None, "data"),),
                                     out_specs=P()))
    hlo = fn.lower(jax.ShapeDtypeStruct((256, 64), np.dtype(np.uint32))
                   ).compile().as_text()
    return hlo, d, 256, d, BUDGETS["tidsharded"]


def _fixture_fat_psum() -> Tuple[str, int, int, int, BackendBudget]:
    """One psum, but of the whole (Q, W) bitmap block, not the (Q,) counts
    — right collective set, busted byte budget (IR002)."""
    import numpy as np
    import jax
    from ..dist.compat import shard_map
    from ..launch.mesh import make_data_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_data_mesh()
    d = int(mesh.shape["data"])

    def body(block):
        return jax.lax.psum(block, "data")

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data", None),),
                           out_specs=P()))
    hlo = fn.lower(jax.ShapeDtypeStruct((d * 256, 64), np.dtype(np.int32))
                   ).compile().as_text()
    return hlo, d, 256, d, BUDGETS["tidsharded"]


def _fixture_wrong_axis_psum() -> Tuple[str, int, int, int, BackendBudget]:
    """A grid-style psum over BOTH mesh axes: class shards' disjoint pair
    counts get mixed (IR003, and IR002 once per extra group width)."""
    import numpy as np
    import jax
    from ..dist.compat import shard_map
    from ..launch.mesh import make_grid_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_grid_mesh()
    n_class = int(mesh.shape["class"])
    n_data = int(mesh.shape["data"])

    def body(pop):
        return jax.lax.psum(pop, ("class", "data"))

    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("class"),),
                           out_specs=P()))
    hlo = fn.lower(jax.ShapeDtypeStruct((n_class * 256,), np.dtype(np.int32))
                   ).compile().as_text()
    # the budget declares the reduce over "data" (width n_data); the bad
    # program's groups span n_class * n_data devices
    return hlo, n_class * n_data, 256, n_data, BUDGETS["grid"]


CONTRACT_FIXTURES: Dict[str, Callable[[], Tuple[str, int, int, int,
                                                BackendBudget]]] = {
    "extra_psum": _fixture_extra_psum,
    "frontier_allgather": _fixture_frontier_allgather,
    "fat_psum": _fixture_fat_psum,
    "wrong_axis_psum": _fixture_wrong_axis_psum,
}


def check_contract_fixture(name: str) -> List[Finding]:
    """Lower one committed bad program and run the real assertions on it.
    A healthy checker returns a non-empty finding list for every fixture."""
    if name not in CONTRACT_FIXTURES:
        raise ValueError(f"unknown contract fixture {name!r}; "
                         f"have: {sorted(CONTRACT_FIXTURES)}")
    _require_devices()
    hlo, n_dev, per_shard, axis_w, budget = CONTRACT_FIXTURES[name]()
    return _assert_budget(f"fixture:{name}", hlo, n_dev, per_shard,
                          axis_w, budget)
