"""Static contract guard for the mining stack (DESIGN.md §12).

Three layers, one gate (``scripts/check_static.py``):

  Layer 1  AST lint over the repo's own Python (``astlint`` + ``rules``):
           the historical bug classes of PRs 1-9 codified as named rules
           RS001-RS005, each with a committed must-fail fixture.
  Layer 2  lowered-IR contract checker (``contracts``): every engine
           backend plus the streaming ring write is lowered under a forced
           multi-device mesh and its post-SPMD HLO is walked via
           ``analysis.hlo_parse`` — the declared collective set, reduce-axis
           group sizes, and byte budgets are asserted statically.
  Layer 3  runtime-shape audit (``shapes``): N streaming slides and M mine
           levels traced under ``jax.log_compiles`` + ``jax.transfer_guard``,
           asserting the compiled-shape set is closed under the half-pow2
           bucket ladder (zero steady-state recompiles, zero implicit host
           transfers).

Layer 1 imports no jax and is safe anywhere; layers 2/3 import jax lazily
so the lint stays usable in environments without a device runtime.
"""
from .report import Finding, Report, SEVERITY_ERROR, SEVERITY_WARNING
from .rules import RULES, HOT_PATHS, rule_ids
from .astlint import lint_file, lint_paths, iter_python_files

__all__ = [
    "Finding", "Report", "SEVERITY_ERROR", "SEVERITY_WARNING",
    "RULES", "HOT_PATHS", "rule_ids",
    "lint_file", "lint_paths", "iter_python_files",
]
