"""RS001 must-fail fixture: a runtime invariant guarded by bare ``assert``.

Distilled from the PR 4-6 bug class: under ``python -O`` (the CI optimized
smokes) this check vanishes and corrupt state flows downstream silently.
Never imported — the gate lints it and must report RS001.
"""
import numpy as np


def validate_ring(words: np.ndarray, n_items: int, n_words: int) -> None:
    assert words.shape == (n_items, n_words)  # stripped under python -O
    assert words.dtype == np.uint32
