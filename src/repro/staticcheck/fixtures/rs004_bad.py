"""RS004 must-fail fixture: ``XLA_FLAGS`` overwritten instead of appended.

The original catch: ``scripts/diagnose_collectives.py`` clobbered any
device-count or dump flag the caller had already exported.  Never imported
— the gate lints it and must report RS004.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
