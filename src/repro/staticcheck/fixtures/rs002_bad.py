"""RS002 must-fail fixture: ``np.empty`` slot buffer later gathered.

Distilled from the PR 4 slot-corruption bug: a pair whose device id falls
outside the grouping loop leaves its slot uninitialized, and the gather
reads garbage as a valid index.  Never imported — the gate lints it and
must report RS002.
"""
import numpy as np


def build_slots(q: int, device_of_pair: np.ndarray, qmax: int) -> np.ndarray:
    slot_of_pair = np.empty(q, np.int64)        # garbage if a slot is missed
    extra = np.empty((q, 2), dtype=np.int32)    # same class, dtype kwarg
    for dev in range(int(device_of_pair.max()) + 1):
        idx = np.nonzero(device_of_pair == dev)[0]
        slot_of_pair[idx] = dev * qmax + np.arange(idx.shape[0])
        extra[idx, 0] = dev
    return slot_of_pair
