"""RS003 must-fail fixture: truthiness on int-or-None config fields.

Distilled from the PR 6 ``max_k=0`` bug: ``max_k or n`` coerces the valid
value 0 into "unbounded".  Never imported — the gate lints it and must
report RS003.
"""


def plan_levels(config, n_items: int) -> int:
    kmax = config.max_k or n_items          # 0 silently becomes unbounded
    if not config.cand_chunk:               # 0 is a valid chunk override
        kmax = min(kmax, 2)
    return kmax
