"""RS005 must-fail fixture: implicit conversions in a declared hot path.

Distilled from the pre-PR-10 slide loop: ``jnp.asarray`` on the host block
(implicit h2d at jit dispatch) and ``np.asarray`` on the device result
(implicit d2h) — both break under ``jax.transfer_guard("disallow")``, the
Layer-3 steady-state contract.  Never imported — the gate lints it and
must report RS005.
"""
# staticcheck: hot-path
import numpy as np
import jax.numpy as jnp


def push(state, new_block: np.ndarray) -> np.ndarray:
    state.device = state.writer(state.device, jnp.asarray(new_block),
                                jnp.int32(0))
    return np.asarray(state.device)
