"""hymba-1.5b [hybrid]: 32L d1600 25H GQA(kv=5) d_ff 5504 vocab 32001,
parallel attention + mamba heads per block, ssm_state 16
[arXiv:2411.13676; hf].  Hybrid/state-based -> long_500k RUNS.
Attention branch uses a 2048 sliding window (Hymba's global-local scheme,
meta-tokens stubbed out — DESIGN.md §4)."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32_001,
    hybrid=True, window=2048, ssm_state=16, ssm_expand=2,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True,
))
