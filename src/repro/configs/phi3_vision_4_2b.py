"""phi-3-vision-4.2b [vlm]: 32L d3072 32H GQA(kv=32) d_ff 8192 vocab 32064,
phi3-mini backbone + CLIP frontend STUB (input_specs supplies 256 precomputed
patch embeddings, early fusion) [hf:microsoft/Phi-3-vision-128k-instruct; hf].
long_500k skipped (full attention)."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_064,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=False,
    frontend="vision", frontend_len=256, rope_theta=10_000.0,
    skip_shapes=(("long_500k", "pure full attention — see DESIGN.md §4"),),
))
