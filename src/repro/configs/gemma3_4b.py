"""gemma3-4b [dense]: 34L d2560 8H GQA(kv=4) d_ff 10240 vocab 262144,
5:1 local:global (window 1024), head_dim 256
[hf:google/gemma-3-1b-pt; unverified].  Sub-quadratic (5/6 of layers are
sliding-window) -> long_500k RUNS for this arch."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab_size=262_144,
    attn_pattern="local_global", local_per_global=5, window=1024,
    mlp_act="geglu", norm="rmsnorm", tie_embeddings=True, scale_embed=True,
    rope_theta=1_000_000.0, qk_norm=True,
))
