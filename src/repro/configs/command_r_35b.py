"""command-r-35b [dense]: 40L d8192 64H GQA(kv=8) d_ff 22528 vocab 256000,
parallel attn∥FFN blocks, LayerNorm, no biases
[hf:CohereForAI/c4ai-command-r-v01; unverified].  long_500k skipped."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256_000,
    parallel_block=True, mlp_act="swiglu", norm="layernorm",
    tie_embeddings=True, rope_theta=8_000_000.0,
    skip_shapes=(("long_500k", "pure full attention — see DESIGN.md §4"),),
))
