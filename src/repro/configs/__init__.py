"""Architecture registry: the 10 assigned archs (+ the paper's FIM configs).

Every config is importable as ``repro.configs.<module>.CONFIG`` and
selectable via ``get_config("<arch-id>")`` / ``--arch <id>`` on the
launchers.  Source citations are in each module's docstring.
"""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (command_r_35b, gemma3_4b, gemma_2b, grok_1_314b,
                   hymba_1_5b, internlm2_20b, llama4_maverick_400b,
                   phi3_vision_4_2b, whisper_base, xlstm_1_3b)  # noqa: F401


__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "SHAPES",
           "get_config", "list_configs", "register"]
