"""Config dataclasses shared by every architecture and the launch stack."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Field semantics follow the assignment table.

    ``layer_pattern`` drives the stage compiler in ``models.transformer``:
    a list of layer-kind strings, e.g. 34 entries of
    ["local"]*5 + ["global"] repeating for gemma3.  Homogeneous runs of the
    same kind become one ``lax.scan`` stage so the lowered HLO stays compact
    at 512 devices.
    """

    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads
    # attention
    attn_pattern: str = "global"   # global | window | local_global
    window: int = 0                # sliding window size for local layers
    local_per_global: int = 0      # gemma3: 5 local then 1 global
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    # block composition
    parallel_block: bool = False   # command-r / GPT-J style attn ∥ mlp
    seq_parallel: bool = False     # Megatron-SP: residual sharded over 'model' on seq
    mlp_dp: bool = False           # replicate FFN weights over 'model', compute on
                                   # seq-sharded activations (needs seq_parallel):
                                   # trades activation ARs for weight-grad ARs
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu | none
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = True
    scale_embed: bool = False   # gemma: embed * sqrt(d_model)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # llama4: MoE every 2nd layer (interleaved)
    capacity_factor: float = 1.25
    expert_sharding: str = "ep"    # ep (experts over data) | tp2d (ffn over data+model)
    moe_dispatch: str = "local"    # local (per-shard sort + a2a) | global (naive)
    expert_split: int = 1          # expert fission: split each expert into N
                                   # half-d_ff slots so E*N divides the EP axis
                                   # (exact for gated FFNs; grok: 8 experts -> 16 slots)
    expert_placement: str = "default"   # default | greedy — Eclat-style balancing
    # SSM / recurrent
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0           # xlstm: one sLSTM per this many mLSTM blocks
    # hybrid (hymba): attention and SSM heads in parallel in every block
    hybrid: bool = False
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_len: int = 0           # fixed encoder frames (whisper: 1500)
    # modality frontend stub: input_specs() supplies precomputed embeddings
    frontend: Optional[str] = None  # None | audio | vision
    frontend_len: int = 0          # prefix embedding length for vlm
    dtype: str = "bfloat16"
    # which shapes are skipped, with reason (DESIGN.md §4)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()
    # exact layer-kind pattern override (scan-calibration variants only)
    pattern_override: Tuple[str, ...] = ()

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def layer_pattern(self) -> List[str]:
        """Per-layer kind list for the decoder stack."""
        if self.pattern_override:
            return list(self.pattern_override)
        kinds: List[str] = []
        for i in range(self.n_layers):
            if self.n_encoder_layers:
                kind = "xdec"
            elif self.hybrid:
                kind = "hybrid"
            elif self.family == "ssm" and self.slstm_every:
                kind = "slstm" if (i % self.slstm_every == self.slstm_every - 1) else "mlstm"
            elif self.family == "ssm":
                kind = "mlstm"
            elif self.attn_pattern == "local_global" and self.local_per_global:
                kind = "local" if (i % (self.local_per_global + 1)) < self.local_per_global else "attn"
            elif self.attn_pattern == "window":
                kind = "local"
            else:
                kind = "attn"
            if self.n_experts and (i % self.moe_every == self.moe_every - 1):
                kind += "+moe"
            kinds.append(kind)
        return kinds

    def _counts(self):
        d, f = self.d_model, self.d_ff
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.mlp_act in ("swiglu", "geglu"):
            mlp = 3 * d * f
        elif self.mlp_act == "none":
            mlp = 0
        else:
            mlp = 2 * d * f
        pattern = self.layer_pattern()
        n_moe = sum(1 for k in pattern if k.endswith("+moe"))
        return attn, mlp, n_moe, pattern

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stack), for 6ND."""
        d, v = self.d_model, self.vocab_size
        attn, mlp, n_moe, pattern = self._counts()
        total = v * d + (0 if self.tie_embeddings else v * d)
        if self.family == "ssm":
            din = 2 * d
            hd = din // self.n_heads
            mlstm = (d * 2 * din + self.n_heads * 3 * hd * hd
                     + din * 2 * self.n_heads + din * d + d)
            slstm = d * 4 * d + d + d * d + d
            for k in pattern:
                total += mlstm if k == "mlstm" else slstm
            return int(total)
        for k in pattern:
            total += attn + 2 * d
            if k.endswith("+moe"):
                total += self.n_experts * mlp + d * self.n_experts
            else:
                total += mlp
            if k.startswith("hybrid"):
                din = self.ssm_expand * d
                total += 2 * d * din + din * d + din * (2 * self.ssm_state + 2)
            if k.startswith("xdec"):
                total += attn  # cross-attention
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + mlp + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        _, mlp, n_moe, _ = self._counts()
        dense = self.param_count() - n_moe * self.n_experts * mlp
        return int(dense + n_moe * self.top_k * mlp)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation
    remat: str = "dots"              # none | dots | full
    zero1: bool = True               # shard optimizer state over data axes
    opt_dtype: str = "float32"       # AdamW moment dtype (bfloat16 halves opt memory)
    grad_compression: str = "none"   # none | int8 | topk
    checkpoint_every: int = 100
    seed: int = 0
