"""Reduced (smoke-test) variants of the assigned configs.

Same family/topology, tiny widths: used by per-arch CPU smoke tests and the
examples.  Full-size configs are only ever lowered abstractly via the
dry-run (ShapeDtypeStruct — no allocation), per the assignment.
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig

__all__ = ["reduced_config"]


def reduced_config(cfg: ModelConfig, *, d_model: int = 64, vocab: int = 256) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        n_layers=(cfg.local_per_global + 1) if cfg.local_per_global
        else min(cfg.n_layers, 4),
        d_model=d_model,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) or 1,
        d_head=d_model // 4,
        d_ff=d_model * 2 if cfg.d_ff else 0,
        vocab_size=vocab,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        window=min(cfg.window, 8) if cfg.window else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_len=16 if cfg.encoder_len else 0,
        frontend_len=4 if cfg.frontend_len else 0,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
        dtype="float32",
    )
