"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H GQA(kv=8) d_ff 8192/expert,
MoE 128 experts top-1, vocab 202048, early fusion (stubbed)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  128 experts shard over
the EP (data) axis; Eclat-style greedy expert placement is this framework's
paper-technique integration (DESIGN.md §4).  long_500k skipped (assigned
config treated as full attention per its spec line)."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202_048,
    n_experts=128, top_k=1, moe_every=2, expert_sharding="ep", expert_placement="greedy",
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=False,
    rope_theta=500_000.0,
    skip_shapes=(("long_500k", "assigned config is full attention — "
                  "see DESIGN.md §4"),),
))
