"""grok-1-314b [moe]: 64L d6144 48H GQA(kv=8) d_ff 32768, MoE 8 experts
top-2, vocab 131072 [hf:xai-org/grok-1; unverified].  8 experts don't divide
the 16-wide EP axis -> expert_sharding=tp2d (each expert's 32k d_ff sharded
over data x model; DESIGN.md §2).  long_500k skipped."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131_072,
    n_experts=8, top_k=2, expert_sharding="tp2d",
    mlp_act="geglu", norm="rmsnorm", tie_embeddings=True,
    attn_logit_softcap=30.0,
    skip_shapes=(("long_500k", "pure full attention — see DESIGN.md §4"),),
))
