"""gemma-2b [dense]: 18L d2048 8H MQA(kv=1) d_ff 16384 GeGLU vocab 256000,
head_dim 256 [arXiv:2403.08295; hf].  Pure full attention -> long_500k skipped."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
    d_ff=16384, vocab_size=256_000,
    mlp_act="geglu", norm="rmsnorm", tie_embeddings=True, scale_embed=True,
    rope_theta=10_000.0,
    skip_shapes=(("long_500k", "pure full attention; quadratic prefill and "
                  "un-windowed KV growth — see DESIGN.md §4"),),
))
