"""whisper-base [audio]: 6L enc + 6L dec, d512 8H d_ff 2048 vocab 51865,
conv frontend STUB (input_specs supplies 1500 precomputed frame embeddings)
[arXiv:2212.04356; unverified].  Backbone-only per the assignment; decode_32k
is lowered mechanically (32k self-KV is architecturally meaningless for 30 s
audio — noted in DESIGN.md §4); long_500k skipped (enc-dec, full attention).
Adaptation: RoPE replaces Whisper's learned positions in the decoder (noted)."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51_865,
    mlp_act="gelu", norm="layernorm", tie_embeddings=True,
    n_encoder_layers=6, encoder_len=1500, frontend="audio",
    skip_shapes=(("long_500k", "enc-dec full attention over 30 s audio; "
                  "500k-token decode is architecturally meaningless"),),
))
