"""internlm2-20b [dense]: 48L d6144 48H GQA(kv=8) d_ff 16384 vocab 92544
[arXiv:2403.17297; hf].  Pure full attention -> long_500k skipped."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92_544,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=False,
    rope_theta=1_000_000.0,
    skip_shapes=(("long_500k", "pure full attention — see DESIGN.md §4"),),
))
