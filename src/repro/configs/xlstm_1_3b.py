"""xlstm-1.3b [ssm]: 48L d2048 4H, sLSTM + mLSTM blocks (7:1), d_ff=0
(blocks carry their own 2x up/down projection) vocab 50304
[arXiv:2405.04517; unverified].  State-based -> long_500k RUNS."""
from . import register
from .base import ModelConfig

CONFIG = register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    mlp_act="none", norm="rmsnorm", tie_embeddings=True,
    slstm_every=8, rope_theta=0.0,
))
