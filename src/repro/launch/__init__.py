"""repro.launch — mesh construction, dry-run, train/serve/mine/stream drivers.

NOTE: dryrun must be executed as a module entry point
(``python -m repro.launch.dryrun``) so its XLA_FLAGS lines run before any
jax import; do not import it from here.
"""
from .mesh import make_mesh_named, make_production_mesh

__all__ = ["make_mesh_named", "make_production_mesh"]
