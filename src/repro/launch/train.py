"""Production training driver: mesh + pjit train step + fault-tolerant runner.

On this CPU container it runs reduced configs end-to-end; on a real pod the
same driver takes ``--arch <id> --mesh single|multi`` and full shapes (the
dry-run proves those lower+compile).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --reduced --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import SHAPES, TrainConfig, get_config
from ..configs.reduced import reduced_config
from ..data import TokenPipeline
from ..dist.sharding import set_mesh, sharding_tree
from ..models import Model, init_params
from ..training import (RunnerConfig, TrainingRunner, adamw_init,
                        make_train_step)
from .mesh import make_mesh_named


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--mesh", default=None,
                    help="single|multi|tiny; default: no mesh (1 device)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced-width config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = make_mesh_named(args.mesh) if args.mesh else None
    set_mesh(mesh)

    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    tcfg = TrainConfig(total_steps=args.steps, microbatches=args.microbatches,
                       remat=args.remat)
    step = make_train_step(model, tcfg)
    if mesh is not None:
        pshard = sharding_tree(jax.eval_shape(lambda: params), mesh,
                               cfg.expert_sharding)
        params = jax.device_put(params, pshard)
        # pin out_shardings for params too: the runner feeds step outputs
        # back in, and a committed output whose GSPMD-chosen sharding drifts
        # from in_shardings fails the next call
        step = jax.jit(step, in_shardings=(pshard, None, None),
                       out_shardings=(pshard, None, None))
    else:
        step = jax.jit(step)

    pipe = TokenPipeline(cfg.vocab_size, batch=args.batch, seq_len=args.seq,
                         seed=tcfg.seed)

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}

    runner = TrainingRunner(
        RunnerConfig(args.ckpt_dir, checkpoint_every=args.ckpt_every),
        step, params, opt, batch_fn)
    resumed = runner.maybe_restore()
    t0 = time.perf_counter()
    final = runner.run(args.steps)
    dt = time.perf_counter() - t0
    losses = [m["loss"] for m in runner.metrics_log]
    print(f"[train] {cfg.name} steps {resumed}->{final} in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}" if losses else "no steps")


if __name__ == "__main__":
    main()
