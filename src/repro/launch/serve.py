"""Serving driver: batched LM requests, or the FIM query front end.

    # LM workload (reduced model, batched generation)
    PYTHONPATH=src python -m repro.launch.serve --workload lm \
        --arch gemma3-4b --requests 8

    # FIM workload: async admission + query storm while the miner slides
    PYTHONPATH=src python -m repro.launch.serve --workload fim \
        --dataset T10I4D100K --min-sup 0.01 --slides 8 --queries 200 \
        --clients 4 [--policy shed --queue-cap 64] [--stall-timeout 5]

    # restarted server: answer the storm from a restored checkpoint window
    PYTHONPATH=src python -m repro.launch.serve --workload fim \
        --restore --checkpoint-dir /tmp/stream_ck --queries 100

The FIM mode is the production shape of DESIGN.md §11: a writer thread
slides windows underneath while client threads storm the bounded admission
queue; every answer is version-stamped, and the driver verifies each one by
checksum against a direct synchronous answer at the same ``window_version``
before printing p50/p99 latency, QPS, and cache hit rate.  A stalled writer
is detected by heartbeat (``--stall-timeout``) and reported — exit code 4 —
instead of hanging the storm.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np


def serve_lm(args) -> None:
    from ..configs import get_config
    from ..configs.reduced import reduced_config
    from ..models import Model, init_params
    from ..serving import Request, ServingEngine

    cfg = reduced_config(get_config(args.arch))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(model, params, s_max=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, int(rng.integers(4, 48))).astype(np.int32),
        max_new_tokens=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    results, stats = engine.serve(reqs, n_batches=args.batches)
    lat = stats["latency"]
    print(f"[serve] {cfg.name}: {len(results)} requests in "
          f"{time.perf_counter()-t0:.1f}s; pack eff "
          f"{stats['padding_efficiency']:.3f}; answer p50 "
          f"{lat['answer_ms']['p50']:.0f}ms p99 {lat['answer_ms']['p99']:.0f}ms")


def serve_fim(args) -> None:
    from ..data import stream_spec, transaction_stream
    from ..serving import (AdmissionConfig, ServingFrontend, query_mix,
                           run_storm, verify_storm)
    from ..streaming import StreamConfig, StreamingMiner
    from ..training import HeartbeatMonitor, WriterStalledError
    from .mesh import mesh_for_mining

    acfg = AdmissionConfig(
        max_queue=args.queue_cap, policy=args.policy,
        max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3,
        n_slots=args.slots, stall_timeout_s=args.stall_timeout,
        keep_versions=max(args.slides + 2, 8))

    if args.restore:
        if not args.checkpoint_dir:
            raise SystemExit("--restore requires --checkpoint-dir")
        frontend, completed = ServingFrontend.from_checkpoint(
            args.checkpoint_dir, config=acfg)
        print(f"[serve] restored {args.checkpoint_dir}: {completed} completed "
              f"slides, window_version={frontend.window_version}, "
              f"{len(frontend.snapshot.itemsets)} itemsets — serving from "
              f"the restored window")
        writer = None
    else:
        spec = stream_spec(args.dataset)
        cfg = StreamConfig(min_sup=args.min_sup, n_blocks=args.n_blocks,
                           block_txns=args.block_txns, backend=args.backend)
        mesh = mesh_for_mining(args.backend, "pairs", None)
        miner = StreamingMiner(spec.n_items, cfg, mesh=mesh,
                               keep_transactions=False)
        frontend = ServingFrontend(miner, acfg)
        batches = list(transaction_stream(args.dataset, cfg.block_txns,
                                          args.slides, seed=args.seed))
        frontend.ingest(batches[0])     # serve a non-empty first window

        def slide():
            for b in batches[1:]:
                frontend.ingest(b)
                time.sleep(args.slide_gap_ms / 1e3)
        writer = threading.Thread(target=slide, name="miner-writer",
                                  daemon=True)
        writer.start()
        print(f"[serve] {spec.name}: window={cfg.n_blocks}x{cfg.block_txns} "
              f"txns, min_sup={cfg.min_sup}, {args.slides} slides underneath "
              f"a {args.queries}-query storm ({args.clients} clients, "
              f"policy={args.policy}, queue={args.queue_cap})")

    queries = query_mix(args.queries, seed=args.seed)
    monitor = (HeartbeatMonitor(frontend.heartbeat, args.stall_timeout,
                                name="miner writer")
               if args.stall_timeout and writer is not None else None)
    outcome = run_storm(frontend, queries, n_clients=args.clients)
    if writer is not None:
        while writer.is_alive():
            if monitor is not None:
                try:
                    monitor.assert_alive()
                except WriterStalledError as e:
                    print(f"[serve] STALL DETECTED: {e}")
                    frontend.stop()
                    raise SystemExit(4)
            writer.join(timeout=0.1)
    ver = verify_storm(frontend, queries, outcome)
    m = frontend.metrics.summary()
    c = frontend.cache.stats()
    print(f"[serve] answered {m['n_answered']}/{len(queries)} "
          f"(shed {m['n_shed']}, errors {m['n_errors']}); "
          f"latency p50 {m['latency_ms']['p50']:.2f}ms "
          f"p99 {m['latency_ms']['p99']:.2f}ms; {m['qps']:.0f} qps; "
          f"mean batch {m['mean_batch']:.1f}")
    print(f"[serve] cache: hit rate {c['hit_rate']:.1%} "
          f"({c['hits']} hits / {c['misses']} misses / {c['stale_evicted']} "
          f"invalidated by slides); final window_version="
          f"{frontend.window_version}")
    print(f"[serve] verified {ver['verified']} answers bit-identical with "
          f"the synchronous path at their window versions "
          f"(checksum {ver['checksum']})")
    frontend.stop()
    if outcome["errors"]:
        raise SystemExit(f"query errors: {outcome['errors']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "fim"],
                    help="lm: batched generation; fim: async itemset-query "
                         "front end under a query storm (DESIGN.md §11)")
    # lm workload
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    # fim workload
    ap.add_argument("--dataset", default="T10I4D100K")
    ap.add_argument("--min-sup", type=float, default=0.01)
    ap.add_argument("--n-blocks", type=int, default=4)
    ap.add_argument("--block-txns", type=int, default=256)
    ap.add_argument("--backend", default="pallas")
    ap.add_argument("--slides", type=int, default=8,
                    help="window slides the writer performs under the storm")
    ap.add_argument("--slide-gap-ms", type=float, default=5.0,
                    help="writer pause between slides")
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--queue-cap", type=int, default=256,
                    help="bounded admission queue capacity")
    ap.add_argument("--policy", default="block", choices=["block", "shed"],
                    help="full-queue backpressure policy")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="drain trigger: batch size")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="drain trigger: oldest-query deadline")
    ap.add_argument("--slots", type=int, default=4,
                    help="greedy-LPT answer slots per drained batch")
    ap.add_argument("--stall-timeout", type=float, default=5.0,
                    help="writer heartbeat deadline (s); 0 disables")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="with --restore: streaming/persist.py checkpoint "
                         "directory to serve from")
    ap.add_argument("--restore", action="store_true",
                    help="rebuild the front end from the newest checkpoint "
                         "and answer the storm from the restored window")
    args = ap.parse_args(argv)
    if args.workload == "fim":
        serve_fim(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
