"""Serving driver: batched requests against a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..configs.reduced import reduced_config
from ..models import Model, init_params
from ..serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch))
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(model, params, s_max=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, int(rng.integers(4, 48))).astype(np.int32),
        max_new_tokens=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    results, stats = engine.serve(reqs, n_batches=args.batches)
    print(f"[serve] {cfg.name}: {len(results)} requests in "
          f"{time.perf_counter()-t0:.1f}s; pack eff "
          f"{stats['padding_efficiency']:.3f}")


if __name__ == "__main__":
    main()
