"""Production meshes.

Kept as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).

Single pod: (data=16, model=16) — 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; gradients reduce over
(pod, data), the pod axis proves cross-pod sharding lowers.
A deeper `pipeline` axis can be requested for >2-pod topologies.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..dist.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_mesh_named", "make_data_mesh",
           "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_data_mesh() -> jax.sharding.Mesh:
    """One ``("data",)`` axis over every visible device — what the mining
    CLIs build for the mesh-mapped engine backends (forced host devices
    included: set XLA_FLAGS before launch)."""
    return make_mesh((len(jax.devices()),), ("data",))


def make_mesh_named(name: str) -> jax.sharding.Mesh:
    if name in ("single", "single_pod", "16x16"):
        return make_production_mesh(multi_pod=False)
    if name in ("multi", "multi_pod", "2x16x16"):
        return make_production_mesh(multi_pod=True)
    if name == "tiny":   # tests: 4 host devices
        return make_mesh((2, 2), ("data", "model"))
    if name == "pipeline":  # optional deeper topology (not an assigned mesh)
        return make_mesh((2, 2, 8, 16), ("pipe", "pod", "data", "model"))
    raise ValueError(f"unknown mesh {name!r}")
