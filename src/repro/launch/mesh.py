"""Production meshes.

Kept as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).

Single pod: (data=16, model=16) — 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; gradients reduce over
(pod, data), the pod axis proves cross-pod sharding lowers.
A deeper `pipeline` axis can be requested for >2-pod topologies.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax

from ..dist.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_mesh_named", "make_data_mesh",
           "make_grid_mesh", "factor_grid", "parse_grid_arg",
           "mesh_for_mining", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_data_mesh() -> jax.sharding.Mesh:
    """One ``("data",)`` axis over every visible device — what the mining
    CLIs build for the mesh-mapped engine backends (forced host devices
    included: set XLA_FLAGS before launch)."""
    return make_mesh((len(jax.devices()),), ("data",))


def factor_grid(n: int) -> Tuple[int, int]:
    """Most-square ``(n_class, n_data)`` factorization of ``n`` devices with
    ``n_class <= n_data`` (4 -> (2, 2), 8 -> (2, 4), 6 -> (2, 3), a prime p
    -> (1, p)).  Ties lean toward the data axis: frontier memory scales with
    ``n_data`` while pair work rebalances across levels anyway, so the wider
    axis goes to the harder constraint."""
    n = int(n)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    best = (1, n)
    for c in range(1, math.isqrt(n) + 1):
        if n % c == 0:
            best = (c, n // c)
    return best


def make_grid_mesh(n_class: Optional[int] = None,
                   n_data: Optional[int] = None) -> jax.sharding.Mesh:
    """2D ``("class", "data")`` mesh for the grid-sharded engine
    (DESIGN.md §8): pairs split over ``class``, the packed word (tid) axis
    over ``data``.  With neither dimension given, the visible devices are
    auto-factorized most-square (:func:`factor_grid`); with one given, the
    other is the visible count divided by it."""
    n = len(jax.devices())
    if n_class is None and n_data is None:
        n_class, n_data = factor_grid(n)
    elif n_class is None:
        n_data = int(n_data)
        if n_data < 1 or n % n_data:
            raise ValueError(f"n_data={n_data} does not divide the {n} "
                             f"visible device(s)")
        n_class = n // n_data
    elif n_data is None:
        n_class = int(n_class)
        if n_class < 1 or n % n_class:
            raise ValueError(f"n_class={n_class} does not divide the {n} "
                             f"visible device(s)")
        n_data = n // n_class
    else:
        n_class, n_data = int(n_class), int(n_data)
    if n_class < 1 or n_data < 1 or n_class * n_data > n:
        raise ValueError(f"grid {n_class}x{n_data} needs "
                         f"{n_class * n_data} device(s); {n} visible")
    return make_mesh((n_class, n_data), ("class", "data"),
                     devices=jax.devices()[: n_class * n_data])


def parse_grid_arg(spec: Optional[str]) -> Tuple[Optional[int], Optional[int]]:
    """Parse a CLI ``--grid RxC`` string ("2x2", "4x1") into ``(n_class,
    n_data)``; ``None`` means auto-factorize (:func:`make_grid_mesh`)."""
    if spec is None:
        return None, None
    parts = spec.lower().replace("×", "x").split("x")
    if len(parts) != 2:
        raise ValueError(f"--grid expects RxC (e.g. 2x2), got {spec!r}")
    try:
        n_class, n_data = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(f"--grid expects integer RxC (e.g. 2x2), got {spec!r}")
    return n_class, n_data


def mesh_for_mining(backend: str, shard: str,
                    grid: Optional[str] = None) -> Optional[jax.sharding.Mesh]:
    """The mesh a mining CLI's backend/shard request needs (one source of
    truth for ``launch.mine`` and ``launch.stream``): a 2D grid mesh for
    the grid mode (``grid`` is the raw ``--grid RxC`` string, auto-factorized
    when absent), a 1D ``("data",)`` mesh for the other mesh-mapped modes,
    ``None`` for the single-device backends."""
    if backend == "grid" or shard == "grid":
        return make_grid_mesh(*parse_grid_arg(grid))
    if grid is not None:
        # silently dropping --grid would run a different configuration than
        # the one the user asked to measure
        raise ValueError(
            f"--grid {grid} requires the grid mode (--shard grid or "
            f"--backend grid); got backend={backend!r}, shard={shard!r}")
    if backend in ("sharded", "tidsharded") or shard == "words":
        return make_data_mesh()
    return None


def make_mesh_named(name: str) -> jax.sharding.Mesh:
    if name in ("single", "single_pod", "16x16"):
        return make_production_mesh(multi_pod=False)
    if name in ("multi", "multi_pod", "2x16x16"):
        return make_production_mesh(multi_pod=True)
    if name == "tiny":   # tests: 4 host devices
        return make_mesh((2, 2), ("data", "model"))
    if name == "pipeline":  # optional deeper topology (not an assigned mesh)
        return make_mesh((2, 2, 8, 16), ("pipe", "pod", "data", "model"))
    raise ValueError(f"unknown mesh {name!r}")
