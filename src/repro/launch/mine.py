"""Mining driver: the paper's job as a launchable (the Spark-submit analogue).

    PYTHONPATH=src python -m repro.launch.mine --dataset chess --min-sup 0.8 \
        --variant v5 --checkpoint-dir /tmp/mine_ckpt

Workload modes (DESIGN.md §9): ``--mode closed|maximal`` post-filters the
mined lattice, ``--top-k K`` replaces the threshold with the adaptive
min_sup ladder, ``--fimi FILE.dat`` mines a FIMI-format file (retail.dat
et al.) instead of a synthetic paper dataset.
"""
from __future__ import annotations

import argparse
import os
import time

from ..core import EclatConfig, generate_rules, mine, resume_mine, top_k_mine
from ..data import PAPER_DATASETS, generate, load_fimi


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="chess", choices=list(PAPER_DATASETS))
    ap.add_argument("--fimi", default=None, metavar="FILE.dat",
                    help="mine a FIMI-format transaction file instead of "
                         "--dataset (one txn per line, whitespace-separated "
                         "integer item ids)")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--min-sup", type=float, default=0.8)
    ap.add_argument("--mode", default="all",
                    choices=["all", "closed", "maximal"],
                    help="workload mode: all frequent itemsets, or the "
                         "closed/maximal subset (lineage post-filter)")
    ap.add_argument("--top-k", type=int, default=None, metavar="K",
                    help="mine the K highest-support itemsets via the "
                         "adaptive min_sup ladder (--min-sup is ignored)")
    ap.add_argument("--variant", default="v4",
                    choices=["v1", "v2", "v3", "v4", "v5", "v6"])
    ap.add_argument("--p", type=int, default=10)
    ap.add_argument("--backend", default="pallas",
                    choices=["jnp", "pallas", "sharded", "tidsharded", "grid",
                             "auto"],
                    help="engine backend; 'auto' picks from the measured "
                         "crossover table (BENCH_kerneltune.json, "
                         "DESIGN.md §6), falling back to pallas")
    ap.add_argument("--shard", default="pairs",
                    choices=["pairs", "words", "grid"],
                    help="mesh split under a device mesh: candidate pairs, "
                         "the frontier's word axis, or both on a 2D grid "
                         "(DESIGN.md §7-8)")
    ap.add_argument("--grid", default=None, metavar="RxC",
                    help="class x data mesh shape for --shard grid, e.g. 2x2 "
                         "(default: auto-factorize the visible devices)")
    ap.add_argument("--diffsets", action="store_true",
                    help="dEclat diffsets (variant v6 only)")
    ap.add_argument("--block-w", type=int, default=None, metavar="WORDS",
                    help="fused-kernel word-tile width override (default: "
                         "autotuned table / cost-model seed)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune-on-miss: measure untuned kernel shape classes "
                         "before dispatching them (winners persist in the "
                         "autotune cache)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--restore", action="store_true",
                    help="resume the deepest mining checkpoint in "
                         "--checkpoint-dir instead of mining from scratch; "
                         "--backend/--shard/--grid select the *restoring* "
                         "mesh, which may differ from the original run's "
                         "(live re-meshing, DESIGN.md §10)")
    ap.add_argument("--min-conf", type=float, default=0.0,
                    help="if >0, also generate association rules")
    args = ap.parse_args(argv)

    if args.fimi:
        txns, n_items = load_fimi(args.fimi)
        name = os.path.basename(args.fimi)
        tri_matrix = None                     # auto (item-id range heuristic)
        scale_note = ""
    else:
        txns, spec = generate(args.dataset, scale=args.scale, seed=1)
        name, n_items = spec.name, spec.n_items
        tri_matrix = spec.tri_matrix or None
        scale_note = f" x{args.scale}"
    cfg = EclatConfig(min_sup=args.min_sup, variant=args.variant, p=args.p,
                      tri_matrix=tri_matrix,
                      use_diffsets=args.diffsets,
                      backend=args.backend, shard=args.shard,
                      mode=args.mode,
                      block_w=args.block_w, autotune=args.autotune,
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every_level=args.checkpoint_dir is not None)
    from .mesh import mesh_for_mining
    mesh = mesh_for_mining(args.backend, args.shard, args.grid)

    if args.restore:
        if not args.checkpoint_dir:
            ap.error("--restore requires --checkpoint-dir")
        t0 = time.perf_counter()
        res = resume_mine(cfg, mesh=mesh)
        dt = time.perf_counter() - t0
        print(f"[mine] resumed {res.stats['resumed_from']} at level "
              f"{res.stats['resume_k']} ({res.stats['backend']}): "
              f"{res.total} itemsets in {dt:.2f}s levels={res.counts}")
        if args.min_conf > 0:
            rules = generate_rules(res.support_map(), args.min_conf)
            print(f"[mine] {len(rules)} rules at conf>={args.min_conf}")
        return

    if args.top_k is not None:
        t0 = time.perf_counter()
        tk = top_k_mine(txns, n_items, args.top_k, config=cfg, mesh=mesh)
        dt = time.perf_counter() - t0
        print(f"[mine] {name}{scale_note} top-{args.top_k} "
              f"({len(tk.itemsets)} returned) in {dt:.2f}s: ladder "
              f"{[r['abs_min_sup'] for r in tk.ladder]} -> "
              f"abs_min_sup={tk.abs_min_sup}")
        for itemset, sup in tk.itemsets[: min(args.top_k, 10)]:
            print(f"[mine]   {itemset} sup={sup}")
        return

    t0 = time.perf_counter()
    res = mine(txns, n_items, cfg, mesh=mesh)
    dt = time.perf_counter() - t0
    grid_note = (f" grid={mesh.shape['class']}x{mesh.shape['data']}"
                 if mesh is not None and "class" in mesh.axis_names else "")
    mode_note = (f" {args.mode}={res.stats['mode_itemsets']}"
                 if args.mode != "all" else "")
    print(f"[mine] {name}{scale_note} min_sup={args.min_sup} "
          f"{args.variant}: {res.total} itemsets in {dt:.2f}s "
          f"levels={res.counts}{grid_note}{mode_note}")
    if args.min_conf > 0:
        rules = generate_rules(res.support_map(), args.min_conf)
        print(f"[mine] {len(rules)} rules at conf>={args.min_conf}")


if __name__ == "__main__":
    main()
