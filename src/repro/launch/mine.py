"""Mining driver: the paper's job as a launchable (the Spark-submit analogue).

    PYTHONPATH=src python -m repro.launch.mine --dataset chess --min-sup 0.8 \
        --variant v5 --checkpoint-dir /tmp/mine_ckpt
"""
from __future__ import annotations

import argparse
import time

from ..core import EclatConfig, generate_rules, mine
from ..data import PAPER_DATASETS, generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="chess", choices=list(PAPER_DATASETS))
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--min-sup", type=float, default=0.8)
    ap.add_argument("--variant", default="v4",
                    choices=["v1", "v2", "v3", "v4", "v5", "v6"])
    ap.add_argument("--p", type=int, default=10)
    ap.add_argument("--backend", default="pallas",
                    choices=["jnp", "pallas", "sharded", "tidsharded", "grid"])
    ap.add_argument("--shard", default="pairs",
                    choices=["pairs", "words", "grid"],
                    help="mesh split under a device mesh: candidate pairs, "
                         "the frontier's word axis, or both on a 2D grid "
                         "(DESIGN.md §7-8)")
    ap.add_argument("--grid", default=None, metavar="RxC",
                    help="class x data mesh shape for --shard grid, e.g. 2x2 "
                         "(default: auto-factorize the visible devices)")
    ap.add_argument("--diffsets", action="store_true",
                    help="dEclat diffsets (variant v6 only)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--min-conf", type=float, default=0.0,
                    help="if >0, also generate association rules")
    args = ap.parse_args(argv)

    txns, spec = generate(args.dataset, scale=args.scale, seed=1)
    cfg = EclatConfig(min_sup=args.min_sup, variant=args.variant, p=args.p,
                      tri_matrix=spec.tri_matrix or None,
                      use_diffsets=args.diffsets,
                      backend=args.backend, shard=args.shard,
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every_level=args.checkpoint_dir is not None)
    from .mesh import mesh_for_mining
    mesh = mesh_for_mining(args.backend, args.shard, args.grid)
    t0 = time.perf_counter()
    res = mine(txns, spec.n_items, cfg, mesh=mesh)
    dt = time.perf_counter() - t0
    grid_note = (f" grid={mesh.shape['class']}x{mesh.shape['data']}"
                 if mesh is not None and "class" in mesh.axis_names else "")
    print(f"[mine] {spec.name} x{args.scale} min_sup={args.min_sup} "
          f"{args.variant}: {res.total} itemsets in {dt:.2f}s "
          f"levels={res.counts}{grid_note}")
    if args.min_conf > 0:
        rules = generate_rules(res.support_map(), args.min_conf)
        print(f"[mine] {len(rules)} rules at conf>={args.min_conf}")


if __name__ == "__main__":
    main()
