"""Dry-run cell builder: (arch x shape x mesh) -> (step fn, abstract args,
shardings).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation — and ``build_cell`` assembles
the jit-able step with explicit in/out shardings so ``.lower().compile()``
exercises exactly the production distribution plan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, TrainConfig, get_config
from ..dist.sharding import batch_spec, dp_axes, set_mesh, spec_tree
from ..models import Model, init_params
from ..training.optimizer import adamw_init, zero1_spec_tree
from ..training.train_step import make_train_step

__all__ = ["input_specs", "build_cell", "cache_spec_tree", "cell_skip_reason"]

SDS = jax.ShapeDtypeStruct


def cell_skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    for name, reason in cfg.skip_shapes:
        if name == shape_name:
            return reason
    return None


def _model_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def input_specs(arch: str, shape_name: str) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    dt = _model_dtype(cfg)
    if shp.kind == "decode":
        out = {"tokens": SDS((b, 1), jnp.int32), "pos": SDS((b,), jnp.int32)}
    else:
        out = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.n_encoder_layers:
        out["enc_embeds"] = SDS((b, cfg.encoder_len, cfg.d_model), dt)
    if cfg.frontend == "vision" and shp.kind != "decode":
        out["img_embeds"] = SDS((b, cfg.frontend_len, cfg.d_model), dt)
    return out


# ---------------------------------------------------------------------------
# cache sharding
# ---------------------------------------------------------------------------

def cache_spec_tree(cache_shapes, mesh: Mesh, batch: int):
    """Cache sharding rules.

    KV leaves (.../k, .../v of shape (L, B, S, KV, hd)): head_dim over
    'model' — this matches the layout attention produces, so prefill's cache
    write is layout-local (no involuntary reshard); B==1 (long-context)
    additionally shards the sequence over 'data' so the idle batch axis
    still splits the KV bytes.  Recurrent-state leaves: batch over dp when
    divisible, then the largest dim that divides 'model'."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    m_size = mesh.shape.get("model", 1)
    d_size = mesh.shape.get("data", 1)
    batch_ok = batch % dp_size == 0

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        shape = leaf.shape
        entries = [None] * len(shape)
        if len(shape) >= 2 and batch_ok and shape[1] == batch:
            entries[1] = dp if len(dp) > 1 else dp[0]
        if name in ("k", "v") and len(shape) == 5:
            if shape[4] % m_size == 0:
                entries[4] = "model"
            if not batch_ok and shape[2] % d_size == 0:
                entries[2] = "data"
            return P(*entries)
        cand = sorted(range(2, len(shape)), key=lambda i: -shape[i])
        for i in cand:
            if not batch_ok and shape[i] % (dp_size * m_size) == 0:
                entries[i] = tuple(list(dp) + ["model"])
                break
            if shape[i] % m_size == 0 and shape[i] >= m_size:
                entries[i] = "model"
                break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


# ---------------------------------------------------------------------------
# cell assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    kind: str
    fn: Any                 # jit-able python callable
    args: Tuple             # abstract args (ShapeDtypeStructs)
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               cfg_override=None, tcfg: Optional[TrainConfig] = None) -> Cell:
    cfg = cfg_override or get_config(arch)
    shp = SHAPES[shape_name]
    model = Model(cfg)
    set_mesh(mesh)
    # dry-run default: 8 microbatches + dots remat — the baseline activation-
    # memory posture at global_batch 256 (per-arch tuning happens in §Perf)
    tcfg = tcfg or TrainConfig(microbatches=8, remat="dots")
    dt = _model_dtype(cfg)
    b, s = shp.global_batch, shp.seq_len

    params_shape = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = spec_tree(params_shape, mesh, cfg.expert_sharding,
                       getattr(cfg, "mlp_dp", False))
    pshard = _named(mesh, pspecs)

    batch_sds = input_specs(arch, shape_name)
    if cfg_override is not None:  # calibration variants keep full-size inputs
        pass
    bspec = batch_spec(b, mesh)
    bshard = {k: NamedSharding(mesh, P(*([bspec[0]] + [None] * (len(v.shape) - 1))))
              for k, v in batch_sds.items()}
    repl = NamedSharding(mesh, P())

    if shp.kind == "train":
        mdt = jnp.bfloat16 if getattr(tcfg, "opt_dtype", "float32") == "bfloat16" else jnp.float32
        opt_shape = jax.eval_shape(
            functools.partial(adamw_init, moment_dtype=mdt), params_shape)
        widen = zero1_spec_tree(pspecs, mesh) if tcfg.zero1 else (lambda sp, shape: sp)
        mu_specs = jax.tree.map(
            lambda sp, leaf: widen(sp, leaf.shape), pspecs, params_shape)
        opt_specs = {"mu": mu_specs, "nu": mu_specs, "step": P()}
        oshard = _named(mesh, opt_specs)
        step = make_train_step(model, tcfg)
        metrics_shard = repl
        return Cell(
            arch=arch, shape_name=shape_name, kind="train",
            fn=step,
            args=(params_shape, opt_shape, batch_sds),
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, metrics_shard),
            donate_argnums=(0, 1),
            meta={"tokens": b * s},
        )

    if shp.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, s_max=s)

        cache_shape = jax.eval_shape(
            lambda: model.init_cache(b, s, dt))
        cspecs = cache_spec_tree(cache_shape, mesh, b)
        cshard = _named(mesh, cspecs)
        v_ax = "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 else None
        logits_shard = NamedSharding(mesh, P(bspec[0], None, v_ax))
        return Cell(
            arch=arch, shape_name=shape_name, kind="prefill",
            fn=prefill_step,
            args=(params_shape, batch_sds),
            in_shardings=(pshard, bshard),
            out_shardings=(logits_shard, cshard),
            donate_argnums=(),
            meta={"tokens": b * s},
        )

    # decode
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, s, dt))
    cspecs = cache_spec_tree(cache_shape, mesh, b)
    cshard = _named(mesh, cspecs)
    tok_sds = batch_sds["tokens"]
    pos_sds = batch_sds["pos"]
    tokshard = bshard["tokens"]
    posshard = bshard["pos"]
    v_ax = "model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0 else None
    logits_shard = NamedSharding(mesh, P(bspec[0], None, v_ax))

    if cfg.n_encoder_layers:
        enc_out_sds = SDS((b, cfg.encoder_len, cfg.d_model), dt)
        xkv_shape = jax.eval_shape(
            lambda p, e: model.cross_kv(p, e), params_shape, enc_out_sds)
        xkv_specs = jax.tree.map(
            lambda leaf: P(None, bspec[0], None, None, None), xkv_shape)
        xkvshard = _named(mesh, xkv_specs)

        def serve_step(params, cache, token, pos, enc_kv):
            return model.decode_step(params, token, cache, pos, enc_out=enc_kv)

        return Cell(
            arch=arch, shape_name=shape_name, kind="decode",
            fn=serve_step,
            args=(params_shape, cache_shape, tok_sds, pos_sds, xkv_shape),
            in_shardings=(pshard, cshard, tokshard, posshard, xkvshard),
            out_shardings=(logits_shard, cshard),
            donate_argnums=(1,),
            meta={"tokens": b},
        )

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, token, cache, pos)

    return Cell(
        arch=arch, shape_name=shape_name, kind="decode",
        fn=serve_step,
        args=(params_shape, cache_shape, tok_sds, pos_sds),
        in_shardings=(pshard, cshard, tokshard, posshard),
        out_shardings=(logits_shard, cshard),
        donate_argnums=(1,),
        meta={"tokens": b},
    )
