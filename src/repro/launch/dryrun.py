import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST precede any jax import (jax locks the device count
# at first init).  Everything below is ordinary.
"""Multi-pod dry-run driver.

For one (arch x shape x mesh) cell:
  1. build the production step (specs.build_cell) with explicit shardings,
  2. jit(...).lower(*abstract_args).compile()  — THE deliverable,
  3. record memory_analysis / cost_analysis / collective schedule,
  4. scan-calibrate FLOP/byte/collective totals (analysis.roofline),
  5. write reports/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from ..analysis.roofline import (CellReport, calibration_patterns,
                                 measure_compiled, model_flops,
                                 roofline_terms)
from ..configs import SHAPES, get_config, list_configs
from ..dist.sharding import set_mesh
from .mesh import make_mesh_named
from .specs import build_cell, cell_skip_reason

REPORT_DIR = "reports/dryrun"


def lower_and_compile(cell):
    jitted = jax.jit(
        cell.fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate_argnums,
    )
    t0 = time.perf_counter()
    lowered = jitted.lower(*cell.args)
    compiled = lowered.compile()
    return lowered, compiled, time.perf_counter() - t0


def run_cell(arch: str, shape_name: str, mesh_name: str,
             calibrate: bool = True, verbose: bool = True) -> dict:
    skip = cell_skip_reason(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    mesh = make_mesh_named(mesh_name)
    n_dev = mesh.size
    cfg = get_config(arch)
    shp = SHAPES[shape_name]

    with mesh:
        cell = build_cell(arch, shape_name, mesh)
        lowered, compiled, compile_s = lower_and_compile(cell)
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        flops_raw, bytes_raw, coll_raw, memory = measure_compiled(compiled, n_dev)

        flops, nbytes, wire = flops_raw, bytes_raw, coll_raw.total_wire_bytes
        coll_counts = dict(coll_raw.count)
        coll_bytes = dict(coll_raw.bytes_wire)
        calibrated = False
        if calibrate:
            try:
                flops, nbytes, wire, coll_counts, coll_bytes = _calibrate(
                    arch, shape_name, mesh, n_dev,
                    flops_raw, bytes_raw, coll_raw)
                calibrated = True
            except Exception:
                traceback.print_exc()

    terms = roofline_terms(flops, nbytes, wire)
    mf = model_flops(cfg, shp)
    report = CellReport(
        arch=arch, shape=shape_name, mesh=mesh_name, kind=shp.kind,
        n_devices=n_dev,
        flops_per_device=flops, bytes_per_device=nbytes,
        wire_bytes_per_device=wire,
        collective_counts=coll_counts, collective_bytes=coll_bytes,
        memory=memory, terms=terms,
        model_flops_total=mf,
        hlo_model_ratio=(flops * n_dev) / mf if mf else 0.0,
        compile_s=compile_s, calibrated=calibrated,
    )
    out = report.to_dict()
    out["status"] = "ok"
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compile={compile_s:.1f}s "
              f"peak={memory['peak_gb']:.2f}GB/dev "
              f"terms(ms): C={terms.compute_s*1e3:.2f} M={terms.memory_s*1e3:.2f} "
              f"X={terms.collective_s*1e3:.2f} dom={terms.dominant} "
              f"HLO/MODEL={out['hlo_model_ratio']:.2f}")
    return out


def _calibrate(arch, shape_name, mesh, n_dev, flops_full, bytes_full, coll_full):
    """Per-kind depth-delta calibration (see analysis.roofline docstring).

    Calibration compiles run under cost mode (inner chunk scans widened to a
    single iteration so HloCostAnalysis sees every op) with microbatches=1
    (FLOPs are batch-linear; the deliverable full compile keeps production
    microbatching for the memory picture)."""
    from ..analysis.hlo_parse import parse_collectives
    from ..configs import TrainConfig
    from ..models.costing import costing
    cfg = get_config(arch)
    base_pat, variants, counts = calibration_patterns(cfg)
    cal_tcfg = TrainConfig(microbatches=1, remat="dots")

    def measure(pattern, cost: bool, enc_layers=None):
        c = dataclasses.replace(
            cfg, pattern_override=tuple(pattern),
            n_layers=len(pattern),
            n_encoder_layers=enc_layers if enc_layers is not None
            else cfg.n_encoder_layers)
        with costing(widen_chunks=cost, unroll=True):
            cell = build_cell(arch, shape_name, mesh, cfg_override=c,
                              tcfg=cal_tcfg)
            _, compiled, _ = lower_and_compile(cell)
        f, b, coll, _ = measure_compiled(compiled, n_dev)
        return f, b, coll

    # Pass A (cost mode): exact FLOPs — inner chunk scans widened so every op
    # is visible.  Pass B (production mode): bytes + the real collective
    # schedule (cost mode's materialized attention makes GSPMD insert
    # partial-sum all-reduces the chunked program never issues).
    enc_base = 1 if cfg.n_encoder_layers else None
    fA0, _, _ = measure(base_pat, True, enc_layers=enc_base)
    _, b0, c0 = measure(base_pat, False, enc_layers=enc_base)
    flops = fA0
    nbytes = b0
    wire = c0.total_wire_bytes
    coll_counts = dict(c0.count)
    coll_bytes = dict(c0.bytes_wire)

    def add_delta(fA1, b1, c1, extra):
        nonlocal flops, nbytes, wire
        flops += (fA1 - fA0) * extra
        nbytes += (b1 - b0) * extra
        wire += (c1.total_wire_bytes - c0.total_wire_bytes) * extra
        for k in set(c1.bytes_wire) | set(c0.bytes_wire):
            d = c1.bytes_wire.get(k, 0.0) - c0.bytes_wire.get(k, 0.0)
            coll_bytes[k] = coll_bytes.get(k, 0.0) + d * extra
        for k in set(c1.count) | set(c0.count):
            d = c1.count.get(k, 0) - c0.count.get(k, 0)
            coll_counts[k] = coll_counts.get(k, 0) + d * extra

    for kind, pat in variants.items():
        extra = counts[kind] - 1
        if extra <= 0:
            continue
        fA1, _, _ = measure(pat, True, enc_layers=enc_base)
        _, b1, c1 = measure(pat, False, enc_layers=enc_base)
        add_delta(fA1, b1, c1, extra)
    if cfg.n_encoder_layers and cfg.n_encoder_layers > 1:
        fA1, _, _ = measure(base_pat, True, enc_layers=2)
        _, b1, c1 = measure(base_pat, False, enc_layers=2)
        add_delta(fA1, b1, c1, cfg.n_encoder_layers - 1)
    return flops, nbytes, wire, coll_counts, coll_bytes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--out-dir", default=REPORT_DIR)
    args = ap.parse_args(argv)

    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out_dir, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                key = f"{arch}__{shape}__{mesh_name}"
                path = os.path.join(args.out_dir, key + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {key}")
                    continue
                try:
                    rep = run_cell(arch, shape, mesh_name,
                                   calibrate=not args.no_calibrate)
                except Exception as e:
                    traceback.print_exc()
                    rep = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e)}
                    failures.append(key)
                with open(path, "w") as f:
                    json.dump(rep, f, indent=1)
    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
