"""Streaming driver: sliding-window mining over a live micro-batch stream.

    PYTHONPATH=src python -m repro.launch.stream --dataset T10I4D100K \
        --min-sup 0.01 --block-txns 512 --n-blocks 8 --batches 12 \
        --top-k 5 --min-conf 0.8 [--drift-every 6] [--backend pallas]

Each slide prints the re-mine latency, window occupancy, class churn
(equivalence classes entering/leaving the active set), and the live top-k;
``--min-conf`` adds the rule count of the current window.

Recovery (DESIGN.md §10): ``--checkpoint-dir`` writes an async miner
snapshot every ``--checkpoint-every`` slides; after a crash, rerun with
``--restore`` (same ``--dataset``/``--seed``/``--drift-every`` — the stream
is deterministic, so completed slides are skipped and the rest replayed).
``--remesh`` restores under *this* invocation's ``--backend``/``--shard``/
``--grid`` and visible devices instead of the checkpoint's recorded config —
live re-meshing, bit-exact either way.

Serving (DESIGN.md §11): ``--serve`` puts the stream behind the async
admission front end — the slides above run on a writer thread (checkpointer
and fault injection included) while the main thread fires a
``--serve-queries`` storm at the bounded queue and verifies every answer
against the synchronous path at its stamped ``window_version``.  A writer
that stops beating its heartbeat for ``--stall-timeout`` seconds is
*reported* (exit code 4) instead of hanging the readers.
"""
from __future__ import annotations

import argparse
import threading

from ..data import PAPER_DATASETS, stream_spec, transaction_stream
from ..faults import InjectedFault, clear_kill_hook, set_kill_hook
from ..serving import StreamQueryService
from ..streaming import (StreamCheckpointer, StreamConfig, StreamingMiner,
                         peek_config, restore_miner)


def _serve_mode(args, miner, cfg, ck, start):
    """--serve: slides on a writer thread, query storm on the main thread.

    The writer is the exact synchronous slide loop (checkpointer, kill-hook
    fault injection and all) moved behind :class:`ServingFrontend`; readers
    never touch the miner, only published snapshots, so a crashed or stalled
    writer degrades to answering from the last complete window — detected
    and reported, never a hang.
    """
    from ..serving import (AdmissionConfig, ServingFrontend, query_mix,
                           run_storm, verify_storm)
    from ..training import HeartbeatMonitor, WriterStalledError

    acfg = AdmissionConfig(max_queue=args.queue_cap, policy=args.serve_policy,
                           stall_timeout_s=args.stall_timeout or None,
                           keep_versions=max(args.batches + 2, 8))
    frontend = ServingFrontend(miner, acfg)
    writer_fault = []

    def writer():
        try:
            for i, batch in enumerate(transaction_stream(
                    args.dataset, cfg.block_txns, args.batches,
                    seed=args.seed, drift_every=args.drift_every)):
                if i < start:
                    continue
                if args.kill_after is not None and i == args.kill_after:
                    def _die(name):
                        if name == "miner:mid_append":
                            raise InjectedFault(name)
                    set_kill_hook(_die)
                res = frontend.ingest(batch)
                print(f"[stream] slide {i:3d}: window={res.n_txn} txns "
                      f"itemsets={res.total} version={res.version} "
                      f"latency={res.stats['slide_s']*1e3:.1f}ms")
                if ck is not None:
                    ck.maybe_save(miner, i + 1)
        except InjectedFault as e:
            writer_fault.append(e)
        finally:
            clear_kill_hook()
            if ck is not None:
                ck.wait()

    wt = threading.Thread(target=writer, name="miner-writer", daemon=True)
    wt.start()
    monitor = (HeartbeatMonitor(frontend.heartbeat, args.stall_timeout,
                                name="miner writer")
               if args.stall_timeout else None)
    queries = query_mix(args.serve_queries, seed=args.seed)
    outcome = run_storm(frontend, queries, n_clients=args.serve_clients)
    stalled = None
    while wt.is_alive():
        if monitor is not None:
            try:
                monitor.assert_alive()
            except WriterStalledError as e:
                stalled = e
                break
        wt.join(timeout=0.1)

    ver = verify_storm(frontend, queries, outcome)
    m = frontend.metrics.summary()
    c = frontend.cache.stats()
    print(f"[stream] storm: answered {m['n_answered']}/{len(queries)} "
          f"(shed {m['n_shed']}, errors {m['n_errors']}); latency "
          f"p50 {m['latency_ms']['p50']:.2f}ms p99 "
          f"{m['latency_ms']['p99']:.2f}ms; {m['qps']:.0f} qps; cache hit "
          f"rate {c['hit_rate']:.1%} ({c['stale_evicted']} invalidated)")
    print(f"[stream] verified {ver['verified']} answers bit-identical at "
          f"their window versions (checksum {ver['checksum']}); final "
          f"window_version={frontend.window_version}")
    frontend.stop()
    if stalled is not None:
        print(f"[stream] STALL DETECTED: {stalled} — readers kept answering "
              f"from window_version={frontend.window_version}")
        raise SystemExit(4)
    if writer_fault:
        print(f"[stream] injected crash mid-append at slide "
              f"{args.kill_after}; storm kept answering from the last "
              f"published window — recover with --restore")
        raise SystemExit(3)
    if outcome["errors"]:
        raise SystemExit(f"[stream] query errors: {outcome['errors']}")
    if ck is not None:
        print(f"[stream] checkpoints durable in {args.checkpoint_dir}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="T10I4D100K",
                    choices=list(PAPER_DATASETS))
    ap.add_argument("--min-sup", type=float, default=0.01)
    ap.add_argument("--block-txns", type=int, default=512,
                    help="transactions per micro-batch block (multiple of 32)")
    ap.add_argument("--n-blocks", type=int, default=8,
                    help="window capacity in blocks")
    ap.add_argument("--batches", type=int, default=12,
                    help="how many micro-batches to stream")
    ap.add_argument("--drift-every", type=int, default=None,
                    help="re-seed the pattern pool every N batches")
    ap.add_argument("--backend", default="pallas",
                    choices=["jnp", "pallas", "sharded", "tidsharded", "grid",
                             "auto"],
                    help="engine backend; 'auto' picks from the measured "
                         "crossover table (BENCH_kerneltune.json, "
                         "DESIGN.md §6), falling back to pallas")
    ap.add_argument("--shard", default="pairs",
                    choices=["pairs", "words", "grid"],
                    help="mesh split under a device mesh: candidate pairs "
                         "(frontier replicated), the frontier's word axis, "
                         "or both on a 2D class x data grid (DESIGN.md §8)")
    ap.add_argument("--grid", default=None, metavar="RxC",
                    help="class x data mesh shape for --shard grid, e.g. 2x2 "
                         "(default: auto-factorize the visible devices)")
    ap.add_argument("--block-w", type=int, default=None, metavar="WORDS",
                    help="fused-kernel word-tile width override (default: "
                         "autotuned table / cost-model seed)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune-on-miss: measure untuned kernel shape classes "
                         "before dispatching them")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--min-conf", type=float, default=0.0,
                    help="if >0, also report association rules per slide")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="write an async miner snapshot (MinerState, "
                         "DESIGN.md §10) every --checkpoint-every slides")
    ap.add_argument("--checkpoint-every", type=int, default=1, metavar="N",
                    help="checkpoint cadence in slides (with --checkpoint-dir)")
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoints retained by GC (with --checkpoint-dir)")
    ap.add_argument("--restore", action="store_true",
                    help="resume from the newest checkpoint in "
                         "--checkpoint-dir: completed slides are skipped and "
                         "the deterministic stream replayed from there; the "
                         "checkpoint's recorded backend/shard/window config "
                         "is reused unless --remesh is given")
    ap.add_argument("--remesh", action="store_true",
                    help="with --restore: re-place the checkpointed state "
                         "under THIS invocation's --backend/--shard/--grid "
                         "and visible devices (live re-meshing) instead of "
                         "the recorded config")
    ap.add_argument("--kill-after", type=int, default=None, metavar="N",
                    help="fault injection (CI recovery smoke): crash "
                         "mid-append during slide N and exit with code 3; "
                         "recover with --restore")
    ap.add_argument("--serve", action="store_true",
                    help="run the slides on a writer thread behind the async "
                         "admission front end and storm it with queries "
                         "(DESIGN.md §11)")
    ap.add_argument("--serve-queries", type=int, default=120, metavar="N",
                    help="with --serve: query storm size")
    ap.add_argument("--serve-clients", type=int, default=4, metavar="N",
                    help="with --serve: concurrent client threads")
    ap.add_argument("--serve-policy", default="block",
                    choices=["block", "shed"],
                    help="with --serve: full-queue backpressure policy")
    ap.add_argument("--queue-cap", type=int, default=256, metavar="N",
                    help="with --serve: bounded admission queue capacity")
    ap.add_argument("--stall-timeout", type=float, default=5.0, metavar="S",
                    help="with --serve: writer heartbeat deadline (0 "
                         "disables); a stalled writer is reported, readers "
                         "keep answering from the last published window")
    args = ap.parse_args(argv)

    from .mesh import mesh_for_mining
    spec = stream_spec(args.dataset)
    start = 0
    if args.restore:
        if not args.checkpoint_dir:
            ap.error("--restore requires --checkpoint-dir")
        ck_cfg, done = peek_config(args.checkpoint_dir)
        if args.remesh:
            backend, shard, grid = args.backend, args.shard, args.grid
        else:
            backend, shard, grid = ck_cfg.backend, ck_cfg.shard, None
        mesh = mesh_for_mining(backend, shard, grid)
        miner, start = restore_miner(args.checkpoint_dir, mesh=mesh,
                                     backend=backend, shard=shard,
                                     keep_transactions=False)
        cfg = miner.config
        print(f"[stream] restored {args.checkpoint_dir} at slide {start} "
              f"({'re-meshed to ' if args.remesh else ''}backend={backend}, "
              f"shard={shard})")
    else:
        cfg = StreamConfig(min_sup=args.min_sup, n_blocks=args.n_blocks,
                           block_txns=args.block_txns, backend=args.backend,
                           shard=args.shard,
                           block_w=args.block_w, autotune=args.autotune)
        backend, shard = args.backend, args.shard
        mesh = mesh_for_mining(backend, shard, args.grid)
        miner = StreamingMiner(spec.n_items, cfg, mesh=mesh,
                               keep_transactions=False)
    service = StreamQueryService(miner)
    ck = (StreamCheckpointer(args.checkpoint_dir,
                             every=args.checkpoint_every, keep=args.keep)
          if args.checkpoint_dir else None)
    eff_shard = {"tidsharded": "words", "grid": "grid"}.get(backend, shard)
    if mesh is None:
        mesh_note = ""
    elif "class" in mesh.axis_names:
        mesh_note = (f", shard=grid over a {mesh.shape['class']}x"
                     f"{mesh.shape['data']} class x data mesh")
    else:
        mesh_note = f", shard={eff_shard} over {mesh.shape['data']} device(s)"
    print(f"[stream] {spec.name}: window={cfg.n_blocks}x{cfg.block_txns} "
          f"txns, min_sup={cfg.min_sup}, backend={backend}{mesh_note}")

    if args.serve:
        return _serve_mode(args, miner, cfg, ck, start)

    try:
        for i, batch in enumerate(transaction_stream(
                args.dataset, cfg.block_txns, args.batches,
                seed=args.seed, drift_every=args.drift_every)):
            if i < start:
                continue    # replayed deterministically; already in the state
            if args.kill_after is not None and i == args.kill_after:
                def _die(name):
                    if name == "miner:mid_append":
                        raise InjectedFault(name)
                set_kill_hook(_die)
            res = service.ingest(batch)
            cls = res.stats["classes"]
            print(f"[stream] slide {i:3d}: window={res.n_txn} txns "
                  f"({res.stats['window']['filled_blocks']}/{cfg.n_blocks} blocks) "
                  f"itemsets={res.total} "
                  f"classes={cls['n_active']} (+{cls['n_entered']}/-{cls['n_exited']}) "
                  f"latency={res.stats['slide_s']*1e3:.1f}ms")
            for iset, sup in service.top_k_itemsets(args.top_k, min_len=2):
                print(f"[stream]   top {iset} support={sup} ({sup/res.n_txn:.1%})")
            if args.min_conf > 0:
                rules = service.rules(args.min_conf, k=3)
                print(f"[stream]   {len(service.rules(args.min_conf))} rules at "
                      f"conf>={args.min_conf}; best: "
                      + "; ".join(f"{a}=>{c} conf={cf:.2f}" for a, c, cf, _ in rules))
            if ck is not None:
                ck.maybe_save(miner, i + 1)
    except InjectedFault:
        if ck is not None:
            ck.wait()
        print(f"[stream] injected crash mid-append at slide "
              f"{args.kill_after}; last durable checkpoint survives — "
              f"recover with --restore")
        raise SystemExit(3)
    finally:
        clear_kill_hook()
    if ck is not None:
        ck.wait()
        print(f"[stream] checkpoints durable in {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
