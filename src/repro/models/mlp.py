"""Dense MLP blocks: SwiGLU / GeGLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import constrain, dp_axes


def init_mlp(key, cfg, dtype, stacked: int = 0) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    shp = (lambda *s: (stacked, *s)) if stacked else (lambda *s: s)
    pre = "stk_" if stacked else ""
    p = {}
    if cfg.mlp_act in ("swiglu", "geglu"):
        p[pre + "w_gate"] = jax.random.normal(ks[0], shp(d, f), dtype) * d ** -0.5
    p[pre + "w_up"] = jax.random.normal(ks[1], shp(d, f), dtype) * d ** -0.5
    p[pre + "w_down"] = jax.random.normal(ks[2], shp(f, d), dtype) * f ** -0.5
    return p


def mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    act = cfg.mlp_act
    up = x @ p["w_up"]
    if getattr(cfg, "mlp_dp", False) and up.ndim == 3 and up.shape[1] > 1:
        # mlp_dp: FFN weights replicated over 'model'; activations stay
        # sequence-sharded -> the whole FFN is collective-free in fwd/bwd-dx
        up = constrain(up, P(dp_axes(), "model", None))
    else:
        up = constrain(up, P(dp_axes(), None, "model"))
    if act == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"])
        hidden = gate * up
    elif act == "geglu":
        gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
        hidden = gate * up
    else:
        hidden = jax.nn.gelu(up, approximate=True)
    return hidden @ p["w_down"]
