"""Model assembly: stage compiler, forward passes, prefill/decode, loss.

Stage compiler: the per-layer kind list (``ModelConfig.layer_pattern``) is
run-length grouped into *stages*; each stage's layers are stacked along a
leading axis and executed with one ``lax.scan``, so a 64-layer model lowers
to a handful of compact while-loops instead of 64 inlined layer bodies —
essential for compile time and HLO size at 512 devices.  Heterogeneous
patterns (gemma3's 5:1 local:global, xlstm's 7:1 mLSTM:sLSTM) simply produce
more stages.

Cross-entropy is computed in sequence chunks against the (possibly
vocab-sharded) embedding so the (B, S, V) logits tensor never materializes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..dist.sharding import constrain, dp_axes
from .attention import attention, init_attention
from .layers import init_norm, norm
from .mlp import init_mlp, mlp
from .moe import expert_placement, init_moe, moe
from .ssm import init_ssm, init_ssm_state, ssm_block
from .xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                    init_slstm_state, mlstm_block, slstm_block)

__all__ = ["stages_meta", "Model"]

LOSS_CHUNK = 512


def stages_meta(cfg) -> List[Tuple[str, int]]:
    """Run-length encode the layer pattern into (kind, count) stages."""
    pattern = cfg.layer_pattern()
    stages: List[Tuple[str, int]] = []
    for kind in pattern:
        if stages and stages[-1][0] == kind:
            stages[-1] = (kind, stages[-1][1] + 1)
        else:
            stages.append((kind, 1))
    return stages


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer_stack(key, cfg, kind: str, count: int) -> Dict[str, jax.Array]:
    dtype = _dtype(cfg)
    p: Dict[str, jax.Array] = {}
    ks = iter(jax.random.split(key, 8))

    def add_norm(name):
        n = init_norm(cfg.d_model, cfg.norm, dtype)
        for k, v in n.items():
            p[f"stk_{name}_{k}"] = jnp.broadcast_to(v[None], (count, *v.shape))

    base = kind.split("+")[0]
    if base in ("attn", "local"):
        add_norm("norm1")
        p.update(init_attention(next(ks), cfg, dtype, stacked=count))
        add_norm("norm2")
    elif base == "hybrid":
        add_norm("norm1")
        p.update(init_attention(next(ks), cfg, dtype, stacked=count))
        p.update(init_ssm(next(ks), cfg, dtype, stacked=count))
        add_norm("norm2")
    elif base == "mlstm":
        add_norm("norm1")
        p.update(init_mlstm(next(ks), cfg, dtype, stacked=count))
    elif base == "slstm":
        add_norm("norm1")
        p.update(init_slstm(next(ks), cfg, dtype, stacked=count))
    elif base == "xdec":  # whisper decoder layer: self + cross + mlp
        add_norm("norm1")
        p.update(init_attention(next(ks), cfg, dtype, stacked=count))
        add_norm("normx")
        p.update(init_attention(next(ks), cfg, dtype, stacked=count, cross=True))
        add_norm("norm2")
    elif base == "enc":   # whisper encoder layer: bidir self + mlp
        add_norm("norm1")
        p.update(init_attention(next(ks), cfg, dtype, stacked=count))
        add_norm("norm2")
    else:
        raise ValueError(f"unknown layer kind {kind}")

    if kind.endswith("+moe"):
        p.update(init_moe(next(ks), cfg, dtype, stacked=count))
    elif base in ("attn", "local", "hybrid", "xdec", "enc") and cfg.mlp_act != "none":
        p.update(init_mlp(next(ks), cfg, dtype, stacked=count))
    return p


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    meta = stages_meta(cfg)
    ks = jax.random.split(key, len(meta) + 4)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), dtype) * 0.02,
        "stages": {f"s{i}": _init_layer_stack(ks[i + 1], cfg, kind, count)
                   for i, (kind, count) in enumerate(meta)},
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            ks[len(meta) + 1], (cfg.d_model, cfg.vocab_size), dtype) * cfg.d_model ** -0.5
    if cfg.n_encoder_layers:
        params["enc_stages"] = {
            "e0": _init_layer_stack(ks[len(meta) + 2], cfg, "enc", cfg.n_encoder_layers)
        }
        params["enc_final_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
        params["enc_pos"] = jax.random.normal(
            ks[len(meta) + 3], (cfg.encoder_len, cfg.d_model), dtype) * 0.02
    return params


# ---------------------------------------------------------------------------
# stage execution
# ---------------------------------------------------------------------------

def _slice_params(stacked: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Strip the stk_ prefix from scan-sliced leaves."""
    return {k[4:]: v for k, v in stacked.items()}


def _layer_forward(lp: Dict[str, jax.Array], x, cfg, kind: str, *,
                   cache=None, pos=None, enc_out=None, placement=None):
    """One layer.  Returns (x, new_cache, aux_loss)."""
    base = kind.split("+")[0]
    use_moe = kind.endswith("+moe")
    aux_loss = jnp.zeros((), jnp.float32)
    new_cache = None

    def n1(v):
        return norm({k.split("_", 1)[1]: lp[k] for k in lp if k.startswith("norm1")}, v, cfg.norm)

    def n2(v):
        return norm({k.split("_", 1)[1]: lp[k] for k in lp if k.startswith("norm2")}, v, cfg.norm)

    sp = getattr(cfg, "seq_parallel", False)

    def rs(v):
        # Megatron-SP: pin the post-matmul partial-sum reduction at the block
        # output, in the matmul's own (bf16) dtype, as a reduce-scatter onto
        # the sequence-sharded residual — before the fp32 norm region can
        # absorb (and upcast) the collective.
        if sp and v.ndim == 3 and v.shape[1] > 1:
            return constrain(v, P(dp_axes(), "model", None))
        return v

    if base in ("attn", "local"):
        window = cfg.window if base == "local" else 0
        h = n1(x)
        attn_out, kv_cache = attention(lp, h, cfg, window=window, cache=cache, pos=pos)
        attn_out = rs(attn_out)
        if cfg.parallel_block:
            ff_in = h
        else:
            x = x + attn_out
            ff_in = n2(x)
        if use_moe:
            ff_out, aux = moe(lp, ff_in, cfg, placement=placement)
            aux_loss = aux["aux_loss"]
        elif cfg.mlp_act != "none":
            ff_out = mlp(lp, ff_in, cfg)
        else:
            ff_out = jnp.zeros_like(x)
        ff_out = rs(ff_out)
        x = x + attn_out + ff_out if cfg.parallel_block else x + ff_out
        new_cache = kv_cache
    elif base == "hybrid":
        h = n1(x)
        attn_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
        ssm_state = None if cache is None else {"h": cache["h"], "conv": cache["conv"]}
        attn_out, kv_cache = attention(lp, h, cfg, window=cfg.window, cache=attn_cache, pos=pos)
        ssm_out, ssm_new = ssm_block(lp, h, cfg, state=ssm_state)
        x = x + 0.5 * (attn_out + ssm_out)
        x = x + mlp(lp, n2(x), cfg)
        new_cache = None if cache is None else {**kv_cache, **ssm_new}
    elif base == "mlstm":
        out, st = mlstm_block(lp, n1(x), cfg, state=cache)
        x = x + out
        new_cache = st if cache is not None else None
    elif base == "slstm":
        out, st = slstm_block(lp, n1(x), cfg, state=cache)
        x = x + out
        new_cache = st if cache is not None else None
    elif base == "enc":
        h = n1(x)
        attn_out, _ = attention(lp, h, cfg, causal=False)
        x = x + attn_out
        x = x + mlp(lp, n2(x), cfg)
    elif base == "xdec":
        h = n1(x)
        attn_out, kv_cache = attention(lp, h, cfg, cache=cache, pos=pos)
        x = x + attn_out
        nx = norm({k.split("_", 1)[1]: lp[k] for k in lp if k.startswith("normx")}, x, cfg.norm)
        xk, xv = enc_out
        cross_out, _ = attention(lp, nx, cfg, cross_kv=(xk, xv), prefix="x")
        x = x + cross_out
        x = x + mlp(lp, n2(x), cfg)
        new_cache = kv_cache
    else:
        raise ValueError(kind)
    return x, new_cache, aux_loss


def run_stage(stage_params, x, cfg, kind: str, *, cache=None, pos=None,
              enc_out=None, placement=None, remat: str = "none"):
    """scan the stacked layers of one stage.  Returns (x, new_cache, aux)."""

    def body(carry, xs):
        h = carry
        lp = _slice_params(xs["p"])
        c = xs.get("c")
        e = xs.get("e")
        h, new_c, aux = _layer_forward(lp, h, cfg, kind, cache=c, pos=pos,
                                       enc_out=e, placement=placement)
        if getattr(cfg, "seq_parallel", False) and h.shape[1] > 1:
            # Megatron-SP: keep the residual stream sequence-sharded over
            # 'model' between blocks — post-matmul partial sums become
            # reduce-scatters and the fp32 norm region stays shard-local.
            h = constrain(h, P(dp_axes(), "model", None))
        outs = {"aux": aux}
        if new_c is not None:
            outs["c"] = new_c
        return h, outs

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = {"p": stage_params}
    if cache is not None:
        xs["c"] = cache
    if enc_out is not None:
        xs["e"] = enc_out  # per-layer cross K/V, leading dim == stage count
    from .costing import unroll_stages
    if unroll_stages():
        # calibration path: python loop so HloCostAnalysis sees every layer
        count = jax.tree.leaves(stage_params)[0].shape[0]
        outs_list = []
        for i in range(count):
            xi = jax.tree.map(lambda a: jax.lax.index_in_dim(
                a, i, axis=0, keepdims=False), xs)
            x, out_i = body(x, xi)
            outs_list.append(out_i)
        outs = jax.tree.map(lambda *ys: jnp.stack(ys), *outs_list)
    else:
        x, outs = jax.lax.scan(body, x, xs)
    new_cache = outs.get("c")
    return x, new_cache, outs["aux"].sum()


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    """Functional model handle for one architecture config."""

    cfg: Any

    # ---- embedding ----
    def embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.scale_embed:
            x = x * jnp.asarray(self.cfg.d_model ** 0.5, x.dtype)
        if getattr(self.cfg, "seq_parallel", False) and x.shape[1] > 1:
            return constrain(x, P(dp_axes(), "model", None))
        return constrain(x, P(dp_axes(), None, None))

    def unembed_chunked(self, params, h, targets, mask):
        """Chunked softmax cross-entropy; never materializes (B, S, V).

        h: (B, S, D); targets/mask: (B, S).  Returns (sum_loss, sum_mask).
        """
        cfg = self.cfg
        w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        b, s, d = h.shape
        from .costing import cost_mode
        c = s if cost_mode() else min(LOSS_CHUNK, s)
        pad = (-s) % c
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nc = h.shape[1] // c
        hs = h.reshape(b, nc, c, d).swapaxes(0, 1)
        ts = targets.reshape(b, nc, c).swapaxes(0, 1)
        ms = mask.reshape(b, nc, c).swapaxes(0, 1)

        def chunk(carry, xs):
            hc, tc, mc = xs
            logits = (hc.astype(jnp.float32) @
                      (w.T if cfg.tie_embeddings else w).astype(jnp.float32))
            logits = constrain(logits, P(dp_axes(), None, "model"))
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
            nll = (lse - gold) * mc
            return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

        (loss_sum, mask_sum), _ = jax.lax.scan(
            chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hs, ts, ms))
        return loss_sum, mask_sum

    def logits_last(self, params, h):
        cfg = self.cfg
        w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        out = h[:, -1:].astype(jnp.float32) @ (w.T if cfg.tie_embeddings else w).astype(jnp.float32)
        return constrain(out, P(dp_axes(), None, "model"))

    # ---- encoder (whisper) ----
    def encode(self, params, enc_embeds):
        cfg = self.cfg
        x = enc_embeds + params["enc_pos"][None, : enc_embeds.shape[1]]
        x, _, _ = run_stage(params["enc_stages"]["e0"], x, cfg, "enc")
        return norm(params["enc_final_norm"], x, cfg.norm)

    def cross_kv(self, params, enc_out):
        """Precompute per-decoder-layer cross K/V from encoder output."""
        cfg = self.cfg
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        b, se, _ = enc_out.shape
        out = {}
        for sname, sp in params["stages"].items():
            xk = jnp.einsum("bsd,ldk->lbsk", enc_out, sp["stk_xwk"])
            xv = jnp.einsum("bsd,ldk->lbsk", enc_out, sp["stk_xwv"])
            out[sname] = (xk.reshape(*xk.shape[:3], kv, hd),
                          xv.reshape(*xv.shape[:3], kv, hd))
        return out

    # ---- full forward over the decoder stack ----
    def backbone(self, params, x, *, cache=None, pos=None, enc_out=None,
                 remat="none"):
        cfg = self.cfg
        meta = stages_meta(cfg)
        placement = None
        if cfg.n_experts and cfg.expert_placement != "default":
            placement = jnp.asarray(expert_placement(cfg))
        aux_total = jnp.zeros((), jnp.float32)
        new_cache = {} if cache is not None else None
        xkv = None
        for i, (kind, count) in enumerate(meta):
            sname = f"s{i}"
            st_cache = cache.get(sname) if cache is not None else None
            if kind.startswith("xdec") and enc_out is not None:
                xkv = enc_out[sname] if isinstance(enc_out, dict) else enc_out
            x, st_new, aux = run_stage(
                params["stages"][sname], x, cfg, kind, cache=st_cache, pos=pos,
                enc_out=xkv, placement=placement, remat=remat)
            aux_total += aux
            if new_cache is not None:
                new_cache[sname] = st_new
        x = norm(params["final_norm"], x, cfg.norm)
        return x, new_cache, aux_total

    # ---- task heads ----
    def loss(self, params, batch, remat="none"):
        """Next-token loss.  batch: tokens (B, S) [+ enc_embeds / img_embeds]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        enc = None
        if cfg.n_encoder_layers:
            enc_out = self.encode(params, batch["enc_embeds"])
            enc = self.cross_kv(params, enc_out)
        if cfg.frontend == "vision" and "img_embeds" in batch:
            # early-fusion stub: image patch embeddings prefix the text
            fl = batch["img_embeds"].shape[1]
            img = batch["img_embeds"].astype(_dtype(cfg))
            text = tokens[:, : tokens.shape[1] - fl]
            x = jnp.concatenate([img, self.embed(params, text)], axis=1)
            # position i predicts full-sequence id at i+1; image positions
            # (except the last, which predicts the first text token) masked
            targets = jnp.concatenate(
                [jnp.zeros((tokens.shape[0], fl - 1), tokens.dtype),
                 text, text[:, -1:]], axis=1)
            mask = jnp.ones_like(targets, jnp.float32)
            mask = mask.at[:, : fl - 1].set(0.0)
        else:
            x = self.embed(params, tokens)
            targets = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
            mask = jnp.ones_like(targets, jnp.float32)
        mask = mask.at[:, -1].set(0.0)
        h, _, aux = self.backbone(params, x, enc_out=enc, remat=remat)
        loss_sum, mask_sum = self.unembed_chunked(params, h, targets, mask)
        return loss_sum / jnp.maximum(mask_sum, 1.0) + 0.01 * aux

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16):
        """Per-stage decode cache pytree."""
        cfg = self.cfg
        cache = {}
        for i, (kind, count) in enumerate(stages_meta(cfg)):
            base = kind.split("+")[0]
            if base in ("attn", "local", "xdec"):
                from .attention import init_cache as kv_init
                cache[f"s{i}"] = kv_init(cfg, batch, s_max, count, dtype)
            elif base == "hybrid":
                from .attention import init_cache as kv_init
                c = kv_init(cfg, batch, s_max, count, dtype)
                c.update(init_ssm_state(cfg, batch, count))
                cache[f"s{i}"] = c
            elif base == "mlstm":
                cache[f"s{i}"] = init_mlstm_state(cfg, batch, count)
            elif base == "slstm":
                cache[f"s{i}"] = init_slstm_state(cfg, batch, count)
        return cache

    def prefill(self, params, batch, s_max: int):
        """Encode a full prompt, returning (last-token logits, filled cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self.embed(params, tokens)
        enc = None
        if cfg.n_encoder_layers:
            enc_out = self.encode(params, batch["enc_embeds"])
            enc = self.cross_kv(params, enc_out)
        cache = self.init_cache(b, s_max, _dtype(cfg))
        pos = jnp.zeros((b,), jnp.int32)
        h, cache, _ = self.backbone(params, x, cache=cache, pos=pos, enc_out=enc)
        return self.logits_last(params, h), cache

    def decode_step(self, params, token, cache, pos, enc_out=None):
        """One token step.  token: (B, 1); pos: (B,) current write index."""
        cfg = self.cfg
        x = self.embed(params, token)
        enc = None
        if cfg.n_encoder_layers and enc_out is not None:
            enc = enc_out
        h, cache, _ = self.backbone(params, x, cache=cache, pos=pos, enc_out=enc)
        return self.logits_last(params, h), cache
