"""Mamba-style selective SSM block (hymba's parallel-head SSM branch).

Chunked scan: the sequence is processed in fixed chunks with an associative
scan inside each chunk and a sequential carry between chunks, so the largest
intermediate is (B, chunk, D_in, N) rather than (B, S, D_in, N) — the
memory-hierarchy adaptation that replaces the CUDA selective-scan kernel on
TPU (DESIGN.md §2: recompute-friendly, remat composes over chunks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import constrain, dp_axes

CHUNK = 128


def init_ssm(key, cfg, dtype, stacked: int = 0, prefix: str = "") -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    shp = (lambda *s: (stacked, *s)) if stacked else (lambda *s: s)
    pre = ("stk_" if stacked else "") + prefix
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, n)))
    if stacked:
        a_init = jnp.broadcast_to(a_init[None], (stacked, din, n))
    return {
        pre + "ssm_in_proj": jax.random.normal(ks[0], shp(d, 2 * din), dtype) * d ** -0.5,
        pre + "ssm_bc_proj": jax.random.normal(ks[1], shp(din, 2 * n + 1), dtype) * din ** -0.5,
        pre + "ssm_conv": jax.random.normal(ks[2], shp(cfg.ssm_conv, din), dtype) * 0.3,
        pre + "ssm_a_log": a_init,
        pre + "ssm_d": jnp.ones(shp(din), jnp.float32),
        pre + "ssm_out_proj": jax.random.normal(ks[5], shp(din, d), dtype) * din ** -0.5,
    }


def _causal_conv(x, w, state=None):
    """x: (B, S, Din); w: (K, Din) depthwise causal conv.

    state: (B, K-1, Din) trailing inputs from the previous step (decode).
    Returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, Din)
    y = sum(xp[:, i: i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, new_state


def ssm_block(p: dict, x: jax.Array, cfg, *, state: dict | None = None,
              prefix: str = ""):
    """x: (B, S, D) -> (B, S, D).  state={"h": (B, Din, N), "conv": (B, K-1, Din)}
    enables stateful decode; returns (out, new_state)."""
    b, s, d = x.shape
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    g = lambda name: p[prefix + name]
    dp = dp_axes()

    xz = x @ g("ssm_in_proj")                          # (B, S, 2*Din)
    xz = constrain(xz, P(dp, None, "model"))
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(xs, g("ssm_conv"), conv_state)
    xs = jax.nn.silu(xs)

    bcd = xs @ g("ssm_bc_proj")                        # (B, S, 2N+1)
    b_t = bcd[..., :n].astype(jnp.float32)             # (B, S, N)
    c_t = bcd[..., n: 2 * n].astype(jnp.float32)
    dt = jax.nn.softplus(bcd[..., -1:].astype(jnp.float32))  # (B, S, 1)
    a = -jnp.exp(g("ssm_a_log"))                       # (Din, N)

    decay = jnp.exp(dt[..., None] * a[None, None])     # (B, S, Din, N)
    drive = (dt * xs.astype(jnp.float32))[..., None] * b_t[:, :, None, :]  # (B,S,Din,N)

    h0 = state["h"].astype(jnp.float32) if state is not None else jnp.zeros((b, din, n), jnp.float32)

    if s == 1:
        h = decay[:, 0] * h0 + drive[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None]
        h_last = h
    else:
        from .costing import cost_mode
        chunk = s if cost_mode() else min(CHUNK, s)
        pad = (-s) % chunk
        if pad:
            decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
            drive = jnp.pad(drive, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
        sp = decay.shape[1]
        nc = sp // chunk
        decay_c = decay.reshape(b, nc, chunk, din, n).transpose(1, 0, 2, 3, 4)
        drive_c = drive.reshape(b, nc, chunk, din, n).transpose(1, 0, 2, 3, 4)
        ct_c = c_t.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

        def chunk_step(h_in, xs_c):
            dc, dr, cc = xs_c                           # (B, chunk, Din, N)
            def combine(l, r):
                return (l[0] * r[0], r[0] * l[1] + r[1])
            dec_cum, drv_cum = jax.lax.associative_scan(combine, (dc, dr), axis=1)
            h_all = dec_cum * h_in[:, None] + drv_cum   # (B, chunk, Din, N)
            y_c = jnp.einsum("bcdn,bcn->bcd", h_all, cc)
            return h_all[:, -1], y_c

        h_last, y_chunks = jax.lax.scan(chunk_step, h0, (decay_c, drive_c, ct_c))
        y = y_chunks.transpose(1, 0, 2, 3).reshape(b, sp, din)[:, :s]

    y = y.astype(x.dtype) + xs * g("ssm_d").astype(x.dtype)[None, None]
    y = y * jax.nn.silu(z)
    out = y @ g("ssm_out_proj")
    new_state = {"h": h_last.astype(jnp.float32), "conv": new_conv}
    return out, new_state


def init_ssm_state(cfg, batch: int, n_layers: int) -> dict:
    din = cfg.ssm_expand * cfg.d_model
    return {
        "h": jnp.zeros((n_layers, batch, din, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, din), jnp.float32),
    }
