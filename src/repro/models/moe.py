"""Mixture-of-Experts with sort-based dispatch and Eclat-style placement.

Two dispatch strategies (``cfg.moe_dispatch``):

local (default, production)
    Tokens are grouped by data shard; top-k routing, the expert-id sort and
    the capacity scatter are all *shard-local* (batched over the group axis,
    so GSPMD keeps them collective-free).  The capacity buffer is then
    constrained from group-sharded to expert-sharded — exactly one
    all-to-all — batch-GEMMed against the stacked expert weights (d_ff
    tensor-parallel over 'model'), constrained back, and combined locally.

global (recorded baseline, §Perf)
    One flat argsort over every routed token; GSPMD turns the global sort
    into a distributed sort — the measured collective catastrophe the §Perf
    log starts from (llama4 train: 98.7 s collective term).

Expert -> device placement reuses the paper's equivalence-class partitioners
(``repro.core.partitioners``): balancing routed load over EP shards is the
same irregular-work-unit assignment the paper solves for equivalence
classes; ``expert_placement="greedy"`` permutes expert ids so heavy experts
spread across the EP axis (benchmarks/moe_balance).  Capacity overflow drops
tokens (weight 0) — the padding-efficiency knob the paper's balance metric
measures.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..dist.sharding import constrain, dp_axes, get_mesh


def init_moe(key, cfg, dtype, stacked: int = 0) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    split = getattr(cfg, "expert_split", 1)
    slots, fs = e * split, f // split   # expert fission (see module docstring)
    ks = jax.random.split(key, 4)
    shp = (lambda *s: (stacked, *s)) if stacked else (lambda *s: s)
    pre = "stk_" if stacked else ""
    p = {
        pre + "router": jax.random.normal(ks[0], shp(d, e), jnp.float32) * d ** -0.5,
        pre + "experts_up": jax.random.normal(ks[2], shp(slots, d, fs), dtype) * d ** -0.5,
        pre + "experts_down": jax.random.normal(ks[3], shp(slots, fs, d), dtype) * f ** -0.5,
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        p[pre + "experts_gate"] = jax.random.normal(ks[1], shp(slots, d, fs), dtype) * d ** -0.5
    return p


def expert_placement(cfg, load_estimate: Optional[np.ndarray] = None) -> np.ndarray:
    """Static expert-id permutation balancing load across the EP axis
    (greedy-LPT from repro.core.partitioners; see module docstring)."""
    e = cfg.n_experts
    if cfg.expert_placement == "default" or e == 0:
        return np.arange(e, dtype=np.int32)
    from ..core.partitioners import greedy_partitioner

    load = load_estimate if load_estimate is not None else np.ones(e)
    shards = 16 if e % 16 == 0 else max(1, e // 8)
    assign = greedy_partitioner(np.arange(e), shards, work=np.asarray(load, np.float64))
    perm = np.argsort(assign, kind="stable").astype(np.int32)
    return perm


def _n_groups(cfg, tokens: int) -> int:
    mesh = get_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in dp_axes(mesh):
        g *= mesh.shape[a]
    while g > 1 and tokens % g:
        g //= 2
    return max(g, 1)


def _dispatch_one_group(xf, probs, k, e, cap, placement, split: int = 1):
    """Single group (no leading axis): returns buffers + combine metadata."""
    tg, d = xf.shape
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                # (Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    if placement is not None:
        expert_ids = placement[expert_ids]
    if split > 1:
        # expert fission: expert -> its `split` slots, same gate weight each
        expert_ids = (expert_ids[..., None] * split +
                      jnp.arange(split)).reshape(tg, k * split)
        gate_vals = jnp.repeat(gate_vals, split, axis=-1)
        k = k * split
    n_slots = e * split
    flat_e = expert_ids.reshape(tg * k)
    flat_g = gate_vals.reshape(tg * k)
    flat_t = jnp.repeat(jnp.arange(tg), k)
    order = jnp.argsort(flat_e)                                     # local sort
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    pos = jnp.arange(tg * k)
    seg_start = jnp.full((n_slots,), tg * k, jnp.int32).at[se].min(
        pos.astype(jnp.int32), mode="drop")
    pos_in_e = pos.astype(jnp.int32) - seg_start[se]
    keep = pos_in_e < cap
    dest = se * cap + jnp.minimum(pos_in_e, cap - 1)
    buf = jnp.zeros((n_slots * cap, d), xf.dtype).at[dest].add(
        jnp.where(keep[:, None], xf[st], 0), mode="drop")
    return buf.reshape(n_slots, cap, d), (dest, st, sg, keep)


def _combine_one_group(out_buf, meta, tg, d):
    dest, st, sg, keep = meta
    gathered = out_buf.reshape(-1, out_buf.shape[-1])[dest]
    weighted = gathered.astype(jnp.float32) * jnp.where(keep, sg, 0.0)[:, None]
    return jnp.zeros((tg, d), jnp.float32).at[st].add(weighted, mode="drop")


def moe(p: dict, x: jax.Array, cfg, placement: Optional[jax.Array] = None):
    """x: (B, S, D) -> (B, S, D), plus aux dict (load stats, router loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    dp = dp_axes()
    e_ax = dp[-1] if cfg.expert_sharding in ("ep", "ep_pad") else None
    f_ax = "model" if cfg.expert_sharding in ("ep", "ep_pad") else tuple(list(dp) + ["model"])

    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                        # (T, E)

    # Switch-style load-balance loss + stats (global)
    me = probs.mean(0)
    top1 = jnp.argmax(probs, axis=-1)
    ce = jnp.zeros((e,), jnp.float32).at[top1].add(1.0) / t
    aux_loss = e * jnp.sum(me * ce)

    # expert fission: token -> all `split` half-d_ff slots of its expert;
    # slot outputs sum in the combine (exact: gated FFNs are elementwise in f)
    split = getattr(cfg, "expert_split", 1)
    slots = e * split

    if cfg.moe_dispatch == "global":
        out, dropped = _moe_global(p, xf, probs, cfg, placement, e_ax, f_ax)
    else:
        g = _n_groups(cfg, t)
        tg = t // g
        cap = int(np.ceil(tg * k / e * cfg.capacity_factor))
        xg = constrain(xf.reshape(g, tg, d), P(dp, None, None))
        pg = probs.reshape(g, tg, e)
        bufs, meta = jax.vmap(
            lambda xx, pp: _dispatch_one_group(xx, pp, k, e, cap, placement,
                                               split=split)
        )(xg, pg)                                                   # (G, slots, C, D)
        # ONE all-to-all: group-sharded -> expert-sharded
        bufs = constrain(bufs, P(None, e_ax, None, None))
        up = jnp.einsum("gecd,edf->gecf", bufs, p["experts_up"])
        up = constrain(up, P(None, e_ax, None, "model" if f_ax == "model" else None))
        if cfg.mlp_act in ("swiglu", "geglu"):
            gate = jnp.einsum("gecd,edf->gecf", bufs, p["experts_gate"])
            act = jax.nn.silu(gate) if cfg.mlp_act == "swiglu" else \
                jax.nn.gelu(gate, approximate=True)
            hidden = act * up
        else:
            hidden = jax.nn.gelu(up, approximate=True)
        out_buf = jnp.einsum("gecf,efd->gecd", hidden, p["experts_down"])
        # all-to-all back: expert-sharded -> group-sharded
        out_buf = constrain(out_buf, P(dp, None, None, None))
        out = jax.vmap(
            lambda ob, de, st_, sg_, kp: _combine_one_group(ob, (de, st_, sg_, kp), tg, d)
        )(out_buf, *meta)
        out = out.reshape(t, d)
        dropped = 1.0 - jnp.mean(meta[3].astype(jnp.float32))

    out = constrain(out.reshape(b, s, d).astype(x.dtype), P(dp, None, None))
    aux = {"aux_loss": aux_loss, "expert_load": ce, "dropped_frac": dropped}
    return out, aux


def _moe_global(p, xf, probs, cfg, placement, e_ax, f_ax):
    """Naive flat dispatch (the §Perf baseline): one global argsort."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    if placement is not None:
        expert_ids = placement[expert_ids]
    flat_e = expert_ids.reshape(t * k)
    flat_g = gate_vals.reshape(t * k)
    flat_t = jnp.repeat(jnp.arange(t), k)
    cap = int(np.ceil(t * k / e * cfg.capacity_factor))
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    pos = jnp.arange(t * k, dtype=jnp.int32)
    seg_start = jnp.full((e,), t * k, jnp.int32).at[se].min(pos, mode="drop")
    pos_in_e = pos - seg_start[se]
    keep = pos_in_e < cap
    dest = se * cap + jnp.minimum(pos_in_e, cap - 1)
    buf = jnp.zeros((e * cap, d), xf.dtype).at[dest].add(
        jnp.where(keep[:, None], xf[st], 0).astype(xf.dtype), mode="drop")
    buf = buf.reshape(e, cap, d)
    buf = constrain(buf, P(e_ax, None, None))
    up = jnp.einsum("ecd,edf->ecf", buf, p["experts_up"])
    if cfg.mlp_act in ("swiglu", "geglu"):
        gate = jnp.einsum("ecd,edf->ecf", buf, p["experts_gate"])
        act = jax.nn.silu(gate) if cfg.mlp_act == "swiglu" else \
            jax.nn.gelu(gate, approximate=True)
        hidden = act * up
    else:
        hidden = jax.nn.gelu(up, approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, p["experts_down"]).reshape(e * cap, d)
    gathered = out_buf[dest]
    weighted = gathered.astype(jnp.float32) * jnp.where(keep, sg, 0.0)[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[st].add(weighted)
    return out, 1.0 - jnp.mean(keep.astype(jnp.float32))
