"""Attention: GQA/MQA, global / sliding-window, train/prefill + decode paths.

Full-sequence attention runs through a memory-bounded chunked online-softmax
(q-chunks outer scan, k-chunks inner scan) so the 32k prefill never
materializes an (S, S) score matrix — the pure-XLA equivalent of the
``repro.kernels.flash_attention`` Pallas kernel, which ``ops.py`` dispatches
to on real TPU.  Decode attends one query against the KV cache in grouped
(B, KV, G, S) form so GQA never repeats KV in memory, and a sequence-sharded
cache reduces over the 'model' axis (GSPMD inserts the all-reduce).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import constrain, dp_axes
from .layers import apply_rope, rope, softcap

NEG_INF = -1e30


def init_attention(key, cfg, dtype, stacked: int = 0, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    shp = (lambda *s: (stacked, *s)) if stacked else (lambda *s: s)
    pre = "stk_" if stacked else ""
    scale = d ** -0.5
    p = {
        pre + ("xwq" if cross else "wq"): jax.random.normal(ks[0], shp(d, h * hd), dtype) * scale,
        pre + ("xwk" if cross else "wk"): jax.random.normal(ks[1], shp(d, kv * hd), dtype) * scale,
        pre + ("xwv" if cross else "wv"): jax.random.normal(ks[2], shp(d, kv * hd), dtype) * scale,
        pre + ("xwo" if cross else "wo"): jax.random.normal(ks[3], shp(h * hd, d), dtype) * (h * hd) ** -0.5,
    }
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def flash_chunked(q, k, v, *, causal: bool, window: int, sm_scale: float,
                  softcap_val: float = 0.0, q_chunk: int = 1024, k_chunk: int = 1024):
    """(B, S, H, D) x (B, S, KV, D)^2 -> (B, S, H, D); online softmax, fp32 accum.

    Never materializes more than (B, H, q_chunk, k_chunk) scores.
    """
    from .costing import cost_mode
    b, s, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    if cost_mode():
        q_chunk = k_chunk = max(s, sk)
    qc = min(q_chunk, s)
    kc = min(k_chunk, sk)
    pad_q = (-s) % qc
    pad_k = (-sk) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sp = q.shape[1]
    skp = k.shape[1]
    nq, nk = sp // qc, skp // kc
    # (B, KV, G, nq, qc, D) grouped query blocks
    qg = q.reshape(b, nq, qc, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    kg = k.reshape(b, nk, kc, kvh, d).transpose(1, 0, 3, 2, 4)
    vg = v.reshape(b, nk, kc, kvh, d).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk  # qblk: (B, KV, G, qc, D)
        rows = qi * qc + jnp.arange(qc)

        def k_step(carry, ki_kv):
            m_prev, l_prev, acc = carry
            ki, kblk, vblk = ki_kv  # (B, KV, kc, D)
            scores = jnp.einsum(
                "bkgqd,bkcd->bkgqc", qblk.astype(jnp.float32),
                kblk.astype(jnp.float32)) * sm_scale
            if softcap_val:
                scores = softcap(scores, softcap_val)
            cols = ki * kc + jnp.arange(kc)
            mask = (cols[None, :] < sk)
            if causal:
                mask = mask & (cols[None, :] <= rows[:, None])
            if window:
                mask = mask & (cols[None, :] > rows[:, None] - window)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m_prev, scores.max(-1))
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqc,bkcd->bkgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, qc), jnp.float32),
            jnp.zeros((b, kvh, g, qc, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(k_step, init, (jnp.arange(nk), kg, vg))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # outs: (nq, B, KV, G, qc, D) -> (B, S, H, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sp, h, d)
    return out[:, :s]


def attention(p: dict, x: jax.Array, cfg, *, window: int = 0,
              cache: Optional[dict] = None, pos: Optional[jax.Array] = None,
              cross_kv: Optional[tuple] = None, causal: bool = True,
              prefix: str = ""):
    """Unified attention layer.

    cache: {"k": (B, S_max, KV, D), "v": ..., } with ``pos`` the current
    decode position -> returns (out, new_cache).  Without cache: full-seq.
    cross_kv: precomputed (k, v) for encoder-decoder cross attention.
    """
    b, s, d_model = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dp = dp_axes()
    wq, wk, wv, wo = (p[prefix + "wq"], p[prefix + "wk"], p[prefix + "wv"], p[prefix + "wo"])
    if wq.ndim == 3:  # stacked leaf sliced by scan — shouldn't happen here
        raise ValueError("stacked params must be sliced before attention()")

    q = _split_heads(x @ wq, h, hd)
    if cross_kv is None:
        k = _split_heads(x @ wk, kv, hd)
        v = _split_heads(x @ wv, kv, hd)
        if cfg.rope_theta:
            if pos is None:
                positions = jnp.arange(s)
                cos, sin = rope(positions, hd, cfg.rope_theta)
            else:
                positions = pos[:, None] + jnp.arange(s)[None]  # (B, S)
                cos, sin = rope(positions, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
    else:
        k, v = cross_kv

    q = constrain(q, P(dp, None, "model", None))
    sm_scale = hd ** -0.5

    if cache is not None and cross_kv is None:
        # append path: write k,v at pos, then attend (decode: over the cache;
        # prefill s>1: within the prompt via the chunked flash path)
        # align the fresh k/v with the cache layout (head_dim over 'model';
        # B==1 long-context shards the sequence over 'data') so the
        # dynamic-update-slice is layout-local instead of an involuntary
        # full reshard (see launch.specs.cache_spec_tree).
        kv_spec = (P(dp, None, None, "model") if b > 1
                   else P(None, "data", None, "model"))
        k = constrain(k.astype(cache["k"].dtype), kv_spec)
        v = constrain(v.astype(cache["v"].dtype), kv_spec)
        idx = pos[0] if pos is not None else 0
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        if s == 1:
            out = _decode_attend(q, ck, cv, idx + s, sm_scale, window, cfg.attn_logit_softcap)
        else:
            out = flash_chunked(q, k, v, causal=causal, window=window,
                                sm_scale=sm_scale, softcap_val=cfg.attn_logit_softcap)
        out = out.reshape(b, s, h * hd) @ wo
        return out, {"k": ck, "v": cv}

    if cache is None and cross_kv is not None:
        out = _decode_attend(q, k, v, k.shape[1], sm_scale, 0, cfg.attn_logit_softcap) \
            if s == 1 else flash_chunked(q, k, v, causal=False, window=0, sm_scale=sm_scale,
                                         softcap_val=cfg.attn_logit_softcap)
        return out.reshape(b, s, h * hd) @ wo, None

    out = flash_chunked(q, k, v, causal=causal, window=window, sm_scale=sm_scale,
                        softcap_val=cfg.attn_logit_softcap)
    out = constrain(out, P(dp, None, "model", None))
    return out.reshape(b, s, h * hd) @ wo, None


def _decode_attend(q, ck, cv, length, sm_scale, window, cap):
    """q: (B, 1, H, D); cache: (B, S_max, KV, D).  Grouped GQA, linear in S.

    The cache stays in its storage dtype (bf16) and sharding (head_dim over
    'model'); q is constrained to the same head_dim sharding so the score
    contraction lowers to a local partial product + a small all-reduce of
    (B, KV, G, 1, S) scores — never an all-gather of the multi-GB cache.
    """
    b, s, h, hd = q.shape
    kvh = ck.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    qg = constrain(qg, P(dp_axes(), None, None, None, "model"))
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck,
                        preferred_element_type=jnp.float32) * sm_scale
    if cap:
        scores = softcap(scores, cap)
    col = jnp.arange(ck.shape[1])
    mask = col[None, :] < length
    if window:
        mask = mask & (col[None, :] > length - 1 - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def init_cache(cfg, batch: int, s_max: int, n_layers: int, dtype=jnp.bfloat16):
    """Stacked KV cache for one stage of ``n_layers`` attention layers."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, s_max, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
