"""repro.models — LM substrate for the 10 assigned architectures."""
from .transformer import Model, init_params, stages_meta

__all__ = ["Model", "init_params", "stages_meta"]
