"""Cost-mode switches for scan-exact HLO accounting.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, which hides (a) per-layer cost inside scanned stages and (b) chunked
inner loops (flash q/k chunks, xent chunks, SSM/mLSTM chunks).  The dry-run
calibration therefore compiles tiny depth variants with:

  unroll_stages — stage scans become python loops (per-layer deltas visible);
  widen_chunks  — inner chunk sizes widen to the full extent (single-iteration
                  scans -> straight-line HLO, exact op counts).

Pass A (FLOPs) uses both; pass B (bytes/collectives) unrolls stages but keeps
production chunking so GSPMD sees the real program.  The deliverable full
compile uses neither.
"""
from __future__ import annotations

import contextlib

_WIDEN = False
_UNROLL = False


def cost_mode() -> bool:
    """True when inner chunk scans should widen to a single iteration."""
    return _WIDEN


def unroll_stages() -> bool:
    return _UNROLL


@contextlib.contextmanager
def costing(widen_chunks: bool = True, unroll: bool = True):
    global _WIDEN, _UNROLL
    prev = (_WIDEN, _UNROLL)
    _WIDEN, _UNROLL = widen_chunks, unroll
    try:
        yield
    finally:
        _WIDEN, _UNROLL = prev
