"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM (matrix memory, xLSTM §2.3) is computed in its chunkwise-parallel form
(the linear-attention decomposition): within-chunk contributions use a
(chunk x chunk) score matrix per head; cross-chunk contributions flow through
the (head_dim x head_dim) matrix state carried between chunks.  Gates use the
stabilizer state m_t (log-space running max) so exponential gating stays
finite.  sLSTM (scalar memory) is a true sequential recurrence via lax.scan.

Shapes follow the assigned xlstm-1.3b config: no separate FFN (d_ff = 0);
each block carries its own up/down projection (proj_factor 2), matching the
published block design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import constrain, dp_axes
from .layers import init_norm, norm

CHUNK = 64
PROJ_FACTOR = 2


def _shp(stacked):
    return (lambda *s: (stacked, *s)) if stacked else (lambda *s: s)


def init_mlstm(key, cfg, dtype, stacked: int = 0) -> dict:
    d = cfg.d_model
    din = PROJ_FACTOR * d
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    shp = _shp(stacked)
    pre = "stk_" if stacked else ""
    hd = din // h
    return {
        pre + "m_in_proj": jax.random.normal(ks[0], shp(d, 2 * din), dtype) * d ** -0.5,
        # block-diagonal per-head q/k/v (xLSTM block design): (H, hd, hd)
        pre + "m_wq": jax.random.normal(ks[1], shp(h, hd, hd), dtype) * hd ** -0.5,
        pre + "m_wk": jax.random.normal(ks[2], shp(h, hd, hd), dtype) * hd ** -0.5,
        pre + "m_wv": jax.random.normal(ks[3], shp(h, hd, hd), dtype) * hd ** -0.5,
        pre + "m_wif": jax.random.normal(ks[4], shp(din, 2 * h), dtype) * din ** -0.5,
        pre + "m_out_proj": jax.random.normal(ks[5], shp(din, d), dtype) * din ** -0.5,
    }


def mlstm_block(p: dict, x: jax.Array, cfg, *, state: dict | None = None):
    """x: (B, S, D) -> (B, S, D); state {"c": (B,H,hd,hd), "n": (B,H,hd),
    "m": (B,H)} enables stateful decode."""
    b, s, d = x.shape
    h = cfg.n_heads
    din = PROJ_FACTOR * d
    hd = din // h
    dp = dp_axes()

    xz = x @ p["m_in_proj"]
    xz = constrain(xz, P(dp, None, "model"))
    xs, z = jnp.split(xz, 2, axis=-1)

    xh = xs.reshape(b, s, h, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, p["m_wq"]).astype(jnp.float32) * hd ** -0.5
    k = jnp.einsum("bshd,hde->bshe", xh, p["m_wk"]).astype(jnp.float32) * hd ** -0.5
    v = jnp.einsum("bshd,hde->bshe", xh, p["m_wv"]).astype(jnp.float32)
    gates = (xs @ p["m_wif"]).astype(jnp.float32)          # (B, S, 2H)
    log_i = -jax.nn.softplus(-gates[..., :h])              # log sigmoid(i)
    log_f = -jax.nn.softplus(-gates[..., h:])              # log sigmoid(f)

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    if s == 1:
        m_new = jnp.maximum(log_f[:, 0] + m0, log_i[:, 0])
        f_sc = jnp.exp(log_f[:, 0] + m0 - m_new)
        i_sc = jnp.exp(log_i[:, 0] - m_new)
        c = f_sc[..., None, None] * c0 + i_sc[..., None, None] * (
            k[:, 0, :, :, None] * v[:, 0, :, None, :])
        n = f_sc[..., None] * n0 + i_sc[..., None] * k[:, 0]
        num = jnp.einsum("bhd,bhde->bhe", q[:, 0], c)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q[:, 0], n))
        y = (num / jnp.maximum(den, 1.0)[..., None]).reshape(b, 1, din)
        new_state = {"c": c, "n": n, "m": m_new}
    else:
        from .costing import cost_mode
        chunk = s if cost_mode() else min(CHUNK, s)
        pad = (-s) % chunk
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
            log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        sp = q.shape[1]
        nc = sp // chunk
        rs = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
        qc, kc, vc, lic, lfc = rs(q), rs(k), rs(v), rs(log_i), rs(log_f)

        def chunk_step(carry, xs_c):
            c_in, n_in, m_in = carry
            qb, kb, vb, li, lf = xs_c                   # (B, c, H, ...)
            lf_cum = jnp.cumsum(lf, axis=1)             # (B, c, H)
            # stabilizer: running max of (m_in + lf_cum) vs per-pos log_i terms
            a_log = lf_cum + m_in[:, None]              # decay applied to old state
            b_log = lf_cum[:, :, None] - lf_cum[:, None, :] + li[:, None]  # (B,c,c,H)? careful
            # within-chunk: contribution of j<=t: exp(lf_cum_t - lf_cum_j + li_j)
            m_new = jnp.maximum(a_log, jnp.max(
                jnp.where(jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None],
                          b_log, -jnp.inf), axis=2))     # (B, c, H)
            scale_old = jnp.exp(a_log - m_new)           # (B, c, H)
            w_in = jnp.exp(b_log - m_new[:, :, None])    # (B, c(t), c(j), H)
            w_in = jnp.where(jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None], w_in, 0.0)
            scores = jnp.einsum("bthd,bjhd->btjh", qb, kb) * w_in
            num_intra = jnp.einsum("btjh,bjhd->bthd", scores, vb)
            num_inter = jnp.einsum("bthd,bhde->bthe", qb, c_in) * scale_old[..., None]
            den_intra = scores.sum(axis=2)  # sum_j w[t,j] * (q_t . k_j)
            den_inter = jnp.einsum("bthd,bhd->bth", qb, n_in) * scale_old
            den = jnp.abs(den_intra + den_inter)
            y_c = (num_intra + num_inter) / jnp.maximum(den, 1.0)[..., None]
            # chunk-end state
            m_end = m_new[:, -1]
            decay_all = lf_cum[:, -1:] - lf_cum + li     # (B, c, H) weight of each j into end-state
            w_end = jnp.exp(decay_all - m_end[:, None])
            kw = kb * w_end[..., None]
            c_out = jnp.exp(lf_cum[:, -1] + m_in - m_end)[..., None, None] * c_in + \
                jnp.einsum("bjhd,bjhe->bhde", kw, vb)
            n_out = jnp.exp(lf_cum[:, -1] + m_in - m_end)[..., None] * n_in + kw.sum(1)
            return (c_out, n_out, m_end), y_c

        (c_l, n_l, m_l), y_chunks = jax.lax.scan(chunk_step, (c0, n0, m0),
                                                 (qc, kc, vc, lic, lfc))
        y = y_chunks.swapaxes(0, 1).reshape(b, sp, din)[:, :s]
        new_state = {"c": c_l, "n": n_l, "m": m_l}

    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["m_out_proj"], new_state


def init_slstm(key, cfg, dtype, stacked: int = 0) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 3)
    shp = _shp(stacked)
    pre = "stk_" if stacked else ""
    return {
        # fused z|i|f|o pre-activations from input; recurrent weight is
        # block-diagonal per head (dense (d, 4d) input + (d,) recurrent gate)
        pre + "s_w_in": jax.random.normal(ks[0], shp(d, 4 * d), dtype) * d ** -0.5,
        pre + "s_r_gate": jax.random.normal(ks[1], shp(d,), dtype) * 0.1,
        pre + "s_out_proj": jax.random.normal(ks[2], shp(d, d), dtype) * d ** -0.5,
    }


def slstm_block(p: dict, x: jax.Array, cfg, *, state: dict | None = None):
    """Sequential scalar-memory LSTM with exponential gating (sLSTM).

    state {"c","n","m","h"}: (B, D) each.  Recurrence is elementwise + a
    diagonal recurrent connection so the per-step cost stays VPU-friendly.
    """
    b, s, d = x.shape
    pre = (x @ p["s_w_in"]).astype(jnp.float32)            # (B, S, 4D)
    z_in, i_in, f_in, o_in = jnp.split(pre, 4, axis=-1)
    r = p["s_r_gate"].astype(jnp.float32)

    if state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), -1e30, jnp.float32)
        h0 = jnp.zeros((b, d), jnp.float32)
    else:
        c0, n0, m0, h0 = state["c"], state["n"], state["m"], state["h"]

    def step(carry, xs_t):
        c, n, m, h_prev = carry
        z_t, i_t, f_t, o_t = xs_t
        z = jnp.tanh(z_t + r * h_prev)
        log_i = i_t
        log_f = -jax.nn.softplus(-(f_t + r * h_prev))      # log sigmoid
        m_new = jnp.maximum(log_f + m, log_i)
        i_sc = jnp.exp(log_i - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        c = f_sc * c + i_sc * z
        n = f_sc * n + i_sc
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h), h

    (c_l, n_l, m_l, h_l), hs = jax.lax.scan(
        step, (c0, n0, m0, h0),
        (z_in.swapaxes(0, 1), i_in.swapaxes(0, 1), f_in.swapaxes(0, 1), o_in.swapaxes(0, 1)),
    )
    y = hs.swapaxes(0, 1).astype(x.dtype)                  # (B, S, D)
    new_state = {"c": c_l, "n": n_l, "m": m_l, "h": h_l}
    return y @ p["s_out_proj"], new_state


def init_mlstm_state(cfg, batch: int, n_layers: int) -> dict:
    din = PROJ_FACTOR * cfg.d_model
    h = cfg.n_heads
    hd = din // h
    return {
        "c": jnp.zeros((n_layers, batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((n_layers, batch, h, hd), jnp.float32),
        "m": jnp.full((n_layers, batch, h), -1e30, jnp.float32),
    }


def init_slstm_state(cfg, batch: int, n_layers: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((n_layers, batch, d), jnp.float32),
        "n": jnp.zeros((n_layers, batch, d), jnp.float32),
        "m": jnp.full((n_layers, batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((n_layers, batch, d), jnp.float32),
    }
