"""Shared model layers: norms, rotary embeddings, embedding/unembedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "layer_norm", "norm", "rope", "apply_rope", "init_norm",
           "softcap"]


def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layer_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    return layer_norm(p, x) if kind == "layernorm" else rms_norm(p, x)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-style logit soft-capping."""
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def rope(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """Rotary cos/sin tables for integer positions (..., S)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)
