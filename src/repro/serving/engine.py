"""Batched serving engine: prefill + decode with greedy-LPT batch packing.

Requests with heterogeneous prompt lengths are packed into fixed decode
batches by the paper's greedy partitioner (``repro.core.partitioners``): the
balance objective that packs equivalence classes onto executors is the same
one that packs prompts onto batch slots so padded prefill work is minimized
(DESIGN.md §4 — framework-level reuse of the paper's technique).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.partitioners import pack_items
from ..models import Model
from .metrics import ServingMetrics, now

__all__ = ["Request", "ServingEngine", "pack_requests"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32 token ids
    max_new_tokens: int = 16


def pack_requests(requests: Sequence[Request], n_batches: int):
    """Greedy-LPT pack requests into ``n_batches`` groups balancing total
    prefill tokens (shared ``core.partitioners.pack_items`` path, same as
    the FIM query packer).  Returns (assignment, stats)."""
    work = np.array([r.prompt.shape[0] for r in requests], np.float64)
    return pack_items(work, n_batches)


class ServingEngine:
    def __init__(self, model: Model, params, s_max: int, temperature: float = 0.0,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.s_max = s_max
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        # same instrumentation layer as the FIM front end: per-request
        # admission->batch->answer latency, aggregated to p50/p99 + QPS
        self.metrics = ServingMetrics()

    def _sample(self, logits) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits[:, -1] / self.temperature).astype(jnp.int32)

    def generate_batch(self, requests: List[Request]) -> List[np.ndarray]:
        """Prefill a length-homogeneous batch once, then decode greedily.

        Requests in one batch must share a prompt length (``serve`` groups by
        length): the causal prefill has no padding mask, so padding tokens
        would leak into attention — length bucketing keeps generation exact
        (tests/test_serving.py::test_batched_matches_single).
        """
        b = len(requests)
        lens = np.array([r.prompt.shape[0] for r in requests])
        lmax = int(lens.max())
        if not (lens == lmax).all():
            raise ValueError("generate_batch requires equal prompt lengths; "
                             "use serve() which buckets by length")
        toks = np.stack([r.prompt for r in requests])
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.s_max)
        max_new = max(r.max_new_tokens for r in requests)
        outs = [[] for _ in range(b)]
        tok = self._sample(logits)
        for i in range(b):
            outs[i].append(int(tok[i]))
        for t in range(1, max_new):
            pos = jnp.full((b,), lmax + t - 1, jnp.int32)
            logits, cache = self._decode(self.params, tok[:, None], cache, pos)
            tok = self._sample(logits)
            for i in range(b):
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(tok[i]))
        return [np.asarray(o, np.int32) for o in outs]

    def serve(self, requests: List[Request], n_batches: int):
        t_enqueue = now()
        assign, stats = pack_requests(requests, n_batches)
        results: dict = {}
        for gb in range(n_batches):
            group = [r for r, a in zip(requests, assign) if a == gb]
            if not group:
                continue
            t_drain = now()
            # exactness: sub-batch by prompt length (no padding mask in the
            # causal prefill; see generate_batch)
            by_len: dict = {}
            for r in group:
                by_len.setdefault(r.prompt.shape[0], []).append(r)
            for sub in by_len.values():
                outs = self.generate_batch(sub)
                t_answer = now()
                for r, o in zip(sub, outs):
                    results[r.rid] = o
                    self.metrics.record_answer(t_enqueue, t_drain, t_answer)
                self.metrics.record_batch(len(sub))
        stats["latency"] = self.metrics.summary()
        return results, stats
