"""repro.serving — KV-cached batched inference engine + live-window FIM
query service (top-k itemsets / rules over the streaming miner)."""
from .engine import Request, ServingEngine, pack_requests
from .stream_query import ItemsetQuery, StreamQueryService, pack_queries

__all__ = ["Request", "ServingEngine", "pack_requests",
           "ItemsetQuery", "StreamQueryService", "pack_queries"]
