"""repro.serving — KV-cached batched inference engine."""
from .engine import Request, ServingEngine, pack_requests

__all__ = ["Request", "ServingEngine", "pack_requests"]
