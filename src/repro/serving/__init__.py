"""repro.serving — the unified serving path (DESIGN.md §11).

One request lifecycle for both workloads: admission -> greedy-LPT pack ->
answer -> version-stamped result, instrumented end to end.

* ``ServingFrontend`` (``admission``) — async batched admission over the
  streaming miner: bounded queue with shed-or-block backpressure,
  deadline/size drain triggers, continuous greedy-LPT packing, answers
  bit-identical to the synchronous path at the same ``window_version``.
* ``StreamQueryService`` (``stream_query``) — the thin synchronous adapter
  over the same snapshot/cache/answer kernels.
* ``ServingEngine`` (``engine``) — KV-cached batched LM inference on the
  shared pack + metrics scaffolding.
* ``VersionedCache`` / ``WindowSnapshot`` / ``ServingMetrics`` — the shared
  version-keyed caching, immutable snapshot handoff, and p50/p99/QPS
  instrumentation layers.
* ``loadgen`` — deterministic query storms + the answer-checksum
  verification oracle (``benchmarks/serving_bench.py``, the ``--serve``
  drivers).
"""
from .admission import AdmissionConfig, QueryShed, ServingFrontend, Ticket
from .cache import VersionedCache
from .engine import Request, ServingEngine, pack_requests
from .loadgen import answer_checksum, query_mix, run_storm, verify_storm
from .metrics import ServingMetrics
from .snapshot import (WindowSnapshot, answer_query, answer_rules,
                       answer_support, answer_topk)
from .stream_query import (ItemsetQuery, StreamQueryService, pack_queries,
                           query_work)

__all__ = ["Request", "ServingEngine", "pack_requests",
           "ItemsetQuery", "StreamQueryService", "pack_queries", "query_work",
           "AdmissionConfig", "QueryShed", "ServingFrontend", "Ticket",
           "VersionedCache", "WindowSnapshot", "ServingMetrics",
           "answer_query", "answer_rules", "answer_support", "answer_topk",
           "answer_checksum", "query_mix", "run_storm", "verify_storm"]
