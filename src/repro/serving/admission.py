"""Async admission for the FIM query surface: bounded queue, continuous
greedy-LPT batching, version-stamped answers (DESIGN.md §11).

``ServingFrontend`` is the production front end the synchronous
``StreamQueryService`` adapts down from: concurrent clients ``submit``
:class:`~repro.serving.ItemsetQuery` objects into a bounded admission queue
and get a :class:`Ticket` back; a drain worker collects queued queries until
either the batch-size or the deadline trigger fires, packs the drained batch
onto answer slots with the paper's greedy-LPT balance objective (the same
``core.partitioners`` call that packs equivalence classes onto executors),
and answers every query from **one** immutable window snapshot — so each
answer is bit-identical to the same query answered synchronously at that
``window_version``, which ``benchmarks/serving_bench.py`` re-checks by
checksum.

Backpressure: a full queue either *sheds* (``QueryShed`` raised to the
client immediately) or *blocks* the submitter until space frees, per
``AdmissionConfig.policy``.  Liveness: the writer beats a
``training.fault_tolerance.Heartbeat`` on every ingest; with
``stall_timeout_s`` set, a stalled miner is detected and reported
(``WriterStalledError`` out of :meth:`ServingFrontend.wait_for_version`,
``n_stalls`` in metrics) instead of readers hanging forever.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..streaming import StreamingMiner, WindowResult, restore_miner
from ..training.fault_tolerance import (Heartbeat, HeartbeatMonitor,
                                        WriterStalledError)
from .cache import VersionedCache
from .metrics import ServingMetrics, now
from .snapshot import WindowSnapshot, answer_query
from .stream_query import ItemsetQuery, pack_queries

__all__ = ["AdmissionConfig", "QueryShed", "Ticket", "ServingFrontend"]


class QueryShed(RuntimeError):
    """Backpressure: the admission queue was full and the policy shed the
    query (or a blocking submit timed out waiting for space)."""


@dataclasses.dataclass
class AdmissionConfig:
    """Knobs of the serving front end."""

    max_queue: int = 256          # bounded admission queue capacity
    policy: str = "block"         # full-queue policy: "block" | "shed"
    max_batch: int = 32           # drain trigger: this many queued...
    max_wait_s: float = 0.002     # ...or the oldest query has waited this long
    n_slots: int = 4              # greedy-LPT answer slots per drained batch
    block_timeout_s: float = 5.0  # block policy: max wait for space, then shed
    stall_timeout_s: Optional[float] = None  # writer heartbeat deadline
    keep_versions: int = 8        # snapshot history depth (verification/pinning)

    def __post_init__(self):
        if self.policy not in ("block", "shed"):
            raise ValueError(f"policy must be 'block' or 'shed', "
                             f"got {self.policy!r}")
        if self.max_queue < 1 or self.max_batch < 1 or self.n_slots < 1:
            raise ValueError("max_queue, max_batch and n_slots must be >= 1")


class Ticket:
    """One admitted query: timestamps, future-style result, version stamp."""

    __slots__ = ("query", "t_enqueue", "t_drain", "t_answer", "version",
                 "answer", "error", "cache_hit", "_done")

    def __init__(self, query: ItemsetQuery):
        self.query = query
        self.t_enqueue = now()
        self.t_drain: Optional[float] = None
        self.t_answer: Optional[float] = None
        self.version: Optional[int] = None
        self.answer = None
        self.error: Optional[BaseException] = None
        self.cache_hit = False
        self._done = threading.Event()

    def _complete(self, answer, version: int, cache_hit: bool) -> None:
        self.answer, self.version, self.cache_hit = answer, version, cache_hit
        self.t_answer = now()
        self._done.set()

    def _fail(self, error: BaseException) -> None:
        self.error = error
        self.t_answer = now()
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block for the answer; returns ``(answer, window_version)``.

        Raises the answering error if the query failed, and ``TimeoutError``
        if no answer lands in ``timeout`` seconds — a bounded wait, so a
        reader can never hang forever on a dead front end.
        """
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"query qid={self.query.qid} unanswered after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.answer, self.version


class ServingFrontend:
    """Continuous-batching query front end over one ``StreamingMiner``.

    Writer side: one thread calls :meth:`ingest` (window slide + snapshot
    publication + heartbeat).  Reader side: any number of threads call
    :meth:`submit` / ``Ticket.result``.  The drain worker is internal.
    """

    def __init__(self, miner: StreamingMiner,
                 config: Optional[AdmissionConfig] = None,
                 auto_start: bool = True):
        self.miner = miner
        self.config = config or AdmissionConfig()
        self.cache = VersionedCache()
        self.metrics = ServingMetrics()
        self.heartbeat = Heartbeat()
        self.monitor = (HeartbeatMonitor(
            self.heartbeat, self.config.stall_timeout_s,
            on_stall=lambda _r: self.metrics.record_stall(), name="miner")
            if self.config.stall_timeout_s else None)
        self._history: "collections.OrderedDict[int, WindowSnapshot]" = \
            collections.OrderedDict()
        # serve the window the miner already holds (empty for a fresh miner,
        # the restored window for a checkpoint restore) — a restarted server
        # answers before its first live slide
        self._snapshot = self._publish(miner.mine_window())
        self._cond = threading.Condition()
        self._queue: "collections.deque[Ticket]" = collections.deque()
        self._running = False
        self._worker: Optional[threading.Thread] = None
        self.last_pack_stats: Optional[dict] = None
        if auto_start:
            self.start()

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._worker = threading.Thread(target=self._drain_loop,
                                        name="serving-drain", daemon=True)
        self._worker.start()

    def stop(self) -> None:
        """Stop the drain worker; fails still-queued tickets (readers are
        released with an error, never left hanging)."""
        with self._cond:
            self._running = False
            pending = list(self._queue)
            self._queue.clear()
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None
        for t in pending:
            t._fail(RuntimeError("serving frontend stopped"))

    def __enter__(self) -> "ServingFrontend":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- writer side ---------------------------------------------------------

    def _publish(self, result: WindowResult) -> WindowSnapshot:
        snap = WindowSnapshot.from_result(result)
        self._history[snap.version] = snap
        while len(self._history) > self.config.keep_versions:
            self._history.popitem(last=False)
        self._snapshot = snap          # atomic publication point
        self.cache.advance(snap.version)
        return snap

    def ingest(self, batch: Sequence[Sequence[int]]) -> WindowResult:
        """One window slide: advance the miner, publish the new snapshot,
        beat the liveness heartbeat."""
        result = self.miner.advance(batch)
        snap = self._publish(result)
        self.heartbeat.beat(snap.version)
        return result

    # -- reader side ---------------------------------------------------------

    @property
    def snapshot(self) -> WindowSnapshot:
        return self._snapshot

    @property
    def window_version(self) -> int:
        return self._snapshot.version

    @property
    def writer_stalled(self) -> bool:
        return self.monitor.check() if self.monitor is not None else False

    def snapshot_at(self, version: int) -> Optional[WindowSnapshot]:
        """A retained historical snapshot (None once aged out) — the bench's
        per-version verification oracle."""
        return self._history.get(int(version))

    def wait_for_version(self, version: int, timeout: Optional[float] = None,
                         poll_s: float = 0.005) -> WindowSnapshot:
        """Block until the published window reaches ``version``.

        Raises ``WriterStalledError`` as soon as the heartbeat monitor
        declares the writer stalled (this is the reported-not-hanging path)
        and ``TimeoutError`` after ``timeout`` seconds regardless.
        """
        deadline = None if timeout is None else now() + timeout
        while True:
            snap = self._snapshot
            if snap.version >= version:
                return snap
            if self.monitor is not None:
                self.monitor.assert_alive()
            if deadline is not None and now() > deadline:
                raise TimeoutError(f"window version {version} not reached "
                                   f"(at {snap.version})")
            time.sleep(poll_s)

    def submit(self, query: ItemsetQuery) -> Ticket:
        """Admit one query; returns its :class:`Ticket`.

        Full queue: policy "shed" raises :class:`QueryShed` immediately;
        policy "block" waits up to ``block_timeout_s`` for space, then
        sheds.  Both outcomes are counted in metrics.
        """
        ticket = Ticket(query)
        with self._cond:
            if not self._running:
                raise RuntimeError("serving frontend is not running")
            if len(self._queue) >= self.config.max_queue:
                if self.config.policy == "shed":
                    self.metrics.record_shed()
                    raise QueryShed(f"admission queue full "
                                    f"({self.config.max_queue}); qid="
                                    f"{query.qid} shed")
                deadline = now() + self.config.block_timeout_s
                while len(self._queue) >= self.config.max_queue:
                    remaining = deadline - now()
                    if remaining <= 0 or not self._running:
                        self.metrics.record_shed()
                        raise QueryShed(
                            f"blocked submit timed out after "
                            f"{self.config.block_timeout_s}s; qid="
                            f"{query.qid} shed")
                    self._cond.wait(remaining)
            self._queue.append(ticket)
            self._cond.notify_all()
        return ticket

    def submit_many(self, queries: Sequence[ItemsetQuery]) -> List[Ticket]:
        return [self.submit(q) for q in queries]

    # -- drain worker --------------------------------------------------------

    def _drain_loop(self) -> None:
        cfg = self.config
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait(0.05)
                    if self.monitor is not None:
                        self.monitor.check()   # latch + count a writer stall
                if not self._running and not self._queue:
                    return
                # continuous batching: drain when max_batch queries are
                # waiting or the oldest has aged past the deadline,
                # whichever first
                deadline = self._queue[0].t_enqueue + cfg.max_wait_s
                while (self._running and len(self._queue) < cfg.max_batch
                       and now() < deadline):
                    self._cond.wait(max(deadline - now(), 1e-4))
                batch = [self._queue.popleft()
                         for _ in range(min(len(self._queue), cfg.max_batch))]
                self._cond.notify_all()        # wake blocked submitters
            if batch:
                self._answer_batch(batch)

    def _answer_batch(self, tickets: List[Ticket]) -> None:
        t_drain = now()
        for t in tickets:
            t.t_drain = t_drain
        snap = self._snapshot              # ONE reference read per batch
        try:
            assign, stats = pack_queries([t.query for t in tickets],
                                         self.config.n_slots,
                                         max(len(snap.itemsets), 1))
        except Exception as e:             # malformed batch: release readers
            for t in tickets:
                t._fail(e)
                self.metrics.record_error()
            return
        stats["window_version"] = snap.version
        self.last_pack_stats = stats
        self.metrics.record_batch(len(tickets))
        for slot in range(self.config.n_slots):
            for qi in np.nonzero(assign == slot)[0]:
                t = tickets[int(qi)]
                try:
                    answer, hit = answer_query(snap, t.query, cache=self.cache)
                    t._complete(answer, snap.version, hit)
                    self.metrics.record_answer(t.t_enqueue, t.t_drain,
                                               t.t_answer, cache_hit=hit)
                except Exception as e:
                    t._fail(e)
                    self.metrics.record_error()

    # -- restore -------------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, directory: str,
                        mesh: Optional[jax.sharding.Mesh] = None,
                        *, backend: Optional[str] = None,
                        shard: Optional[str] = None,
                        config: Optional[AdmissionConfig] = None,
                        auto_start: bool = True
                        ) -> Tuple["ServingFrontend", int]:
        """Rebuild a serving front end from a ``streaming/persist.py``
        checkpoint: the restored miner re-expands its window and the
        frontend answers from it immediately (a restarted server needs no
        live slide before its first answer).  Returns
        ``(frontend, completed_slides)``.
        """
        miner, completed = restore_miner(directory, mesh=mesh,
                                         backend=backend, shard=shard,
                                         keep_transactions=False)
        return cls(miner, config=config, auto_start=auto_start), completed
