"""Live-window FIM query service: top-k itemsets and rules over the stream.

``StreamQueryService`` sits on a :class:`repro.streaming.StreamingMiner` the
way :class:`ServingEngine` sits on a model: ``ingest`` advances the window
and refreshes the query snapshot; readers then query the *current window*
without touching mining state.  Heterogeneous query batches are packed onto
answer slots with the same greedy-LPT partitioner that packs equivalence
classes onto executors and prompts onto decode batches (DESIGN.md §4/§5 —
the paper's balance objective reused at the product surface).

Rule generation is cached per (window snapshot, min_conf): repeated rule
queries between slides pay the ``generate_rules`` scan once.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.itemsets import generate_rules
from ..core.partitioners import greedy_partitioner, partition_stats
from ..streaming import StreamingMiner, WindowResult

__all__ = ["ItemsetQuery", "StreamQueryService", "pack_queries"]


@dataclasses.dataclass
class ItemsetQuery:
    """One reader request against the current window.

    kind:     "topk" (k most supported itemsets of length >= min_len) or
              "rules" (k most confident rules at min_conf).
    """

    qid: int
    kind: str = "topk"
    k: int = 10
    min_len: int = 1
    min_conf: float = 0.8


def pack_queries(queries: Sequence[ItemsetQuery], n_batches: int,
                 n_itemsets: int):
    """Greedy-LPT pack queries into ``n_batches`` answer slots.

    The work estimate is the number of store entries each query scans:
    ``n_itemsets`` for a top-k pass, a rule-expansion multiple of it for
    rule queries (antecedent enumeration dominates).
    """
    work = np.array(
        [n_itemsets * (4.0 if q.kind == "rules" else 1.0) for q in queries],
        np.float64)
    assign = greedy_partitioner(np.arange(len(queries)), n_batches, work=work)
    stats = partition_stats(assign, work, n_batches)
    return assign, stats


class StreamQueryService:
    def __init__(self, miner: StreamingMiner):
        self.miner = miner
        self.result: Optional[WindowResult] = None
        self._itemsets: List[Tuple[Tuple[int, ...], int]] = []
        self._support_map: Dict[Tuple[int, ...], int] = {}
        self._rules_cache: Dict[float, list] = {}
        self.n_slides = 0

    # -- writer side ---------------------------------------------------------

    def ingest(self, batch: Sequence[Sequence[int]]) -> WindowResult:
        """Advance the window one micro-batch and refresh the snapshot."""
        result = self.miner.advance(batch)
        self.result = result
        self._itemsets = result.itemsets()
        self._support_map = dict(self._itemsets)
        self._rules_cache = {}
        self.n_slides += 1
        return result

    # -- reader side ---------------------------------------------------------

    def top_k_itemsets(self, k: int = 10, min_len: int = 1):
        """k most supported frequent itemsets (ties: longer, then lex)."""
        cand = [(s, it) for it, s in self._itemsets if len(it) >= min_len]
        cand.sort(key=lambda e: (-e[0], -len(e[1]), e[1]))
        return [(it, s) for s, it in cand[:k]]

    def support(self, itemset: Sequence[int]) -> int:
        """Support of one itemset over the live window (0 if infrequent)."""
        return self._support_map.get(tuple(sorted(itemset)), 0)

    def rules(self, min_conf: float = 0.8, k: Optional[int] = None):
        """Most confident association rules over the live window."""
        cached = self._rules_cache.get(min_conf)
        if cached is None:
            cached = sorted(generate_rules(self._support_map, min_conf),
                            key=lambda r: (-r[2], -r[3], r[0], r[1]))
            self._rules_cache[min_conf] = cached
        return cached if k is None else cached[:k]

    def answer_batch(self, queries: Sequence[ItemsetQuery], n_batches: int = 4):
        """Answer a heterogeneous query batch, greedy-LPT packed.

        The packing is executed, not just reported: queries are answered
        slot-by-slot in the packed assignment (the regression was computing
        the packing, answering in input order, and returning balance stats
        for work that never happened).  Returns ``(answers by qid, packing
        stats)`` — the stats carry the partitioner's ``padding_efficiency``
        plus ``queries_per_slot``, the per-answer-slot query counts of the
        assignment that actually ran.
        """
        assign, stats = pack_queries(queries, n_batches, max(len(self._itemsets), 1))
        answers: Dict[int, list] = {}
        queries_per_slot: List[int] = []
        for slot in range(int(n_batches)):
            members = np.nonzero(assign == slot)[0]
            queries_per_slot.append(int(members.size))
            for qi in members:
                q = queries[int(qi)]
                if q.kind == "topk":
                    answers[q.qid] = self.top_k_itemsets(q.k, q.min_len)
                elif q.kind == "rules":
                    answers[q.qid] = self.rules(q.min_conf, q.k)
                else:
                    raise ValueError(f"unknown query kind {q.kind!r}")
        stats["queries_per_slot"] = queries_per_slot
        return answers, stats
