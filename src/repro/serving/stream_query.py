"""Synchronous adapter over the shared serving scaffolding.

``StreamQueryService`` keeps the original one-call-at-a-time API (ingest /
top_k_itemsets / support / rules / answer_batch) but is now a thin layer
over the pieces the batched front end (``serving.admission``) also uses:
immutable :class:`~repro.serving.snapshot.WindowSnapshot` publication, the
version-keyed :class:`~repro.serving.cache.VersionedCache`, and the shared
answer kernels — so a synchronous answer and a batched answer at the same
``window_version`` are bit-identical by construction (DESIGN.md §11).

Heterogeneous query batches are packed onto answer slots with the same
greedy-LPT partitioner that packs equivalence classes onto executors and
prompts onto decode batches (DESIGN.md §4/§5 — the paper's balance
objective reused at the product surface).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.partitioners import pack_items
from ..streaming import StreamingMiner, WindowResult
from .cache import VersionedCache
from .snapshot import (WindowSnapshot, answer_query, answer_rules,
                       answer_support, answer_topk)

__all__ = ["ItemsetQuery", "StreamQueryService", "pack_queries", "query_work"]


@dataclasses.dataclass
class ItemsetQuery:
    """One reader request against the current window.

    kind:     "topk" (k most supported itemsets of length >= min_len) or
              "rules" (k most confident rules at min_conf).
    """

    qid: int
    kind: str = "topk"
    k: int = 10
    min_len: int = 1
    min_conf: float = 0.8


def query_work(query: ItemsetQuery, n_itemsets: int) -> float:
    """Estimated store-scan work of one query, in entry-visit units.

    The estimate folds in the query's own parameters, not just its kind
    (the regression: a ``k=1`` probe and a ``k=10_000`` scan were costed
    identically, so the greedy packer balanced the wrong quantity):

    * topk — one full store scan (the ``min_len`` filter touches every
      entry) plus top-k selection/copy work proportional to the ``k``
      entries actually ranked and returned;
    * rules — antecedent enumeration over the store dominates (~4x a scan),
      and a *looser* ``min_conf`` keeps more candidate rules alive through
      confidence ranking, so cost grows as ``min_conf`` drops; the ``k``
      term prices the returned slice.
    """
    n = max(int(n_itemsets), 1)
    k = n if query.k is None else min(int(query.k), n)
    if query.kind == "rules":
        return 4.0 * n * (2.0 - float(query.min_conf)) + 8.0 * k
    return float(n) + 8.0 * k


def pack_queries(queries: Sequence[ItemsetQuery], n_batches: int,
                 n_itemsets: int):
    """Greedy-LPT pack queries into ``n_batches`` answer slots, balancing
    the per-query :func:`query_work` estimate.  Returns (assignment,
    stats)."""
    work = np.array([query_work(q, n_itemsets) for q in queries], np.float64)
    return pack_items(work, n_batches)


class StreamQueryService:
    def __init__(self, miner: StreamingMiner):
        self.miner = miner
        self.result: Optional[WindowResult] = None
        self.cache = VersionedCache()
        self._snapshot = WindowSnapshot.empty(version=miner.window_version)
        self.n_slides = 0

    # -- writer side ---------------------------------------------------------

    def ingest(self, batch: Sequence[Sequence[int]]) -> WindowResult:
        """Advance the window one micro-batch and publish a new snapshot."""
        result = self.miner.advance(batch)
        self.publish(result)
        return result

    def publish(self, result: WindowResult) -> WindowSnapshot:
        """Swap in an immutable snapshot of ``result`` (one atomic reference
        assignment — readers see the old window or the new one, never a
        torn mixture) and invalidate exactly the out-of-version cache
        entries."""
        snap = WindowSnapshot.from_result(result)
        self._snapshot = snap
        self.result = result
        self.cache.advance(snap.version)
        self.n_slides += 1
        return snap

    # -- reader side ---------------------------------------------------------

    @property
    def snapshot(self) -> WindowSnapshot:
        """The current published window view (immutable, version-stamped)."""
        return self._snapshot

    @property
    def window_version(self) -> int:
        return self._snapshot.version

    @property
    def _itemsets(self) -> List[Tuple[Tuple[int, ...], int]]:
        # legacy alias (pre-snapshot layout); kept for callers/tests that
        # sized packing off the raw store list
        return list(self._snapshot.itemsets)

    @property
    def _support_map(self) -> Dict[Tuple[int, ...], int]:
        return self._snapshot.support_map

    def top_k_itemsets(self, k: int = 10, min_len: int = 1):
        """k most supported frequent itemsets (ties: longer, then lex)."""
        return answer_topk(self._snapshot, k, min_len, cache=self.cache)

    def support(self, itemset: Sequence[int]) -> int:
        """Support of one itemset over the live window (0 if infrequent)."""
        return answer_support(self._snapshot, itemset)

    def rules(self, min_conf: float = 0.8, k: Optional[int] = None):
        """Most confident association rules over the live window."""
        return answer_rules(self._snapshot, min_conf, k, cache=self.cache)

    def answer_batch(self, queries: Sequence[ItemsetQuery], n_batches: int = 4):
        """Answer a heterogeneous query batch, greedy-LPT packed.

        The packing is executed, not just reported: queries are answered
        slot-by-slot in the packed assignment against one snapshot grabbed
        up front.  Returns ``(answers by qid, packing stats)`` — the stats
        carry the partitioner's ``padding_efficiency`` plus
        ``queries_per_slot``, the per-answer-slot query counts of the
        assignment that actually ran.
        """
        snap = self._snapshot
        assign, stats = pack_queries(queries, n_batches,
                                     max(len(snap.itemsets), 1))
        answers: Dict[int, list] = {}
        queries_per_slot: List[int] = []
        for slot in range(int(n_batches)):
            members = np.nonzero(assign == slot)[0]
            queries_per_slot.append(int(members.size))
            for qi in members:
                q = queries[int(qi)]
                answers[q.qid], _ = answer_query(snap, q, cache=self.cache)
        stats["queries_per_slot"] = queries_per_slot
        stats["window_version"] = snap.version
        return answers, stats
