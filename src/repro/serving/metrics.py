"""Per-query serving instrumentation: enqueue→drain→answer latency, QPS.

Every query that crosses the serving front end (DESIGN.md §11) carries three
timestamps: ``t_enqueue`` (admission), ``t_drain`` (its batch left the
admission queue), ``t_answer`` (answer materialized).  ``ServingMetrics``
aggregates them into the SLO numbers the north star asks for — p50/p99 of
total latency and of its queue-wait and answer components, plus sustained
queries/sec — and carries the backpressure/staleness counters (shed queries,
writer-stall detections) that the latency distribution alone cannot show.

Thread-safe: readers record from the drain worker while clients submit and
the writer slides windows; ``summary()`` takes a consistent copy under the
same lock.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["ServingMetrics", "percentiles"]


def percentiles(xs: Sequence[float], qs: Sequence[float] = (50.0, 99.0)) -> Dict[str, float]:
    """``{"p50": ..., "p99": ...}`` in milliseconds (empty input -> zeros)."""
    if len(xs) == 0:
        return {f"p{int(q)}": 0.0 for q in qs}
    vals = np.percentile(np.asarray(xs, np.float64), list(qs))
    return {f"p{int(q)}": float(v) * 1e3 for q, v in zip(qs, vals)}


class ServingMetrics:
    """Latency histogram + counters for one serving front end."""

    def __init__(self):
        self._lock = threading.Lock()
        self._total: List[float] = []      # t_answer - t_enqueue
        self._queue: List[float] = []      # t_drain - t_enqueue
        self._answer: List[float] = []     # t_answer - t_drain
        self._first_enqueue: Optional[float] = None
        self._last_answer: Optional[float] = None
        self._batch_sizes: List[int] = []
        self.n_answered = 0
        self.n_cache_hits = 0
        self.n_shed = 0
        self.n_errors = 0
        self.n_stalls = 0

    # -- recording -----------------------------------------------------------

    def record_answer(self, t_enqueue: float, t_drain: float, t_answer: float,
                      *, cache_hit: bool = False) -> None:
        with self._lock:
            self._total.append(t_answer - t_enqueue)
            self._queue.append(t_drain - t_enqueue)
            self._answer.append(t_answer - t_drain)
            if self._first_enqueue is None or t_enqueue < self._first_enqueue:
                self._first_enqueue = t_enqueue
            if self._last_answer is None or t_answer > self._last_answer:
                self._last_answer = t_answer
            self.n_answered += 1
            if cache_hit:
                self.n_cache_hits += 1

    def record_batch(self, n: int) -> None:
        with self._lock:
            self._batch_sizes.append(int(n))

    def record_shed(self) -> None:
        with self._lock:
            self.n_shed += 1

    def record_error(self) -> None:
        with self._lock:
            self.n_errors += 1

    def record_stall(self) -> None:
        with self._lock:
            self.n_stalls += 1

    # -- aggregation ---------------------------------------------------------

    def summary(self) -> dict:
        """p50/p99 (ms) of total / queue-wait / answer latency, QPS, batch
        shape, and the shed/error/stall counters."""
        with self._lock:
            total, queue, answer = list(self._total), list(self._queue), list(self._answer)
            batches = list(self._batch_sizes)
            span = ((self._last_answer - self._first_enqueue)
                    if self._first_enqueue is not None
                    and self._last_answer is not None else 0.0)
            out = {
                "n_answered": self.n_answered,
                "n_shed": self.n_shed,
                "n_errors": self.n_errors,
                "n_stalls": self.n_stalls,
                "cache_hit_rate": (self.n_cache_hits / self.n_answered
                                   if self.n_answered else 0.0),
            }
        out["latency_ms"] = percentiles(total)
        out["queue_wait_ms"] = percentiles(queue)
        out["answer_ms"] = percentiles(answer)
        out["qps"] = (len(total) / span) if span > 0 else 0.0
        out["mean_batch"] = float(np.mean(batches)) if batches else 0.0
        out["n_batches"] = len(batches)
        return out


def now() -> float:
    """The serving clock (one place, so tests can reason about it)."""
    return time.perf_counter()
