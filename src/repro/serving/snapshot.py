"""Immutable per-version window snapshots + the shared answer kernels.

The reader/writer contract of the serving layer (DESIGN.md §11) hinges on
one rule: a reader answers a query entirely from **one**
:class:`WindowSnapshot` object, grabbed by a single reference read.  The
writer builds the next snapshot off to the side and publishes it with one
attribute assignment (atomic in CPython), so a query racing ``ingest`` sees
either the old window or the new one, never a torn mixture — and every
answer is stamped with the version it was computed against.

The answer kernels here are the *only* implementation of top-k / support /
rules in the repo; the synchronous :class:`~repro.serving.StreamQueryService`
and the batched :class:`~repro.serving.ServingFrontend` both call them, so
"batched answer == direct answer at the same version" is true by
construction and re-checked by checksum in ``benchmarks/serving_bench.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.itemsets import generate_rules
from .cache import VersionedCache

__all__ = ["WindowSnapshot", "answer_topk", "answer_rules", "answer_support",
           "answer_query"]


@dataclasses.dataclass(frozen=True)
class WindowSnapshot:
    """One consistent, immutable view of a mined window.

    ``version`` is the miner's ``window_version`` at mine time; ``itemsets``
    is the store's ``(itemset, support)`` list and ``support_map`` its dict
    form.  Frozen: readers share it freely across threads.
    """

    version: int
    n_txn: int
    itemsets: Tuple[Tuple[Tuple[int, ...], int], ...]
    support_map: Dict[Tuple[int, ...], int]

    @classmethod
    def from_result(cls, result) -> "WindowSnapshot":
        """Snapshot a :class:`~repro.streaming.WindowResult` (host copies)."""
        itemsets = tuple(result.itemsets())
        return cls(version=int(result.version), n_txn=int(result.n_txn),
                   itemsets=itemsets, support_map=dict(itemsets))

    @classmethod
    def empty(cls, version: int = 0) -> "WindowSnapshot":
        return cls(version=int(version), n_txn=0, itemsets=(), support_map={})


# -- answer kernels (shared by the sync adapter and the batched front end) ---

def _sorted_topk(snap: WindowSnapshot, min_len: int,
                 cache: Optional[VersionedCache]):
    """All itemsets of length >= min_len, sorted by (-support, -len, lex);
    cached per (version, min_len) so any k slices the same list."""
    key = ("topk", int(min_len))
    if cache is not None:
        found, value = cache.lookup(snap.version, key)
        if found:
            return value, True
    cand = [(s, it) for it, s in snap.itemsets if len(it) >= min_len]
    cand.sort(key=lambda e: (-e[0], -len(e[1]), e[1]))
    value = [(it, s) for s, it in cand]
    if cache is not None:
        cache.insert(snap.version, key, value)
    return value, False


def answer_topk(snap: WindowSnapshot, k: int = 10, min_len: int = 1,
                cache: Optional[VersionedCache] = None):
    """k most supported frequent itemsets (ties: longer, then lex)."""
    ranked, _ = _sorted_topk(snap, min_len, cache)
    return ranked[:k]


def answer_support(snap: WindowSnapshot, itemset: Sequence[int]) -> int:
    """Support of one itemset over the snapshot window (0 if infrequent)."""
    return snap.support_map.get(tuple(sorted(itemset)), 0)


def _sorted_rules(snap: WindowSnapshot, min_conf: float,
                  cache: Optional[VersionedCache]):
    """Full confidence-ranked rule list, cached per (version, min_conf)."""
    key = ("rules", float(min_conf))
    if cache is not None:
        found, value = cache.lookup(snap.version, key)
        if found:
            return value, True
    value = sorted(generate_rules(snap.support_map, min_conf),
                   key=lambda r: (-r[2], -r[3], r[0], r[1]))
    if cache is not None:
        cache.insert(snap.version, key, value)
    return value, False


def answer_rules(snap: WindowSnapshot, min_conf: float = 0.8,
                 k: Optional[int] = None,
                 cache: Optional[VersionedCache] = None):
    """Most confident association rules over the snapshot window.

    A cache hit at ``k=None`` returns the identical list object (callers
    must not mutate it — the sync adapter's cache-identity test relies on
    it).
    """
    rules, _ = _sorted_rules(snap, min_conf, cache)
    return rules if k is None else rules[:k]


def answer_query(snap: WindowSnapshot, query,
                 cache: Optional[VersionedCache] = None):
    """Dispatch one :class:`~repro.serving.ItemsetQuery` against ``snap``.

    Returns ``(answer, cache_hit)``; unknown kinds raise ``ValueError``
    (same contract as the pre-refactor ``answer_batch``).
    """
    if query.kind == "topk":
        ranked, hit = _sorted_topk(snap, query.min_len, cache)
        return ranked[:query.k], hit
    if query.kind == "rules":
        rules, hit = _sorted_rules(snap, query.min_conf, cache)
        return (rules if query.k is None else rules[:query.k]), hit
    raise ValueError(f"unknown query kind {query.kind!r}")
