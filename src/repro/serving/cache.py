"""Version-keyed answer caches with exact slide invalidation.

The streaming miner stamps every completed slide with a monotonically
increasing ``window_version`` (DESIGN.md §11).  Cached answers (sorted top-k
candidate lists, confidence-ranked rule lists) are stored under
``(query key, version)``: repeated queries between slides return the *same
object* at zero recompute cost, and a slide invalidates **exactly** the
entries built against older windows — entries stamped with the new version
(e.g. a re-mine without a window change) survive untouched.

The data-structure-sensitivity lesson of arXiv:1908.01338 applied to the
query surface: making the cache key (the version) first-class, instead of
clearing a dict on every ingest, is what lets hit/miss/stale accounting be
exact and lets concurrent readers keep hitting a still-valid snapshot while
the writer advances.

Thread-safe; counters are exposed via :meth:`stats` and feed the serving
benchmark's cache-hit-rate column.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Hashable, Optional, Tuple

__all__ = ["VersionedCache"]


class VersionedCache:
    """``key -> (version, value)`` with eager cross-version eviction."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[Hashable, Tuple[int, Any]] = {}
        self.hits = 0
        self.misses = 0
        self.stale = 0          # lookups that found an outdated version
        self.stale_evicted = 0  # entries dropped by advance()

    def lookup(self, version: int, key: Hashable):
        """``(found, value)`` — found only on an exact version match.

        A same-key entry from an older window counts (and is evicted) as
        *stale*, not as a plain miss: it measures how much of the cache a
        slide actually invalidated.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return False, None
            ver, value = entry
            if ver == version:
                self.hits += 1
                return True, value
            del self._entries[key]
            self.stale += 1
            return False, None

    def insert(self, version: int, key: Hashable, value: Any) -> None:
        with self._lock:
            self._entries[key] = (int(version), value)

    def advance(self, version: int) -> int:
        """A new window version was published: evict exactly the entries
        keyed to older versions; returns how many were dropped."""
        with self._lock:
            dead = [k for k, (v, _) in self._entries.items() if v != version]
            for k in dead:
                del self._entries[k]
            self.stale_evicted += len(dead)
            return len(dead)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            lookups = self.hits + self.misses + self.stale
            return {
                "hits": self.hits,
                "misses": self.misses,
                "stale": self.stale,
                "stale_evicted": self.stale_evicted,
                "entries": len(self._entries),
                "hit_rate": self.hits / lookups if lookups else 0.0,
            }
