"""Deterministic query storms + the answer-checksum verification oracle.

Shared by the ``--serve`` drivers (``launch.stream`` / ``launch.serve``) and
``benchmarks/serving_bench.py``: :func:`query_mix` builds a seeded,
heterogeneous query population (top-k probes of wildly different ``k``,
rule scans at several ``min_conf``), :func:`run_storm` fires it from
concurrent client threads at the front end while the miner slides windows
underneath, and :func:`verify_storm` replays every served answer
*synchronously* against the retained snapshot of the exact
``window_version`` it was stamped with — any checksum divergence raises,
which is the bit-identity gate of DESIGN.md §11.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from .admission import QueryShed, ServingFrontend
from .snapshot import answer_query
from .stream_query import ItemsetQuery

__all__ = ["query_mix", "run_storm", "answer_checksum", "verify_storm"]


def query_mix(n_queries: int, seed: int = 0, *,
              rules_frac: float = 0.25,
              ks: Sequence[int] = (1, 5, 20, 100),
              min_lens: Sequence[int] = (1, 2),
              min_confs: Sequence[float] = (0.6, 0.8, 0.9)
              ) -> List[ItemsetQuery]:
    """A seeded heterogeneous query population (deterministic in its args)."""
    rng = np.random.default_rng(seed)
    out = []
    for qid in range(n_queries):
        if rng.random() < rules_frac:
            out.append(ItemsetQuery(
                qid=qid, kind="rules",
                k=int(rng.choice(ks)),
                min_conf=float(rng.choice(min_confs))))
        else:
            out.append(ItemsetQuery(
                qid=qid, kind="topk",
                k=int(rng.choice(ks)),
                min_len=int(rng.choice(min_lens))))
    return out


def run_storm(frontend: ServingFrontend, queries: Sequence[ItemsetQuery],
              n_clients: int = 4, timeout_s: float = 60.0,
              pace_s: float = 0.0) -> dict:
    """Fire ``queries`` at the front end from ``n_clients`` threads.

    Queries are dealt round-robin to clients; each client submits and blocks
    on its ticket (the open-loop arrival process is the admission queue's
    job).  Returns per-query outcomes:
    ``{"answers": {qid: (answer, version)}, "shed": [qid...],
    "errors": {qid: repr}}``.
    """
    answers: Dict[int, tuple] = {}
    shed: List[int] = []
    errors: Dict[int, str] = {}
    lock = threading.Lock()

    def client(cid: int):
        for q in list(queries)[cid::n_clients]:
            try:
                ticket = frontend.submit(q)
                ans, version = ticket.result(timeout=timeout_s)
                with lock:
                    answers[q.qid] = (ans, version)
            except QueryShed:
                with lock:
                    shed.append(q.qid)
            except Exception as e:          # surfaced per query, never hung
                with lock:
                    errors[q.qid] = repr(e)
            if pace_s:
                time.sleep(pace_s)

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=timeout_s + 10.0)
    return {"answers": answers, "shed": sorted(shed), "errors": errors}


def answer_checksum(answer) -> str:
    """Stable content hash of one answer payload (tuples of ints/floats —
    ``repr`` is canonical for them)."""
    return hashlib.sha256(repr(answer).encode()).hexdigest()[:16]


def verify_storm(frontend: ServingFrontend,
                 queries: Sequence[ItemsetQuery],
                 outcome: dict) -> dict:
    """Replay every served answer synchronously at its stamped version.

    For each answered query, the retained :class:`WindowSnapshot` of that
    exact ``window_version`` is queried directly (no cache, no batching)
    and the checksums must match — a divergence means the batched path
    served a torn or wrong-version answer, and raises.  Versions already
    aged out of the history are reported, not silently skipped.
    """
    by_qid = {q.qid: q for q in queries}
    verified = 0
    unverifiable = []
    digest = hashlib.sha256()
    for qid in sorted(outcome["answers"]):
        answer, version = outcome["answers"][qid]
        snap = frontend.snapshot_at(version)
        if snap is None:
            unverifiable.append(qid)
            continue
        direct, _ = answer_query(snap, by_qid[qid], cache=None)
        got, want = answer_checksum(answer), answer_checksum(direct)
        if got != want:
            raise RuntimeError(
                f"serving divergence: qid={qid} at window_version={version} "
                f"answered {got} batched vs {want} direct — the batched "
                f"path is not bit-identical with the synchronous path")
        digest.update(f"{qid}:{version}:{got};".encode())
        verified += 1
    return {"verified": verified,
            "unverifiable": unverifiable,
            "checksum": digest.hexdigest()[:16],
            "identical": True}
