"""Pallas TPU kernel: grouped-query decode attention over a KV cache.

The decode-cell rooflines (EXPERIMENTS §Roofline) are KV-read bound; this
kernel streams the cache once through VMEM in (block_s) tiles with an online
softmax, computing all G query heads of a KV group against each tile — KV
bytes are read exactly once per group instead of once per query head.

    q     : (B, KV, G, D)    one new token, grouped by KV head
    k, v  : (B, S, KV, D)    cache (storage dtype, e.g. bf16)
    length: (B,)             valid prefix of the cache per sequence
    out   : (B, KV, G, D)

Grid = (B, KV, S/block_s) with the sequence dimension innermost/sequential;
m/l/acc scratch persists across sequence tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            sm_scale, window, block_s):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_s, D)
    v = v_ref[0, :, 0].astype(jnp.float32)       # (block_s, D)
    length = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    cols = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    mask = cols < length
    if window:
        mask &= cols > length - 1 - window
    s = jnp.where(mask, s, NEG_INF)              # (G, block_s)

    m_prev = m_scr[...]                          # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "sm_scale", "block_s", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array,
    *,
    window: int = 0,
    sm_scale: float | None = None,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = False,
) -> jax.Array:
    """See module docstring.  S is zero-padded to a block_s multiple."""
    b, kv, g, d = q.shape
    _, s, kv2, d2 = k.shape
    if kv2 != kv or d2 != d or v.shape != k.shape or length.shape != (b,):
        raise ValueError(f"bad shapes q={q.shape} k={k.shape} len={length.shape}")
    if sm_scale is None:
        sm_scale = d ** -0.5
    bs = min(block_s, s)
    pad = (-s) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = k.shape[1]
    grid = (b, kv, sp // bs)

    kernel = functools.partial(_kernel, sm_scale=sm_scale, window=window,
                               block_s=bs)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh, si: (bb,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, si: (bb, hh, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bb, hh, si: (bb, si, hh, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bb, hh, si: (bb, si, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, hh, si: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(length.astype(jnp.int32), q, k, v)
    return out
