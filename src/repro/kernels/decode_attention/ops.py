"""Dispatching wrapper: Pallas decode-attention on TPU, jnp oracle on CPU."""
from __future__ import annotations

import jax

from .decode_attention import decode_attention
from .ref import decode_attention_ref


def grouped_decode_attention(q, k, v, length, *, window=0, sm_scale=None,
                             interpret: bool | None = None):
    if interpret is None:
        if jax.default_backend() == "tpu":
            return decode_attention(q, k, v, length, window=window,
                                    sm_scale=sm_scale)
        return decode_attention_ref(q, k, v, length, window=window,
                                    sm_scale=sm_scale)
    return decode_attention(q, k, v, length, window=window, sm_scale=sm_scale,
                            interpret=interpret)
