from .decode_attention import decode_attention
from .ops import grouped_decode_attention
from .ref import decode_attention_ref

__all__ = ["decode_attention", "grouped_decode_attention", "decode_attention_ref"]
