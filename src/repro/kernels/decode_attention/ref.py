"""Pure-jnp oracle for grouped decode attention (mirrors
repro.models.attention._decode_attend semantics)."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, length, *, window=0, sm_scale=None):
    """q: (B, KV, G, D); k/v: (B, S, KV, D); length: (B,) -> (B, KV, G, D)."""
    b, kv, g, d = q.shape
    s = k.shape[1]
    if sm_scale is None:
        sm_scale = d ** -0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    cols = jnp.arange(s)[None, :]
    mask = cols < length[:, None]
    if window:
        mask &= cols > (length[:, None] - 1 - window)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32)).astype(q.dtype)
