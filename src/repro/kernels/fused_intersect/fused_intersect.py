"""Pallas TPU kernel: fused gather + AND + popcount + min-support mask.

The Eclat hot loop in one ``pallas_call``: for each candidate pair ``q`` the
kernel DMA-gathers the two parent bitmap rows straight out of the frontier
(no materialized ``jnp.take`` copies), intersects them in the mode the miner
is running in, accumulates the per-row popcount across the word grid, and on
the last word block converts the count into a support and compares it against
``min_sup``.  Only the ``(Q,)`` support and mask vectors need to cross back
to the driver; the ``(Q, W)`` intersection stays device-resident for the
survivor compaction.

Modes (match ``repro.core.engine``):
    0  tidset:           inter = a & b,   sup = |inter|
    1  tidset->diffset:  inter = a & ~b,  sup = sup_left - |inter|
    2  diffset:          inter = b & ~a,  sup = sup_left - |inter|

The row gather uses ``PrefetchScalarGridSpec``: the pair-index array is a
scalar-prefetch operand, so the input ``BlockSpec`` index maps read
``idx_ref[0, q]`` / ``idx_ref[1, q]`` and the pipeline prefetches arbitrary
frontier rows.  Grid = (Q, W/bw) with one pair row per grid step — the
gathered rows are not contiguous, so the q dimension cannot be blocked; the
DMA pipeline overlaps the row fetches instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 512

MODE_TIDSET = 0
MODE_TID_TO_DIFF = 1
MODE_DIFFSET = 2


def _kernel(idx_ref, supl_ref, msup_ref, a_ref, b_ref,
            inter_ref, sup_ref, mask_ref, *, mode):
    q = pl.program_id(0)
    wj = pl.program_id(1)
    nw = pl.num_programs(1)
    a = a_ref[...]
    b = b_ref[...]
    if mode == MODE_TIDSET:
        inter = jnp.bitwise_and(a, b)
    elif mode == MODE_TID_TO_DIFF:
        inter = jnp.bitwise_and(a, jnp.bitwise_not(b))
    else:
        inter = jnp.bitwise_and(b, jnp.bitwise_not(a))
    inter_ref[...] = inter
    partial = jax.lax.population_count(inter).astype(jnp.int32).sum()

    @pl.when(wj == 0)
    def _init():
        sup_ref[0] = partial

    @pl.when(wj != 0)
    def _acc():
        sup_ref[0] = sup_ref[0] + partial

    @pl.when(wj == nw - 1)
    def _finish():
        pop = sup_ref[0]
        sup = pop if mode == MODE_TIDSET else supl_ref[q] - pop
        sup_ref[0] = sup
        mask_ref[0] = (sup >= msup_ref[0]).astype(jnp.int32)


def _kernel_partial(idx_ref, a_ref, b_ref, inter_ref, pop_ref, *, mode):
    """Shard-local half of the fused kernel: intersect + accumulate popcount.

    No ``sup_left`` finishing and no min-support mask — on a word-sharded
    frontier each device sees only its word slice, so the popcount here is a
    *partial* count; the caller psums it across shards before thresholding
    (``repro.core.engine.TidShardedEngine``, DESIGN.md §7).
    """
    wj = pl.program_id(1)
    a = a_ref[...]
    b = b_ref[...]
    if mode == MODE_TIDSET:
        inter = jnp.bitwise_and(a, b)
    elif mode == MODE_TID_TO_DIFF:
        inter = jnp.bitwise_and(a, jnp.bitwise_not(b))
    else:
        inter = jnp.bitwise_and(b, jnp.bitwise_not(a))
    inter_ref[...] = inter
    partial = jax.lax.population_count(inter).astype(jnp.int32).sum()

    @pl.when(wj == 0)
    def _init():
        pop_ref[0] = partial

    @pl.when(wj != 0)
    def _acc():
        pop_ref[0] = pop_ref[0] + partial


@functools.partial(
    jax.jit, static_argnames=("mode", "block_w", "interpret")
)
def fused_intersect_partial_pairs(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    *,
    mode: int = MODE_TIDSET,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """(P, W) uint32 frontier shard x (Q,) int32 pair indices ->
    ((Q, W) uint32 intersections, (Q,) int32 partial popcounts).

    The word-sharded counterpart of :func:`fused_intersect_pairs`: it stops
    at the raw popcount (no support conversion, no threshold) because both
    need the *total* count, which only exists after a cross-shard psum.
    """
    if bitmaps.ndim != 2:
        raise ValueError(f"expected (P, W) frontier shard, got {bitmaps.shape}")
    if left.shape != right.shape:
        raise ValueError("left/right must share a (Q,) shape")
    qn = left.shape[0]
    w = bitmaps.shape[1]
    bw = min(block_w, max(w, 1))
    pad_w = (-w) % bw
    if pad_w:
        bitmaps = jnp.pad(bitmaps, ((0, 0), (0, pad_w)))
    wp = bitmaps.shape[1]

    idx = jnp.stack([left.astype(jnp.int32), right.astype(jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qn, wp // bw),
        in_specs=[
            pl.BlockSpec((1, bw), lambda q, j, idx_ref: (idx_ref[0, q], j)),
            pl.BlockSpec((1, bw), lambda q, j, idx_ref: (idx_ref[1, q], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda q, j, *_: (q, j)),
            pl.BlockSpec((1,), lambda q, j, *_: (q,)),
        ],
    )
    inter, pop = pl.pallas_call(
        functools.partial(_kernel_partial, mode=mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, wp), jnp.uint32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(idx, bitmaps, bitmaps)
    return inter[:, :w], pop


@functools.partial(
    jax.jit, static_argnames=("mode", "block_w", "interpret")
)
def fused_intersect_pairs(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup: jax.Array | int,
    *,
    mode: int = MODE_TIDSET,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """(P, W) uint32 frontier x (Q,) int32 pair indices ->
    ((Q, W) uint32 intersections, (Q,) int32 supports, (Q,) int32 mask).

    ``min_sup`` is a traced operand (scalar prefetch), so sweeping the
    threshold does not recompile; only ``mode`` and the block shape do.
    W need not be a multiple of ``block_w``; the frontier is zero-padded
    (zero words contribute zero popcount).
    """
    if bitmaps.ndim != 2:
        raise ValueError(f"expected (P, W) frontier, got {bitmaps.shape}")
    if left.shape != right.shape or left.shape != sup_left.shape:
        raise ValueError("left/right/sup_left must share a (Q,) shape")
    qn = left.shape[0]
    p, w = bitmaps.shape
    bw = min(block_w, max(w, 1))
    pad_w = (-w) % bw
    if pad_w:
        bitmaps = jnp.pad(bitmaps, ((0, 0), (0, pad_w)))
    wp = bitmaps.shape[1]

    idx = jnp.stack([left.astype(jnp.int32), right.astype(jnp.int32)])
    supl = sup_left.astype(jnp.int32)
    msup = jnp.asarray(min_sup, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(qn, wp // bw),
        in_specs=[
            pl.BlockSpec((1, bw), lambda q, j, idx_ref, supl_ref, msup_ref: (idx_ref[0, q], j)),
            pl.BlockSpec((1, bw), lambda q, j, idx_ref, supl_ref, msup_ref: (idx_ref[1, q], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda q, j, *_: (q, j)),
            pl.BlockSpec((1,), lambda q, j, *_: (q,)),
            pl.BlockSpec((1,), lambda q, j, *_: (q,)),
        ],
    )
    inter, sup, mask = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, wp), jnp.uint32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(idx, supl, msup, bitmaps, bitmaps)
    return inter[:, :w], sup, mask
