"""Pallas TPU kernel: fused gather + AND + popcount + min-support mask.

The Eclat hot loop in one ``pallas_call``: for each candidate pair ``q`` the
kernel DMA-gathers the two parent bitmap rows straight out of the frontier
(no materialized ``jnp.take`` copies), intersects them in the mode the miner
is running in, accumulates the per-row popcount across the word grid, and on
the last word block converts the count into a support and compares it against
``min_sup``.  Only the ``(Q,)`` support and mask vectors need to cross back
to the driver; the ``(Q, W)`` intersection stays device-resident for the
survivor compaction.

Modes (match ``repro.core.engine``):
    0  tidset:           inter = a & b,   sup = |inter|
    1  tidset->diffset:  inter = a & ~b,  sup = sup_left - |inter|
    2  diffset:          inter = b & ~a,  sup = sup_left - |inter|

Raw-speed structure (ISSUE 7 / ROADMAP item 2):

* **Scalar-prefetch row gather, double-buffered.**  The pair-index array is
  a scalar-prefetch operand (``PrefetchScalarGridSpec``), so the input
  ``BlockSpec`` index maps read ``idx_ref[0, q]`` / ``idx_ref[1, q]`` and
  the Mosaic pipeline issues the row DMAs from the prefetched indices.  The
  grid is (Q, W/bw) with the word axis innermost and the two parent rows as
  *separate* operands: the pipeline keeps two buffers in flight per operand,
  so the gather of step ``(q, j+1)`` (and of the next pair's first block)
  overlaps the AND+popcount of step ``(q, j)``.  The q dimension cannot be
  blocked — gathered rows are not contiguous — so overlap, not blocking, is
  what hides the gather.
* **Lane-aligned popcount accumulation.**  Block widths are rounded to the
  VPU lane width (128); the running popcount is carried as a ``(1, 128)``
  per-lane partial vector in VMEM scratch and only collapsed to a scalar on
  the last word block.  Accumulating per-lane keeps every grid step a pure
  element-wise VPU op (AND, popcount, add) with no cross-lane reduction in
  the loop body.
* **Survivor compaction in the fused executable.**  The ``*_compact``
  variants append a prefix-sum survivor compaction (mask -> ascending
  survivor indices -> row gather) to the kernel epilogue inside the same
  jit, so one dispatch returns the min-sup mask, supports, *and* the
  survivor-compacted block — the engine no longer round-trips the mask to
  the host before launching a second gather dispatch, and only survivor
  rows are live downstream (DESIGN.md §3, §6).

``block_w`` is no longer a single hard-coded constant: callers that pass
``None`` to the ``ops`` dispatch layer get the autotuned width for their
(Q, W, mode) shape class (``repro.kernels.autotune``); ``DEFAULT_BLOCK_W``
remains the seed/fallback value only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 512
LANE = 128                      # VPU lane width: all block widths are 128-multiples

MODE_TIDSET = 0
MODE_TID_TO_DIFF = 1
MODE_DIFFSET = 2


def round_up_lanes(n: int) -> int:
    """Smallest 128-multiple >= n (>= 128): the lane-aligned word width."""
    return max((int(n) + LANE - 1) // LANE * LANE, LANE)


def _resolve_block_w(w: int, block_w: int) -> int:
    """Lane-align a requested tile width and cap it at the (lane-padded)
    row width — a wider block than the row would only stream zeros."""
    return min(round_up_lanes(block_w), round_up_lanes(w))


def _intersect(a, b, mode):
    if mode == MODE_TIDSET:
        return jnp.bitwise_and(a, b)
    if mode == MODE_TID_TO_DIFF:
        return jnp.bitwise_and(a, jnp.bitwise_not(b))
    return jnp.bitwise_and(b, jnp.bitwise_not(a))


def _lane_popcount(inter) -> jax.Array:
    """(1, bw) uint32 block -> (1, LANE) int32 per-lane popcount partials.
    Pure VPU work: popcount, a sublane-folding reshape, and an add-reduce
    that never crosses lanes."""
    pc = jax.lax.population_count(inter).astype(jnp.int32)
    return pc.reshape(-1, LANE).sum(axis=0, keepdims=True)


def _kernel(idx_ref, supl_ref, msup_ref, a_ref, b_ref,
            inter_ref, sup_ref, mask_ref, acc_ref, *, mode):
    q = pl.program_id(0)
    wj = pl.program_id(1)
    nw = pl.num_programs(1)
    inter = _intersect(a_ref[...], b_ref[...], mode)
    inter_ref[...] = inter
    lanes = _lane_popcount(inter)

    @pl.when(wj == 0)
    def _init():
        acc_ref[...] = lanes

    @pl.when(wj != 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + lanes

    @pl.when(wj == nw - 1)
    def _finish():
        pop = acc_ref[...].sum()
        sup = pop if mode == MODE_TIDSET else supl_ref[q] - pop
        sup_ref[0] = sup
        mask_ref[0] = (sup >= msup_ref[0]).astype(jnp.int32)


def _kernel_partial(idx_ref, a_ref, b_ref, inter_ref, pop_ref, acc_ref, *,
                    mode):
    """Shard-local half of the fused kernel: intersect + accumulate popcount.

    No ``sup_left`` finishing and no min-support mask — on a word-sharded
    frontier each device sees only its word slice, so the popcount here is a
    *partial* count; the caller psums it across shards before thresholding
    (``repro.core.engine.TidShardedEngine``, DESIGN.md §7).
    """
    wj = pl.program_id(1)
    nw = pl.num_programs(1)
    inter = _intersect(a_ref[...], b_ref[...], mode)
    inter_ref[...] = inter
    lanes = _lane_popcount(inter)

    @pl.when(wj == 0)
    def _init():
        acc_ref[...] = lanes

    @pl.when(wj != 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + lanes

    @pl.when(wj == nw - 1)
    def _finish():
        pop_ref[0] = acc_ref[...].sum()


def _pad_words(bitmaps: jax.Array, bw: int) -> jax.Array:
    pad_w = (-bitmaps.shape[1]) % bw
    if pad_w:
        bitmaps = jnp.pad(bitmaps, ((0, 0), (0, pad_w)))
    return bitmaps


@functools.partial(
    jax.jit, static_argnames=("mode", "block_w", "interpret")
)
def fused_intersect_partial_pairs(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    *,
    mode: int = MODE_TIDSET,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """(P, W) uint32 frontier shard x (Q,) int32 pair indices ->
    ((Q, W) uint32 intersections, (Q,) int32 partial popcounts).

    The word-sharded counterpart of :func:`fused_intersect_pairs`: it stops
    at the raw popcount (no support conversion, no threshold) because both
    need the *total* count, which only exists after a cross-shard psum.
    """
    if bitmaps.ndim != 2:
        raise ValueError(f"expected (P, W) frontier shard, got {bitmaps.shape}")
    if left.shape != right.shape:
        raise ValueError("left/right must share a (Q,) shape")
    qn = left.shape[0]
    w = bitmaps.shape[1]
    bw = _resolve_block_w(w, block_w)
    bitmaps = _pad_words(bitmaps, bw)
    wp = bitmaps.shape[1]

    idx = jnp.stack([left.astype(jnp.int32), right.astype(jnp.int32)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qn, wp // bw),
        in_specs=[
            pl.BlockSpec((1, bw), lambda q, j, idx_ref: (idx_ref[0, q], j)),
            pl.BlockSpec((1, bw), lambda q, j, idx_ref: (idx_ref[1, q], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda q, j, *_: (q, j)),
            pl.BlockSpec((1,), lambda q, j, *_: (q,)),
        ],
        scratch_shapes=[pltpu.VMEM((1, LANE), jnp.int32)],
    )
    inter, pop = pl.pallas_call(
        functools.partial(_kernel_partial, mode=mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, wp), jnp.uint32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(idx, bitmaps, bitmaps)
    return inter[:, :w], pop


def _fused_pairs_call(bitmaps, left, right, sup_left, min_sup, *, mode,
                      block_w, interpret):
    """Shared core of the fused kernel call: validate, lane-pad, launch.
    Returns the *word-padded* intersection block plus (Q,) supports/mask —
    the public wrappers slice (plain) or compact (``*_compact``) it."""
    if bitmaps.ndim != 2:
        raise ValueError(f"expected (P, W) frontier, got {bitmaps.shape}")
    if left.shape != right.shape or left.shape != sup_left.shape:
        raise ValueError("left/right/sup_left must share a (Q,) shape")
    qn = left.shape[0]
    w = bitmaps.shape[1]
    bw = _resolve_block_w(w, block_w)
    bitmaps = _pad_words(bitmaps, bw)
    wp = bitmaps.shape[1]

    idx = jnp.stack([left.astype(jnp.int32), right.astype(jnp.int32)])
    supl = sup_left.astype(jnp.int32)
    msup = jnp.asarray(min_sup, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(qn, wp // bw),
        in_specs=[
            pl.BlockSpec((1, bw), lambda q, j, idx_ref, supl_ref, msup_ref: (idx_ref[0, q], j)),
            pl.BlockSpec((1, bw), lambda q, j, idx_ref, supl_ref, msup_ref: (idx_ref[1, q], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda q, j, *_: (q, j)),
            pl.BlockSpec((1,), lambda q, j, *_: (q,)),
            pl.BlockSpec((1,), lambda q, j, *_: (q,)),
        ],
        scratch_shapes=[pltpu.VMEM((1, LANE), jnp.int32)],
    )
    inter, sup, mask = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, wp), jnp.uint32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
            jax.ShapeDtypeStruct((qn,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(idx, supl, msup, bitmaps, bitmaps)
    return inter, sup, mask, w


@functools.partial(
    jax.jit, static_argnames=("mode", "block_w", "interpret")
)
def fused_intersect_pairs(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup: jax.Array | int,
    *,
    mode: int = MODE_TIDSET,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """(P, W) uint32 frontier x (Q,) int32 pair indices ->
    ((Q, W) uint32 intersections, (Q,) int32 supports, (Q,) int32 mask).

    ``min_sup`` is a traced operand (scalar prefetch), so sweeping the
    threshold does not recompile; only ``mode`` and the block shape do.
    W need not be a multiple of ``block_w``; the frontier is zero-padded
    (zero words contribute zero popcount).
    """
    inter, sup, mask, w = _fused_pairs_call(
        bitmaps, left, right, sup_left, min_sup,
        mode=mode, block_w=block_w, interpret=interpret)
    return inter[:, :w], sup, mask


def compact_epilogue(inter: jax.Array, sup: jax.Array, mask: jax.Array,
                     n_valid: jax.Array | int):
    """Fold the min-sup mask + a prefix-sum survivor scatter into the fused
    executable: ``(Q, Wp)`` intersections + ``(Q,)`` mask -> ``(Q, Wp)``
    block whose rows ``[:S]`` are the survivors in ascending pair order
    (rows ``[S:]`` duplicate row 0 — the engine's rung-padding convention)
    plus the survivor count ``S``.

    ``n_valid`` masks out the bucket-ladder pad pairs (a padded ``(0, 0)``
    self-pair can clear any threshold), traced so the valid count never
    recompiles.  ``jnp.nonzero(size=Q)`` *is* the prefix-sum scatter:
    XLA lowers it to cumsum + scatter with a static output shape, so the
    whole mask->compact path stays inside one dispatch and the full block
    never needs a host round-trip before compaction.
    """
    q = mask.shape[0]
    valid = jnp.arange(q, dtype=jnp.int32) < jnp.asarray(n_valid, jnp.int32)
    m = (mask != 0) & valid
    sel = jnp.nonzero(m, size=q, fill_value=0)[0]
    compact = jnp.take(inter, sel, axis=0)
    return compact, sup, m.astype(jnp.int32), m.sum(dtype=jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_w", "interpret")
)
def fused_intersect_compact_pairs(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup: jax.Array | int,
    n_valid: jax.Array | int,
    *,
    mode: int = MODE_TIDSET,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """:func:`fused_intersect_pairs` with in-executable survivor compaction:
    one dispatch returns ``(compact (Q, W), sup (Q,), mask (Q,), n_surv)``
    where ``compact[:n_surv]`` are the surviving intersections in ascending
    pair order.  Pairs at positions >= ``n_valid`` are bucket padding and
    never survive.  The engine reads the mask once and slices the compacted
    block to its survivor rung — no second gather dispatch, no index upload
    (DESIGN.md §3)."""
    inter, sup, mask, w = _fused_pairs_call(
        bitmaps, left, right, sup_left, min_sup,
        mode=mode, block_w=block_w, interpret=interpret)
    compact, sup, mask, n_surv = compact_epilogue(inter, sup, mask, n_valid)
    return compact[:, :w], sup, mask, n_surv
