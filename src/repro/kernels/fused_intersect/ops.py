"""Dispatching wrapper: fused Pallas kernel on TPU, fused jnp path elsewhere.

``repro.core.engine`` routes the pallas backend's pair batches through here,
so the hot loop is kernel-backed on real hardware while staying exact (and a
single fused XLA computation) on the CPU host used for tests/benchmarks.
"""
from __future__ import annotations

import jax

from .fused_intersect import fused_intersect_pairs
from .ref import fused_intersect_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_intersect(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup,
    *,
    mode: int,
    interpret: bool | None = None,
):
    """Fused gather+AND+popcount+mask.  See kernel docstring for tiling."""
    if interpret is None:
        if _on_tpu():
            return fused_intersect_pairs(bitmaps, left, right, sup_left,
                                         min_sup, mode=mode)
        return fused_intersect_ref(bitmaps, left, right, sup_left,
                                   min_sup, mode=mode)
    return fused_intersect_pairs(bitmaps, left, right, sup_left, min_sup,
                                 mode=mode, interpret=interpret)
