"""Dispatching wrapper: fused Pallas kernel on TPU, fused jnp path elsewhere.

``repro.core.engine`` routes the pallas backend's pair batches through here,
so the hot loop is kernel-backed on real hardware while staying exact (and a
single fused XLA computation) on the CPU host used for tests/benchmarks.
"""
from __future__ import annotations

import jax

from .fused_intersect import (fused_intersect_pairs,
                              fused_intersect_partial_pairs)
from .ref import fused_intersect_partial_ref, fused_intersect_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_intersect_partial(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    *,
    mode: int,
    interpret: bool | None = None,
):
    """Shard-local fused gather+AND+popcount (no threshold); see the partial
    kernel docstring.  Dispatch mirrors :func:`fused_intersect`."""
    if interpret is None:
        if _on_tpu():
            return fused_intersect_partial_pairs(bitmaps, left, right,
                                                 mode=mode)
        return fused_intersect_partial_ref(bitmaps, left, right, mode=mode)
    return fused_intersect_partial_pairs(bitmaps, left, right, mode=mode,
                                         interpret=interpret)


def fused_intersect(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup,
    *,
    mode: int,
    interpret: bool | None = None,
):
    """Fused gather+AND+popcount+mask.  See kernel docstring for tiling."""
    if interpret is None:
        if _on_tpu():
            return fused_intersect_pairs(bitmaps, left, right, sup_left,
                                         min_sup, mode=mode)
        return fused_intersect_ref(bitmaps, left, right, sup_left,
                                   min_sup, mode=mode)
    return fused_intersect_pairs(bitmaps, left, right, sup_left, min_sup,
                                 mode=mode, interpret=interpret)
