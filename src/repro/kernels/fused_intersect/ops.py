"""Dispatching wrapper: fused Pallas kernel on TPU, fused jnp path elsewhere.

``repro.core.engine`` routes the pallas backend's pair batches through here,
so the hot loop is kernel-backed on real hardware while staying exact (and a
single fused XLA computation) on the CPU host used for tests/benchmarks.

``block_w`` resolution: ``None`` (the default everywhere above this layer)
consults the autotuned shape->config table (``repro.kernels.autotune``) at
trace time, so tuned tile widths reach every call site — including the
shard_map-wrapped partial kernels, whose bodies trace through here — without
threading a width through every driver.  An explicit ``block_w`` (config /
CLI override) wins over the table.
"""
from __future__ import annotations

import jax

from .fused_intersect import (DEFAULT_BLOCK_W, fused_intersect_compact_pairs,
                              fused_intersect_pairs,
                              fused_intersect_partial_pairs)
from .ref import (fused_intersect_compact_ref, fused_intersect_partial_ref,
                  fused_intersect_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_block_w(block_w, q: int, w: int, mode: int) -> int:
    """Explicit width if given, else the autotuned (or cost-model-seeded)
    width for this call's shape class."""
    if block_w is not None:
        return int(block_w)
    from .. import autotune
    return autotune.lookup(q, w, mode).block_w


def fused_intersect_partial(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    *,
    mode: int,
    block_w: int | None = None,
    interpret: bool | None = None,
):
    """Shard-local fused gather+AND+popcount (no threshold); see the partial
    kernel docstring.  Dispatch mirrors :func:`fused_intersect`."""
    bw = resolve_block_w(block_w, left.shape[0], bitmaps.shape[1], mode)
    if interpret is None:
        if _on_tpu():
            return fused_intersect_partial_pairs(bitmaps, left, right,
                                                 mode=mode, block_w=bw)
        return fused_intersect_partial_ref(bitmaps, left, right, mode=mode)
    return fused_intersect_partial_pairs(bitmaps, left, right, mode=mode,
                                         block_w=bw, interpret=interpret)


def fused_intersect(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup,
    *,
    mode: int,
    block_w: int | None = None,
    interpret: bool | None = None,
):
    """Fused gather+AND+popcount+mask.  See kernel docstring for tiling."""
    bw = resolve_block_w(block_w, left.shape[0], bitmaps.shape[1], mode)
    if interpret is None:
        if _on_tpu():
            return fused_intersect_pairs(bitmaps, left, right, sup_left,
                                         min_sup, mode=mode, block_w=bw)
        return fused_intersect_ref(bitmaps, left, right, sup_left,
                                   min_sup, mode=mode)
    return fused_intersect_pairs(bitmaps, left, right, sup_left, min_sup,
                                 mode=mode, block_w=bw, interpret=interpret)


def fused_intersect_compact(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup,
    n_valid,
    *,
    mode: int,
    block_w: int | None = None,
    interpret: bool | None = None,
):
    """Fused gather+AND+popcount+mask with the survivor-compaction epilogue
    in the same executable: returns ``(compact, sup, mask, n_surv)`` —
    ``compact[:n_surv]`` are the surviving rows in ascending pair order
    (pairs >= ``n_valid`` are bucket padding and excluded).  Dispatch
    mirrors :func:`fused_intersect`."""
    bw = resolve_block_w(block_w, left.shape[0], bitmaps.shape[1], mode)
    if interpret is None:
        if _on_tpu():
            return fused_intersect_compact_pairs(
                bitmaps, left, right, sup_left, min_sup, n_valid,
                mode=mode, block_w=bw)
        return fused_intersect_compact_ref(bitmaps, left, right, sup_left,
                                           min_sup, n_valid, mode=mode)
    return fused_intersect_compact_pairs(
        bitmaps, left, right, sup_left, min_sup, n_valid,
        mode=mode, block_w=bw, interpret=interpret)
