"""Pure-jnp oracle for the fused gather-AND-popcount-mask kernel.

Same contract as :func:`fused_intersect_pairs` (one XLA-fused jit, so it is
also the production path on non-TPU backends): gather both parent rows,
intersect in the requested mode, count supports, compare against ``min_sup``.
``min_sup`` is traced — threshold sweeps hit the same executable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .fused_intersect import (MODE_DIFFSET, MODE_TID_TO_DIFF, MODE_TIDSET,
                              compact_epilogue)


@functools.partial(jax.jit, static_argnames=("mode",))
def fused_intersect_partial_ref(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    *,
    mode: int = MODE_TIDSET,
):
    """(P, W) shard x (Q,) -> ((Q, W) uint32, (Q,) int32 partial popcount).

    Oracle for the word-sharded partial kernel: intersect the shard, count
    its bits, and stop — support conversion and thresholding happen after
    the caller's cross-shard psum (DESIGN.md §7).
    """
    a = jnp.take(bitmaps, left.astype(jnp.int32), axis=0)
    b = jnp.take(bitmaps, right.astype(jnp.int32), axis=0)
    if mode == MODE_TIDSET:
        inter = jnp.bitwise_and(a, b)
    elif mode == MODE_TID_TO_DIFF:
        inter = jnp.bitwise_and(a, jnp.bitwise_not(b))
    elif mode == MODE_DIFFSET:
        inter = jnp.bitwise_and(b, jnp.bitwise_not(a))
    else:
        raise ValueError(f"unknown mode {mode}")
    pop = jax.lax.population_count(inter).astype(jnp.int32).sum(-1)
    return inter, pop


@functools.partial(jax.jit, static_argnames=("mode",))
def fused_intersect_ref(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup: jax.Array | int,
    *,
    mode: int = MODE_TIDSET,
):
    """(P, W) x (Q,) -> ((Q, W) uint32, (Q,) int32 sup, (Q,) int32 mask)."""
    a = jnp.take(bitmaps, left.astype(jnp.int32), axis=0)
    b = jnp.take(bitmaps, right.astype(jnp.int32), axis=0)
    if mode == MODE_TIDSET:
        inter = jnp.bitwise_and(a, b)
    elif mode == MODE_TID_TO_DIFF:
        inter = jnp.bitwise_and(a, jnp.bitwise_not(b))
    elif mode == MODE_DIFFSET:
        inter = jnp.bitwise_and(b, jnp.bitwise_not(a))
    else:
        raise ValueError(f"unknown mode {mode}")
    pop = jax.lax.population_count(inter).astype(jnp.int32).sum(-1)
    sup = pop if mode == MODE_TIDSET else sup_left.astype(jnp.int32) - pop
    mask = (sup >= jnp.asarray(min_sup, jnp.int32)).astype(jnp.int32)
    return inter, sup, mask


@functools.partial(jax.jit, static_argnames=("mode",))
def fused_intersect_compact_ref(
    bitmaps: jax.Array,
    left: jax.Array,
    right: jax.Array,
    sup_left: jax.Array,
    min_sup: jax.Array | int,
    n_valid: jax.Array | int,
    *,
    mode: int = MODE_TIDSET,
):
    """Oracle for the compacting variant: the fused intersect/threshold pass
    plus the same prefix-sum survivor compaction epilogue
    (:func:`..fused_intersect.compact_epilogue`) in one jit — returns
    ``(compact (Q, W), sup (Q,), mask (Q,), n_surv)`` with survivors in
    ascending pair order and pad rows duplicating row 0.  This is also the
    production path on non-TPU backends: one fused XLA executable instead
    of intersect-dispatch -> host mask -> gather-dispatch."""
    inter, sup, mask = fused_intersect_ref(bitmaps, left, right, sup_left,
                                           min_sup, mode=mode)
    return compact_epilogue(inter, sup, mask, n_valid)
