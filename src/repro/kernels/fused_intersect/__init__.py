from .fused_intersect import (MODE_DIFFSET, MODE_TID_TO_DIFF, MODE_TIDSET,
                              fused_intersect_pairs)
from .ops import fused_intersect
from .ref import fused_intersect_ref

__all__ = [
    "MODE_TIDSET", "MODE_TID_TO_DIFF", "MODE_DIFFSET",
    "fused_intersect", "fused_intersect_pairs", "fused_intersect_ref",
]
