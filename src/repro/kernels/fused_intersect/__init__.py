from .fused_intersect import (DEFAULT_BLOCK_W, LANE, MODE_DIFFSET,
                              MODE_TID_TO_DIFF, MODE_TIDSET, compact_epilogue,
                              fused_intersect_compact_pairs,
                              fused_intersect_pairs,
                              fused_intersect_partial_pairs, round_up_lanes)
from .ops import (fused_intersect, fused_intersect_compact,
                  fused_intersect_partial, resolve_block_w)
from .ref import (fused_intersect_compact_ref, fused_intersect_partial_ref,
                  fused_intersect_ref)

__all__ = [
    "MODE_TIDSET", "MODE_TID_TO_DIFF", "MODE_DIFFSET",
    "DEFAULT_BLOCK_W", "LANE", "round_up_lanes", "resolve_block_w",
    "compact_epilogue",
    "fused_intersect", "fused_intersect_pairs", "fused_intersect_ref",
    "fused_intersect_compact", "fused_intersect_compact_pairs",
    "fused_intersect_compact_ref",
    "fused_intersect_partial", "fused_intersect_partial_pairs",
    "fused_intersect_partial_ref",
]
