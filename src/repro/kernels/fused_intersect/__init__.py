from .fused_intersect import (MODE_DIFFSET, MODE_TID_TO_DIFF, MODE_TIDSET,
                              fused_intersect_pairs,
                              fused_intersect_partial_pairs)
from .ops import fused_intersect, fused_intersect_partial
from .ref import fused_intersect_partial_ref, fused_intersect_ref

__all__ = [
    "MODE_TIDSET", "MODE_TID_TO_DIFF", "MODE_DIFFSET",
    "fused_intersect", "fused_intersect_pairs", "fused_intersect_ref",
    "fused_intersect_partial", "fused_intersect_partial_pairs",
    "fused_intersect_partial_ref",
]
