"""repro.kernels — Pallas TPU kernels for the framework's compute hot-spots.

fused_intersect  : fused gather + AND + popcount + min-support mask (the
                   Eclat hot loop; backs ``core.engine``'s pallas backend)
popcount_support : tidset AND + support counting (paper Algorithm-1 inner loop)
decode_attention : grouped GQA decode over the KV cache (serving hot-spot)
trimatrix        : 2-itemset triangular-matrix co-occurrence (paper Phase-2)
flash_attention  : tiled online-softmax attention (LM substrate prefill)

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (dispatching
jit wrapper), ref.py (pure-jnp oracle).  Kernels are TPU-target; on this CPU
container they are validated in interpret mode against the oracles.
"""
from . import (decode_attention, flash_attention, fused_intersect,
               popcount_support, trimatrix)

__all__ = ["decode_attention", "flash_attention", "fused_intersect",
           "popcount_support", "trimatrix"]
