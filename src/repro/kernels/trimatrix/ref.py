"""Pure-jnp oracles for the trimatrix kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trimatrix_ref(bitmaps: jax.Array) -> jax.Array:
    """(N, W) uint32 -> (N, N) int32 popcount co-occurrence (packed form)."""
    inter = jnp.bitwise_and(bitmaps[:, None, :], bitmaps[None, :, :])
    return jax.lax.population_count(inter).astype(jnp.int32).sum(axis=-1)


def cooccurrence_mxu_ref(bitmaps: jax.Array, n_txn: int) -> jax.Array:
    """The MXU alternative: unpack bits to {0,1} and use a real matmul.

    C = D @ D.T with D the (N, n_txn) dense indicator — numerically identical,
    32x more bytes moved per word but systolic-array compute.  Which path wins
    on TPU depends on W vs the MXU's effective throughput; both are exposed so
    the benchmark can make the call per dataset.
    """
    n, w = bitmaps.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmaps[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    dense = bits.reshape(n, w * 32)[:, :n_txn].astype(jnp.float32)
    return (dense @ dense.T).astype(jnp.int32)
