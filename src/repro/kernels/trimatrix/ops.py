"""Dispatching wrapper for the trimatrix kernel (TPU) / blocked jnp (CPU)."""
from __future__ import annotations

import jax

from .trimatrix import trimatrix
from .ref import trimatrix_ref


def cooccurrence(bitmaps: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        if jax.default_backend() == "tpu":
            return trimatrix(bitmaps)
        # CPU path: repro.core.triangular's blocked jnp version is used by the
        # driver directly; this fallback exists for API completeness.
        return trimatrix_ref(bitmaps)
    return trimatrix(bitmaps, interpret=interpret)
