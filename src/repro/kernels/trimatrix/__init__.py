from .ops import cooccurrence
from .trimatrix import trimatrix
from .ref import trimatrix_ref, cooccurrence_mxu_ref

__all__ = ["cooccurrence", "trimatrix", "trimatrix_ref", "cooccurrence_mxu_ref"]
