"""Pallas TPU kernel: 2-itemset triangular-matrix counting (paper Phase-2).

Co-occurrence counts over the packed vertical bitmap:

    C[i, j] = sum_w popcount(B[i, w] & B[j, w])

The paper streams the horizontal DB through a Spark accumulator; on TPU the
whole matrix is one blocked popcount-product.  Grid = (N/bn, N/bn, W/bw) with
the W dimension innermost/sequential: each step broadcasts a (bn, bw) row
tile against a (bn, bw) column-row tile, popcounts the (bn, bn, bw) AND, and
accumulates into the (bn, bn) C tile held in VMEM.

Keeping the bitmap packed trades the MXU (which an int8 unpacked `B @ B.T`
would use) for 32x less VMEM traffic per word — the right trade for wide
transaction databases where the product is memory-bound; the unpacked MXU
variant is `ref.cooccurrence_mxu_ref` and benchmarked in benchmarks/fim_kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_W = 128


def _kernel(rows_ref, cols_ref, c_ref):
    w_idx = pl.program_id(2)
    a = rows_ref[...]          # (bn, bw)
    b = cols_ref[...]          # (bn, bw)
    inter = jnp.bitwise_and(a[:, None, :], b[None, :, :])      # (bn, bn, bw)
    partial = jax.lax.population_count(inter).astype(jnp.int32).sum(axis=-1)

    @pl.when(w_idx == 0)
    def _init():
        c_ref[...] = partial

    @pl.when(w_idx != 0)
    def _acc():
        c_ref[...] = c_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("block_n", "block_w", "interpret"))
def trimatrix(
    bitmaps: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
) -> jax.Array:
    """(N, W) uint32 packed bitmap -> (N, N) int32 co-occurrence counts.

    The full square matrix is produced (C is symmetric; the driver reads the
    upper triangle, matching the paper's triangular-matrix storage).
    """
    if bitmaps.ndim != 2:
        raise ValueError(f"expected (N, W), got {bitmaps.shape}")
    n, w = bitmaps.shape
    bn = min(block_n, max(n, 1))
    bw = min(block_w, max(w, 1))
    pad_n = (-n) % bn
    pad_w = (-w) % bw
    x = jnp.pad(bitmaps, ((0, pad_n), (0, pad_w))) if (pad_n or pad_w) else bitmaps
    np_, wp = x.shape
    grid = (np_ // bn, np_ // bn, wp // bw)

    c = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(x, x)
    return c[:n, :n]
