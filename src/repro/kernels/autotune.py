"""Autotuned tile configs for the fused-intersect kernel.

``DEFAULT_BLOCK_W = 512`` was a guess; the right tile width for the
gather+AND+popcount loop depends on the frontier width (how many word
blocks a row spans), the pair count (how much pipeline there is to fill),
and the backend actually executing (Mosaic kernel on TPU, fused XLA
elsewhere).  This module makes it a measured decision:

1.  **Shape classes.**  Expansions are bucketed by the same power-of-two
    ladders the engine already pads to (``q`` rung, ``w`` rung, mode,
    executing backend), so one tuned entry covers every call that compiles
    to the same executable.
2.  **Cost-model seeding.**  Candidate widths are lane-aligned
    (128-multiples) and *ordered* by ``analysis.roofline.intersect_cost``
    — the compute-vs-HBM model of the loop — so measurement starts from
    the predicted winner and the sweep can be truncated without losing it.
3.  **Measurement, then cache.**  Each candidate is timed steady-state
    (compile excluded, ``block_until_ready`` inside the timed region) on
    synthetic data of the class shape; the winner lands in a persistent
    JSON table (``REPRO_AUTOTUNE_CACHE`` or
    ``~/.cache/repro-eclat/autotune.json``) keyed by shape class.
4.  **Lookup at trace time.**  ``repro.kernels.fused_intersect.ops``
    resolves ``block_w=None`` through :func:`lookup`; the table read is a
    host-side dict hit during tracing, so tuned widths reach every backend
    — including the shard_map-wrapped partial kernels — with zero traced
    overhead.

Off-TPU (this CPU container) the non-interpret fused path is the XLA ref,
which has no tile parameter — ``candidates`` collapses to the single
lane-padded width and the measured decision reduces to the in-executable
compaction on/off choice the engine exposes.  The sweep still runs under
``interpret=True`` in tests to pin the mechanics.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..analysis.roofline import intersect_cost
from .fused_intersect.fused_intersect import (DEFAULT_BLOCK_W, MODE_TIDSET,
                                              round_up_lanes)

__all__ = ["KernelConfig", "shape_class", "block_w_candidates",
           "seeded_candidates", "AutotuneTable", "table_path", "load_table",
           "lookup", "tune_shape", "reset", "DEFAULT_BLOCK_W"]

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_CACHE = os.path.join("~", ".cache", "repro-eclat", "autotune.json")

# candidate tile widths: every lane-aligned power of two the pipeline can
# reasonably hold double-buffered in VMEM ((1, bw) uint32 blocks x 2 rows
# x 2 buffers -> 8 KiB/lane-k at bw=2048)
_POW2_CANDIDATES = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One tuned kernel configuration for a shape class.

    ``block_w``: word-tile width of the fused kernel (lane-aligned).
    ``compact``: run the survivor-compaction epilogue inside the fused
    executable (one dispatch) instead of the legacy mask-roundtrip +
    separate gather (two dispatches).
    """

    block_w: int = DEFAULT_BLOCK_W
    compact: bool = True

    def to_dict(self) -> dict:
        return {"block_w": int(self.block_w), "compact": bool(self.compact)}

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        return cls(block_w=int(d.get("block_w", DEFAULT_BLOCK_W)),
                   compact=bool(d.get("compact", True)))


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < max(int(n), 1):
        b <<= 1
    return b


def shape_class(q: int, w: int, mode: int = MODE_TIDSET,
                kind: Optional[str] = None) -> str:
    """Stable key for 'calls that hit the same executable': power-of-two
    rungs of the pair count and the lane-padded word width, the intersect
    mode, and the executing path (``tpu`` Mosaic / ``xla`` fused ref /
    ``interpret``)."""
    if kind is None:
        kind = "tpu" if jax.default_backend() == "tpu" else "xla"
    return (f"q{_pow2_bucket(q)}_w{_pow2_bucket(round_up_lanes(w))}"
            f"_m{int(mode)}_{kind}")


def block_w_candidates(w: int, kind: Optional[str] = None) -> List[int]:
    """Lane-aligned candidate tile widths for a row of ``w`` words: the
    power-of-two ladder capped at the lane-padded row width, plus the
    padded width itself (the single-block tile).  Off-TPU the fused XLA
    path has no tile parameter, so the list collapses to the one padded
    width — a tuner must not pretend to sweep a knob the executable does
    not have."""
    if kind is None:
        kind = "tpu" if jax.default_backend() == "tpu" else "xla"
    wp = round_up_lanes(w)
    if kind == "xla":
        return [min(DEFAULT_BLOCK_W, wp)]
    cands = sorted({c for c in _POW2_CANDIDATES if c <= wp} | {wp})
    return cands


def seeded_candidates(q: int, w: int,
                      kind: Optional[str] = None) -> List[int]:
    """Candidates ordered by the roofline cost model (best predicted
    first): ``intersect_cost`` charges per-block-step overhead (penalizing
    tiny tiles) and padded-word streaming (penalizing over-wide tiles on
    narrow rows), so the predicted winner leads the measured sweep."""
    cands = block_w_candidates(w, kind)
    return sorted(cands, key=lambda bw: intersect_cost(q, w, bw).bound_s)


# ---------------------------------------------------------------------------
# persistent shape -> config table
# ---------------------------------------------------------------------------

class AutotuneTable:
    """Shape-class -> :class:`KernelConfig` map with JSON persistence.

    Entries carry provenance (``source``: measured / seeded / manual) and
    the measured steady-state seconds, so a bench artifact can report not
    just the winner but the margin."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, dict] = {}

    def get(self, key: str) -> Optional[KernelConfig]:
        e = self.entries.get(key)
        return KernelConfig.from_dict(e) if e is not None else None

    def put(self, key: str, config: KernelConfig, *,
            measured_s: Optional[float] = None,
            source: str = "measured") -> None:
        self.entries[key] = {**config.to_dict(), "source": source}
        if measured_s is not None:
            self.entries[key]["measured_s"] = float(measured_s)

    def load(self) -> "AutotuneTable":
        if self.path and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    data = json.load(f)
                self.entries.update(data.get("shapes", {}))
            except (OSError, ValueError):
                pass  # a corrupt cache is a cache miss, not a crash
        return self

    def save(self) -> None:
        if not self.path:
            return
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "shapes": self.entries}, f, indent=2,
                      sort_keys=True)
        os.replace(tmp, self.path)


def table_path() -> str:
    return os.path.expanduser(os.environ.get(CACHE_ENV, _DEFAULT_CACHE))


_TABLE: Optional[AutotuneTable] = None


def load_table(refresh: bool = False) -> AutotuneTable:
    """The process-wide table, loaded once from :func:`table_path`."""
    global _TABLE
    if _TABLE is None or refresh:
        _TABLE = AutotuneTable(table_path()).load()
    return _TABLE


def reset() -> None:
    """Drop the cached in-process table (tests; after env changes)."""
    global _TABLE
    _TABLE = None


def lookup(q: int, w: int, mode: int = MODE_TIDSET,
           kind: Optional[str] = None) -> KernelConfig:
    """Tuned config for a call shape; falls back to the cost-model seed
    (best predicted candidate) when the shape was never measured.  This is
    the trace-time hook behind ``ops.fused_intersect(block_w=None)``."""
    cfg = load_table().get(shape_class(q, w, mode, kind))
    if cfg is not None:
        return cfg
    return KernelConfig(block_w=seeded_candidates(q, w, kind)[0])


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def measure_steady(fn: Callable[[], jax.Array], reps: int = 5,
                   warmup: int = 1) -> Tuple[float, float]:
    """(compile_s, steady_s): first call timed separately (trace+compile),
    then ``reps`` calls each blocked to completion inside the timed region
    — the timing-hygiene contract every benchmark in this repo follows."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    compile_s = time.perf_counter() - t0
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return compile_s, (time.perf_counter() - t0) / reps


def _synthetic_case(q: int, w: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    p = max(min(int(q), 4096), 2)
    bitmaps = jnp.asarray(rng.integers(0, 2 ** 32, (p, w), dtype=np.uint32))
    left = jnp.asarray(rng.integers(0, p, q).astype(np.int32))
    right = jnp.asarray(rng.integers(0, p, q).astype(np.int32))
    supl = jnp.asarray(np.full(q, w * 32, np.int32))
    return bitmaps, left, right, supl


def tune_shape(q: int, w: int, mode: int = MODE_TIDSET, *,
               kind: Optional[str] = None,
               reps: int = 5,
               max_candidates: Optional[int] = None,
               interpret: bool = False,
               save: bool = True) -> dict:
    """Measure the seeded candidates for one (q, w, mode) shape class and
    cache the winner.

    Returns the bench record: per-candidate steady seconds, the tuned
    ``block_w``, the cost-model's pick, and whether they agree.  With
    ``max_candidates`` the sweep keeps only the model's top-N — the seeding
    is what makes truncation safe.
    """
    from .fused_intersect.fused_intersect import fused_intersect_pairs
    from .fused_intersect.ref import fused_intersect_ref

    if kind is None:
        kind = ("interpret" if interpret
                else "tpu" if jax.default_backend() == "tpu" else "xla")
    cands = seeded_candidates(q, w, "xla" if kind == "xla" else "tpu")
    if max_candidates is not None:
        cands = cands[:max_candidates]
    bitmaps, left, right, supl = _synthetic_case(q, w)
    msup = jnp.int32(w * 16)

    timings: Dict[int, float] = {}
    compiles: Dict[int, float] = {}
    for bw in cands:
        if kind == "xla":
            fn = lambda: fused_intersect_ref(
                bitmaps, left, right, supl, msup, mode=mode)[1]
        else:
            fn = lambda bw=bw: fused_intersect_pairs(
                bitmaps, left, right, supl, msup, mode=mode, block_w=bw,
                interpret=(kind == "interpret"))[1]
        compile_s, steady_s = measure_steady(fn, reps=reps)
        timings[bw] = steady_s
        compiles[bw] = compile_s
    best = min(timings, key=timings.get)
    config = KernelConfig(block_w=best)
    key = shape_class(q, w, mode, "xla" if kind == "xla" else "tpu")
    table = load_table()
    table.put(key, config, measured_s=timings[best], source="measured")
    if save:
        table.save()
    return {
        "key": key, "q": int(q), "w": int(w), "mode": int(mode),
        "kind": kind,
        "candidates": {str(bw): timings[bw] for bw in cands},
        "compile_s": {str(bw): compiles[bw] for bw in cands},
        "tuned_block_w": int(best),
        "model_pick": int(cands[0]),
        "model_agrees": bool(best == cands[0]),
        "steady_s": timings[best],
        "default_steady_s": timings.get(
            min(DEFAULT_BLOCK_W, round_up_lanes(w)), timings[best]),
    }
