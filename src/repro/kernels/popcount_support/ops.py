"""Dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere.

``repro.core.eclat`` routes its pair batches through here so the hot loop is
kernel-backed on real hardware while remaining exact (and fast enough) on the
CPU host used for tests/benchmarks.
"""
from __future__ import annotations

import jax

from .popcount_support import popcount_support
from .ref import popcount_support_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def intersect_support(a: jax.Array, b: jax.Array, *, interpret: bool | None = None):
    """Batched tidset AND + support.  See kernel docstring for tiling."""
    if interpret is None:
        if _on_tpu():
            return popcount_support(a, b)
        return popcount_support_ref(a, b)
    return popcount_support(a, b, interpret=interpret)
