"""Pallas TPU kernel: batched tidset intersection + support counting.

The paper's Algorithm-1 inner loop (tidset AND + cardinality) over a batch of
candidate pairs.  Pure VPU work on packed uint32 words:

    inter[m, w] = a[m, w] & b[m, w]
    support[m]  = sum_w popcount(inter[m, w])

Tiling: grid = (M/bm, W/bw); each step loads (bm, bw) uint32 tiles of both
operands into VMEM (2*bm*bw*4 bytes), writes the intersected tile, and
accumulates the per-row popcount partial into the (bm,) support block —
revisited across the W-grid dimension, so that dimension is declared
"arbitrary" (sequential) for TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_W = 512


def _kernel(a_ref, b_ref, inter_ref, sup_ref):
    w_idx = pl.program_id(1)
    inter = jnp.bitwise_and(a_ref[...], b_ref[...])
    inter_ref[...] = inter
    partial = jax.lax.population_count(inter).astype(jnp.int32).sum(axis=1)

    @pl.when(w_idx == 0)
    def _init():
        sup_ref[...] = partial

    @pl.when(w_idx != 0)
    def _acc():
        sup_ref[...] = sup_ref[...] + partial


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_w", "interpret")
)
def popcount_support(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """(M, W) uint32 x2 -> ((M, W) uint32 intersection, (M,) int32 support).

    M and W need not be multiples of the block sizes; inputs are zero-padded
    (zero words contribute zero popcount, so supports are unaffected).
    """
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"expected matching (M, W) operands, got {a.shape} {b.shape}")
    m, w = a.shape
    bm = min(block_m, max(m, 1))
    bw = min(block_w, max(w, 1))
    pad_m = (-m) % bm
    pad_w = (-w) % bw
    if pad_m or pad_w:
        a = jnp.pad(a, ((0, pad_m), (0, pad_w)))
        b = jnp.pad(b, ((0, pad_m), (0, pad_w)))
    mp, wp = a.shape
    grid = (mp // bm, wp // bw)

    inter, sup = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, wp), jnp.uint32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(a, b)
    return inter[:m, :w], sup[:m]
