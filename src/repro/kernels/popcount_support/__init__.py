from .ops import intersect_support
from .popcount_support import popcount_support
from .ref import popcount_support_ref

__all__ = ["intersect_support", "popcount_support", "popcount_support_ref"]
