"""Pure-jnp oracle for the popcount_support kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def popcount_support_ref(a: jax.Array, b: jax.Array):
    """(M, W) uint32 x2 -> ((M, W) intersection, (M,) int32 support)."""
    inter = jnp.bitwise_and(a, b)
    sup = jax.lax.population_count(inter).astype(jnp.int32).sum(axis=-1)
    return inter, sup
