"""Pure-jnp oracle for flash attention (materializes the score matrix)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, sm_scale=None):
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) -> (B, H, S, D), fp32 math."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * sm_scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
