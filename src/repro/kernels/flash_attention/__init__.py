from .flash_attention import flash_attention
from .ops import multi_head_attention
from .ref import attention_ref

__all__ = ["flash_attention", "multi_head_attention", "attention_ref"]
