"""Dispatching wrapper: Pallas flash attention on TPU, jnp oracle on CPU.

``repro.models.attention`` routes full-sequence (prefill/train) attention
through here; decode-shape attention (q_len == 1) is linear in KV length and
stays in plain jnp (no kernel needed — see DESIGN.md §6).
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_ref


def multi_head_attention(q, k, v, *, causal=True, window=None, sm_scale=None,
                         interpret: bool | None = None):
    if interpret is None:
        if jax.default_backend() == "tpu":
            return flash_attention(q, k, v, causal=causal, window=window,
                                   sm_scale=sm_scale)
        return attention_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale)
    return flash_attention(q, k, v, causal=causal, window=window,
                           sm_scale=sm_scale, interpret=interpret)
