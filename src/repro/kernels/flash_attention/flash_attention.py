"""Pallas TPU kernel: tiled online-softmax (flash) attention.

Used by the LM substrate's prefill path (the framework's dominant compute
hot-spot at the 32k prefill shape).  Standard FlashAttention-2 style tiling
adapted to TPU: the KV sequence is the innermost sequential grid dimension;
running max / normalizer / accumulator tiles live in VMEM scratch so each
(bq, d) output block is written once.

Supports causal masking, sliding-window masking (windowed archs: gemma3's
local layers, hymba), and GQA via the K/V BlockSpec index map (no KV
repetition in HBM — the map folds q-head -> kv-head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            sm_scale, causal, window, block_q, block_k, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = cols < seq_len                         # padding mask
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                           # (bq, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) with H % Hkv == 0 -> (B, H, S, D)."""
    b, h, s, d = q.shape
    _, hkv, sk, dk = k.shape
    if sk != s or dk != d or v.shape != k.shape or h % hkv:
        raise ValueError(f"bad shapes q={q.shape} k={k.shape} v={v.shape}")
    group = h // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5

    bq = min(block_q, s)
    bk = min(block_k, s)
    pad_s = (-s) % max(bq, bk)
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    sp = q.shape[2]
    grid = (b, h, sp // bq, sp // bk)

    kernel = functools.partial(
        _kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=bq, block_k=bk, seq_len=s,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, qi, ki: (bb, hh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :]
