"""Periodic, non-blocking persistence for the streaming miner.

Bridges the miner's state contract (:class:`~repro.streaming.miner.MinerState`,
DESIGN.md §10) onto ``training.checkpoint``: a snapshot is taken
synchronously on the stream thread (cheap host copies), then written by
``AsyncCheckpointer`` off-thread so the next slide never waits on disk.

Checkpoint step semantics: step ``s`` is the state *after* ``s`` completed
slides.  ``data.stream.transaction_stream`` is deterministic in its
arguments, so recovery is restore-at-``s`` + replay batches ``s..`` — the
Spark lineage-recovery story with the window state as the materialized RDD.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Tuple

import jax

from ..training.checkpoint import AsyncCheckpointer, restore_latest, valid_steps
from .miner import MinerState, StreamConfig, StreamingMiner

__all__ = ["StreamCheckpointer", "restore_miner", "peek_config"]


class StreamCheckpointer:
    """Snapshot-and-write-behind for a :class:`StreamingMiner`.

    ``save(miner, step)`` is cheap on the caller's thread (host deep-copies
    via ``snapshot_state``); the directory write, atomic rename and GC run
    on the :class:`AsyncCheckpointer` background thread.  ``every`` gates
    :meth:`maybe_save` to one checkpoint per N slides.  Call :meth:`wait`
    before reading the directory or exiting — it joins the in-flight write
    and re-raises any writer error (tests rely on this for deterministic
    fault surfacing; nothing here depends on thread scheduling).
    """

    def __init__(self, directory: str, *, every: int = 1, keep: int = 3):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = int(every)
        self._ckpt = AsyncCheckpointer(directory, keep=keep)

    @property
    def directory(self) -> str:
        return self._ckpt.directory

    def save(self, miner: StreamingMiner, step: int) -> None:
        tree, extra = miner.snapshot_state().to_tree()
        self._ckpt.save(int(step), tree, extra=extra)

    def maybe_save(self, miner: StreamingMiner, step: int) -> bool:
        """Save iff ``step`` lands on the cadence; returns whether it did."""
        if int(step) % self.every != 0:
            return False
        self.save(miner, step)
        return True

    def wait(self) -> None:
        self._ckpt.wait()


def restore_miner(
    directory: str,
    mesh: Optional[jax.sharding.Mesh] = None,
    *,
    backend: Optional[str] = None,
    shard: Optional[str] = None,
    keep_transactions: Optional[bool] = None,
) -> Tuple[StreamingMiner, int]:
    """Rebuild a miner from the newest restorable checkpoint in
    ``directory`` (falling back past torn/corrupt steps) under whatever
    ``mesh`` / ``backend`` / ``shard`` the restoring process brings — the
    re-meshing entry point the stream driver's ``--restore`` / ``--remesh``
    flags call.  Returns ``(miner, completed_slides)``; resume by replaying
    the deterministic stream from ``completed_slides``.
    """
    flat, manifest, step = restore_latest(directory)
    state = MinerState.from_tree(flat, manifest["extra"])
    miner = StreamingMiner.from_state(state, mesh=mesh, backend=backend,
                                      shard=shard,
                                      keep_transactions=keep_transactions)
    return miner, int(manifest["step"])


def peek_config(directory: str) -> Tuple[StreamConfig, int]:
    """The (StreamConfig, completed_slides) of the newest valid checkpoint,
    from its manifest alone (no array loads) — the driver reads this first
    to decide which mesh to build before calling :func:`restore_miner`."""
    fields = {f.name for f in dataclasses.fields(StreamConfig)}
    for step in reversed(valid_steps(directory)):
        try:
            path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
            with open(path) as f:
                manifest = json.load(f)
            cfg_kw = {k: v for k, v in manifest["extra"]["config"].items()
                      if k in fields}
            return StreamConfig(**cfg_kw), int(manifest["step"])
        except (OSError, ValueError, KeyError, TypeError):
            continue
    raise FileNotFoundError(f"no readable checkpoint manifest under "
                            f"{directory!r}")
