"""Sliding-window incremental Eclat: re-mine micro-batch streams in-place.

The paper's argument for RDD-Eclat is that the vertical tidset state is worth
keeping resident between passes.  This module takes that to its conclusion:
when the database is a *sliding window* over a transaction stream, almost all
of a fresh ``mine()`` call is recomputation of state that one micro-batch
cannot have changed much.  The incremental miner therefore maintains, across
window slides:

* the packed vertical bitmap, as a ring of word-blocks (``WindowRing``) —
  admitting a micro-batch is one block pack + one in-place device write, never
  a full repack;
* per-item (1-itemset) supports, as the diagonal of
* the full co-occurrence count matrix ``C[i, j] = |tidset(i) ∩ tidset(j)|``
  over the item universe — popcount is additive across word blocks, so one
  slide updates it exactly with two block-sized popcount matmuls
  (``C += cooc(new_block) - cooc(evicted_block)``) instead of the
  window-sized triangular-matrix pass batch mining pays.

Re-mining a window is then: threshold the cached supports (equivalence
classes whose 1-prefix crossed ``min_sup`` enter or leave the active set with
no device work), read the frequent 2-itemsets straight out of ``C``, and
expand only the surviving classes level-by-level through the *same*
``core.engine`` backend interface batch mining uses — the frontier bitmaps
never leave the device.  Results are bit-exact with batch ``mine()`` over the
window's transactions (DESIGN.md §5; tests/test_streaming.py holds all three
backends to it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from ..core import engine as eng
from ..core.eclat import resolve_min_sup, run_bottom_up
from ..core.equivalence import pair_work
from ..core.itemsets import ItemsetStore, LevelRecord, generate_rules
from ..core.partitioners import assign_partitions
from ..core.triangular import cooccurrence_counts, frequent_pairs
from ..core.vertical import sort_items
from .window import WindowRing

__all__ = ["StreamConfig", "WindowResult", "StreamingMiner"]


@dataclasses.dataclass
class StreamConfig:
    """Knobs of the streaming miner (the EclatConfig of the windowed world)."""

    min_sup: float                 # float in (0,1] = fraction of live window txns; int >= 1 = count
    n_blocks: int = 16             # window capacity in micro-batch blocks
    block_txns: int = 1024         # txn columns per block (multiple of 32)
    backend: str = "pallas"        # core.engine backend: jnp | pallas | sharded | tidsharded | grid
    shard: str = "pairs"           # mesh split: "pairs" | "words" (word-sharded ring, DESIGN.md §7) | "grid" (2D pairs x words mesh, DESIGN.md §8)
    partitioner: str = "greedy"    # equivalence-class placement (paper §4.5)
    p: int = 10                    # partitions for the class table
    max_k: Optional[int] = None    # deepest itemset length to mine (>= 1); None = unbounded
    bucket_min: int = 128          # engine pair-buffer ladder floor (half-pow2 rungs)
    block_w: Optional[int] = None  # fused-kernel word-tile width; None = autotuned table / cost-model seed
    autotune: bool = False         # tune-on-miss: measure untuned kernel shapes before dispatching them
    compact: bool = True           # in-executable survivor compaction (False = legacy mask-roundtrip + gather)

    def resolve_min_sup(self, n_txn: int) -> int:
        return resolve_min_sup(self.min_sup, n_txn)


@dataclasses.dataclass
class WindowResult:
    """Frequent itemsets of the current window + per-slide accounting."""

    store: ItemsetStore
    n_txn: int
    stats: dict

    @property
    def counts(self) -> List[int]:
        return self.store.counts

    @property
    def total(self) -> int:
        return self.store.total

    def itemsets(self):
        return self.store.itemsets()

    def support_map(self):
        return self.store.support_map()

    def rules(self, min_conf: float):
        return generate_rules(self.support_map(), min_conf)


class StreamingMiner:
    """Ingest micro-batches, keep the vertical state incremental, re-mine.

    ``advance(batch)`` = ``push(batch)`` (state deltas only) +
    ``mine_window()`` (re-expansion); callers that mine on a cadence rather
    than every batch can call the two halves separately.
    """

    def __init__(self, n_items: int, config: StreamConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 keep_transactions: bool = True):
        self.n_items = int(n_items)
        self.config = config
        # word-sharded and grid modes carry the ring itself at P(None, "data")
        # so the window bitmap never fully lands on any one device (on the 2D
        # grid mesh the spec replicates it over the class axis for free)
        words_mode = (config.shard in ("words", "grid")
                      or config.backend in ("tidsharded", "grid"))
        self.ring = WindowRing(n_items, config.n_blocks, config.block_txns,
                               keep_transactions=keep_transactions,
                               mesh=mesh if words_mode else None)
        # incremental state: co-occurrence counts over the item universe;
        # per-item supports are its diagonal
        self.cooc = np.zeros((n_items, n_items), np.int64)
        # dispatch hints for backend="auto": the steady-state expansion is
        # bounded by the window's item universe and ring capacity
        est_q = max(n_items * (n_items - 1) // 2, 1)
        est_w = max(-(-int(config.n_blocks) * int(config.block_txns) // 32), 1)
        self.engine = eng.resolve_engine(config.backend, mesh,
                                         bucket_min=config.bucket_min,
                                         shard=config.shard,
                                         block_w=config.block_w,
                                         autotune=config.autotune,
                                         compact=config.compact,
                                         hints=(est_q, est_w))
        self._prev_frequent: Optional[np.ndarray] = None

    # -- incremental state maintenance --------------------------------------

    @property
    def supports(self) -> np.ndarray:
        """Per-item supports over the live window (universe-indexed)."""
        return np.diag(self.cooc)

    def push(self, batch: Sequence[Sequence[int]]) -> dict:
        """Admit one micro-batch; update ring + counts by block deltas."""
        t0 = time.perf_counter()
        new_block, old_block, n_evicted = self.ring.push(batch)
        # popcount is additive over word blocks, so the count matrix follows
        # the ring exactly: add the admitted block, subtract the evicted one.
        self.cooc += cooccurrence_counts(jnp.asarray(new_block)).astype(np.int64)
        if n_evicted or old_block.any():
            self.cooc -= cooccurrence_counts(jnp.asarray(old_block)).astype(np.int64)
        return {
            "push_s": time.perf_counter() - t0,
            "n_admitted": len(batch),
            "n_evicted": n_evicted,
        }

    # -- re-mining -----------------------------------------------------------

    def mine_window(self) -> WindowResult:
        """Expand the active equivalence classes of the current window.

        Level-1 supports and level-2 counts are read from the incrementally
        maintained state; only levels >= 2 of classes that still hold a
        frequent pair do device work, through ``engine.expand`` (so the jnp /
        pallas / sharded backends are interchangeable here exactly as in
        batch ``mine()``).
        """
        cfg = self.config
        if cfg.max_k is not None and cfg.max_k < 1:
            raise ValueError(f"max_k must be >= 1 (or None for unbounded), "
                             f"got {cfg.max_k}")
        t_start = time.perf_counter()
        engine_snap = self.engine.snapshot()
        n_txn = self.ring.n_txn
        abs_min_sup = cfg.resolve_min_sup(n_txn)
        stats: dict = {
            "abs_min_sup": abs_min_sup,
            "window": {"n_txn": n_txn, "filled_blocks": self.ring.filled,
                       "n_blocks": self.ring.n_blocks,
                       "n_words": self.ring.n_words},
            "phase_s": {},
        }

        sup = self.supports
        freq = sup >= abs_min_sup
        item_ids = np.nonzero(freq)[0].astype(np.int64)
        # class churn: prefixes whose support crossed min_sup this slide
        prev = self._prev_frequent
        if prev is None:
            entered, exited = item_ids, np.zeros(0, np.int64)
        else:
            entered = np.setdiff1d(item_ids, prev, assume_unique=True)
            exited = np.setdiff1d(prev, item_ids, assume_unique=True)
        self._prev_frequent = item_ids
        stats["classes"] = {"n_active": int(item_ids.shape[0]),
                            "n_entered": int(entered.shape[0]),
                            "n_exited": int(exited.shape[0])}

        sup_f = sup[item_ids]
        perm = sort_items(item_ids, sup_f, "support_asc")
        items = item_ids[perm]
        sup1 = sup_f[perm].astype(np.int64)
        n1 = int(items.shape[0])

        store = ItemsetStore(items)
        n_classes = max(n1 - 1, 0)
        sizes1 = (n1 - 1 - np.arange(n_classes)).clip(min=0)
        est = pair_work(sizes1 + 1, self.ring.n_words)
        eff_p = cfg.p if cfg.partitioner in ("hash", "reverse_hash", "greedy") \
            else max(n_classes, 1)
        table = assign_partitions(n_classes, cfg.partitioner, eff_p, work=est)
        part_to_dev = np.arange(eff_p, dtype=np.int64) % max(self.engine.n_devices, 1)

        lvl1_partition = (np.concatenate([table, [table[-1] if n_classes else 0]])[:n1]
                          if n1 else np.zeros(0, np.int64))
        store.add_level(LevelRecord(k=1, parent=np.full(n1, -1, np.int64),
                                    item_rank=np.arange(n1, dtype=np.int64),
                                    support=sup1, partition=lvl1_partition))
        # max_k bounds every level, including 2 — bit-exact with the batch
        # driver (the regression was expanding level 2 regardless of max_k)
        max_k = n1 if cfg.max_k is None else cfg.max_k
        if n1 < 2 or max_k < 2:
            stats.update(self.engine.stats(since=engine_snap))
            stats["total_s"] = time.perf_counter() - t_start
            return WindowResult(store=store, n_txn=n_txn, stats=stats)

        # ---- level 2: straight from the cached count matrix ----------------
        t0 = time.perf_counter()
        csub = self.cooc[np.ix_(items, items)]
        iu, ju, c2 = frequent_pairs(csub, abs_min_sup)
        if iu.size:
            res = self.engine.expand(
                self.ring.device,
                items[iu].astype(np.int32), items[ju].astype(np.int32),
                sup1[iu].astype(np.int32),
                mode=eng.MODE_TIDSET, min_sup=abs_min_sup,
                device_of_pair=part_to_dev[table[iu]],
            )
            # pairs were pre-filtered by the exact cached counts, so the
            # engine must confirm every one; disagreement means the
            # incremental state is corrupt and every further window would be
            # silently wrong.  A real exception, not an ``assert`` — this
            # must also fire under ``python -O``.
            if not res.mask.all():
                bad = np.nonzero(~res.mask)[0]
                raise RuntimeError(
                    f"cached co-occurrence counts disagree with the engine "
                    f"on {bad.size}/{res.mask.size} level-2 pair(s) "
                    f"(first: items {int(items[iu[bad[0]]])},"
                    f"{int(items[ju[bad[0]]])}) — incremental window state "
                    f"is corrupt")
            sup2 = res.supports.astype(np.int64)
            lvl_bitmaps = res.bitmaps
        else:
            sup2 = np.zeros(0, np.int64)
            lvl_bitmaps = jnp.zeros((0, self.ring.n_words), jnp.uint32)
        partition = table[iu] if iu.size else np.zeros(0, np.int64)
        store.add_level(LevelRecord(k=2, parent=iu.copy(), item_rank=ju.copy(),
                                    support=sup2, partition=partition))
        stats["phase_s"]["level2"] = time.perf_counter() - t0

        # ---- levels >= 3: the shared per-class bottom-up loop --------------
        t0 = time.perf_counter()
        run_bottom_up(self.engine, store, lvl_bitmaps,
                      class_id=iu.copy(), item_rank=ju.copy(),
                      partition=partition, support=sup2,
                      abs_min_sup=abs_min_sup, mode=eng.MODE_TIDSET,
                      max_k=max_k, part_to_dev=part_to_dev)
        stats["phase_s"]["bottom_up"] = time.perf_counter() - t0
        # engine counters are lifetime-cumulative; report this slide's delta
        stats.update(self.engine.stats(since=engine_snap))
        stats["total_s"] = time.perf_counter() - t_start
        return WindowResult(store=store, n_txn=n_txn, stats=stats)

    def advance(self, batch: Sequence[Sequence[int]]) -> WindowResult:
        """One window slide: admit the micro-batch, then re-mine."""
        push_stats = self.push(batch)
        result = self.mine_window()
        result.stats.update(push_stats)
        result.stats["slide_s"] = push_stats["push_s"] + result.stats["total_s"]
        return result

    def window_transactions(self) -> List[List[int]]:
        """Live window contents (for parity checks against batch mining)."""
        return self.ring.window_transactions()
