"""Sliding-window incremental Eclat: re-mine micro-batch streams in-place.

The paper's argument for RDD-Eclat is that the vertical tidset state is worth
keeping resident between passes.  This module takes that to its conclusion:
when the database is a *sliding window* over a transaction stream, almost all
of a fresh ``mine()`` call is recomputation of state that one micro-batch
cannot have changed much.  The incremental miner therefore maintains, across
window slides:

* the packed vertical bitmap, as a ring of word-blocks (``WindowRing``) —
  admitting a micro-batch is one block pack + one in-place device write, never
  a full repack;
* per-item (1-itemset) supports, as the diagonal of
* the full co-occurrence count matrix ``C[i, j] = |tidset(i) ∩ tidset(j)|``
  over the item universe — popcount is additive across word blocks, so one
  slide updates it exactly with two block-sized popcount matmuls
  (``C += cooc(new_block) - cooc(evicted_block)``) instead of the
  window-sized triangular-matrix pass batch mining pays.

Re-mining a window is then: threshold the cached supports (equivalence
classes whose 1-prefix crossed ``min_sup`` enter or leave the active set with
no device work), read the frequent 2-itemsets straight out of ``C``, and
expand only the surviving classes level-by-level through the *same*
``core.engine`` backend interface batch mining uses — the frontier bitmaps
never leave the device.  Results are bit-exact with batch ``mine()`` over the
window's transactions (DESIGN.md §5; tests/test_streaming.py holds all three
backends to it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from ..core import engine as eng
from ..core.eclat import resolve_min_sup, run_bottom_up
from ..core.equivalence import pair_work
from ..core.itemsets import ItemsetStore, LevelRecord, generate_rules
from ..core.partitioners import assign_partitions
from ..core.triangular import cooccurrence_counts, frequent_pairs
from ..core.vertical import sort_items
from ..faults import kill_point
from .window import RingState, WindowRing

__all__ = ["StreamConfig", "WindowResult", "StreamingMiner", "MinerState"]


@dataclasses.dataclass
class StreamConfig:
    """Knobs of the streaming miner (the EclatConfig of the windowed world)."""

    min_sup: float                 # float in (0,1] = fraction of live window txns; int >= 1 = count
    n_blocks: int = 16             # window capacity in micro-batch blocks
    block_txns: int = 1024         # txn columns per block (multiple of 32)
    backend: str = "pallas"        # core.engine backend: jnp | pallas | sharded | tidsharded | grid
    shard: str = "pairs"           # mesh split: "pairs" | "words" (word-sharded ring, DESIGN.md §7) | "grid" (2D pairs x words mesh, DESIGN.md §8)
    partitioner: str = "greedy"    # equivalence-class placement (paper §4.5)
    p: int = 10                    # partitions for the class table
    max_k: Optional[int] = None    # deepest itemset length to mine (>= 1); None = unbounded
    bucket_min: int = 128          # engine pair-buffer ladder floor (half-pow2 rungs)
    block_w: Optional[int] = None  # fused-kernel word-tile width; None = autotuned table / cost-model seed
    autotune: bool = False         # tune-on-miss: measure untuned kernel shapes before dispatching them
    compact: bool = True           # in-executable survivor compaction (False = legacy mask-roundtrip + gather)

    def resolve_min_sup(self, n_txn: int) -> int:
        return resolve_min_sup(self.min_sup, n_txn)


@dataclasses.dataclass
class MinerState:
    """Serializable snapshot of a :class:`StreamingMiner` (DESIGN.md §10).

    Composes the ring and engine contracts with the miner's own incremental
    state: the co-occurrence count matrix and the previous slide's frequent
    item set (class-churn lineage).  Everything here is logical — mesh
    placement, compiled executors and pair buffers are derived on restore —
    so a snapshot taken under any backend/mesh restores under any other
    (:meth:`StreamingMiner.from_state`), bit-exact.
    """
    n_items: int
    config: dict                          # StreamConfig, as a plain dict
    ring: RingState
    engine: eng.EngineState
    cooc: np.ndarray                      # (n_items, n_items) int64
    prev_frequent: Optional[np.ndarray]   # last slide's frequent items
    window_version: int = 0               # monotonic slide stamp (DESIGN.md §11)

    def to_tree(self):
        """Flat ``{path: ndarray}`` tree + JSON-able extra, ready for
        ``training.checkpoint.save_checkpoint`` — ring and engine leaves are
        namespaced under ``ring/`` and ``engine/``."""
        ring_tree, ring_extra = self.ring.to_tree()
        eng_tree, eng_extra = self.engine.to_tree()
        tree = {"cooc": np.asarray(self.cooc, np.int64)}
        if self.prev_frequent is not None:
            tree["prev_frequent"] = np.asarray(self.prev_frequent, np.int64)
        tree.update({f"ring/{k}": v for k, v in ring_tree.items()})
        tree.update({f"engine/{k}": v for k, v in eng_tree.items()})
        extra = {"kind": "miner_state", "version": 1,
                 "n_items": int(self.n_items), "config": dict(self.config),
                 "has_prev_frequent": self.prev_frequent is not None,
                 "window_version": int(self.window_version),
                 "ring": ring_extra, "engine": eng_extra}
        return tree, extra

    @classmethod
    def from_tree(cls, tree, extra) -> "MinerState":
        def sub(prefix):
            return {k[len(prefix):]: v for k, v in tree.items()
                    if k.startswith(prefix)}
        return cls(
            n_items=int(extra["n_items"]), config=dict(extra["config"]),
            ring=RingState.from_tree(sub("ring/"), extra["ring"]),
            engine=eng.EngineState.from_tree(sub("engine/"), extra["engine"]),
            cooc=np.asarray(tree["cooc"], np.int64),
            prev_frequent=(np.asarray(tree["prev_frequent"], np.int64)
                           if extra["has_prev_frequent"] else None),
            # pre-versioning checkpoints restore at version 0 and count up
            window_version=int(extra.get("window_version", 0)))


@dataclasses.dataclass
class WindowResult:
    """Frequent itemsets of the current window + per-slide accounting.

    ``version`` is the miner's ``window_version`` at mine time — the cache
    key of the serving layer (DESIGN.md §11): two results with equal
    versions were mined from identical window contents.
    """

    store: ItemsetStore
    n_txn: int
    stats: dict
    version: int = 0

    @property
    def counts(self) -> List[int]:
        return self.store.counts

    @property
    def total(self) -> int:
        return self.store.total

    def itemsets(self):
        return self.store.itemsets()

    def support_map(self):
        return self.store.support_map()

    def rules(self, min_conf: float):
        return generate_rules(self.support_map(), min_conf)


class StreamingMiner:
    """Ingest micro-batches, keep the vertical state incremental, re-mine.

    ``advance(batch)`` = ``push(batch)`` (state deltas only) +
    ``mine_window()`` (re-expansion); callers that mine on a cadence rather
    than every batch can call the two halves separately.
    """

    def __init__(self, n_items: int, config: StreamConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 keep_transactions: bool = True):
        self.n_items = int(n_items)
        self.config = config
        # word-sharded and grid modes carry the ring itself at P(None, "data")
        # so the window bitmap never fully lands on any one device (on the 2D
        # grid mesh the spec replicates it over the class axis for free)
        words_mode = (config.shard in ("words", "grid")
                      or config.backend in ("tidsharded", "grid"))
        self.ring = WindowRing(n_items, config.n_blocks, config.block_txns,
                               keep_transactions=keep_transactions,
                               mesh=mesh if words_mode else None)
        # incremental state: co-occurrence counts over the item universe;
        # per-item supports are its diagonal
        self.cooc = np.zeros((n_items, n_items), np.int64)
        # dispatch hints for backend="auto": the steady-state expansion is
        # bounded by the window's item universe and ring capacity
        est_q = max(n_items * (n_items - 1) // 2, 1)
        est_w = max(-(-int(config.n_blocks) * int(config.block_txns) // 32), 1)
        self.engine = eng.resolve_engine(config.backend, mesh,
                                         bucket_min=config.bucket_min,
                                         shard=config.shard,
                                         block_w=config.block_w,
                                         autotune=config.autotune,
                                         compact=config.compact,
                                         hints=(est_q, est_w))
        self._prev_frequent: Optional[np.ndarray] = None
        # monotonic window-content stamp: bumped once per completed push();
        # mine_window() stamps its result with the current value, so equal
        # versions imply identical window contents (the serving cache key,
        # DESIGN.md §11).  Survives checkpoint/restore via MinerState.
        self.window_version = 0

    # -- incremental state maintenance --------------------------------------

    @property
    def supports(self) -> np.ndarray:
        """Per-item supports over the live window (universe-indexed)."""
        return np.diag(self.cooc)

    def push(self, batch: Sequence[Sequence[int]]) -> dict:
        """Admit one micro-batch; update ring + counts by block deltas."""
        t0 = time.perf_counter()
        new_block, old_block, n_evicted = self.ring.push(batch)
        # ring written, count matrix not yet — the torn state recovery must
        # handle (tests/faultinject.py kills here)
        kill_point("miner:mid_append")
        # popcount is additive over word blocks, so the count matrix follows
        # the ring exactly: add the admitted block, subtract the evicted one.
        self.cooc += cooccurrence_counts(
            jax.device_put(new_block)).astype(np.int64)
        # admitted block counted, evicted block not yet subtracted
        kill_point("miner:mid_evict")
        if n_evicted or old_block.any():
            self.cooc -= cooccurrence_counts(
                jax.device_put(old_block)).astype(np.int64)
        # the window's contents changed: new version.  Bumped only after the
        # ring AND the count matrix agree, so a crash between the kill points
        # above never publishes a version for a half-applied slide.
        self.window_version += 1
        return {
            "push_s": time.perf_counter() - t0,
            "n_admitted": len(batch),
            "n_evicted": n_evicted,
        }

    # -- re-mining -----------------------------------------------------------

    def mine_window(self) -> WindowResult:
        """Expand the active equivalence classes of the current window.

        Level-1 supports and level-2 counts are read from the incrementally
        maintained state; only levels >= 2 of classes that still hold a
        frequent pair do device work, through ``engine.expand`` (so the jnp /
        pallas / sharded backends are interchangeable here exactly as in
        batch ``mine()``).
        """
        cfg = self.config
        if cfg.max_k is not None and cfg.max_k < 1:
            raise ValueError(f"max_k must be >= 1 (or None for unbounded), "
                             f"got {cfg.max_k}")
        t_start = time.perf_counter()
        engine_snap = self.engine.snapshot()
        n_txn = self.ring.n_txn
        abs_min_sup = cfg.resolve_min_sup(n_txn)
        stats: dict = {
            "abs_min_sup": abs_min_sup,
            "window_version": int(self.window_version),
            "window": {"n_txn": n_txn, "filled_blocks": self.ring.filled,
                       "n_blocks": self.ring.n_blocks,
                       "n_words": self.ring.n_words},
            "phase_s": {},
        }

        sup = self.supports
        freq = sup >= abs_min_sup
        item_ids = np.nonzero(freq)[0].astype(np.int64)
        # class churn: prefixes whose support crossed min_sup this slide
        prev = self._prev_frequent
        if prev is None:
            entered, exited = item_ids, np.zeros(0, np.int64)
        else:
            entered = np.setdiff1d(item_ids, prev, assume_unique=True)
            exited = np.setdiff1d(prev, item_ids, assume_unique=True)
        self._prev_frequent = item_ids
        stats["classes"] = {"n_active": int(item_ids.shape[0]),
                            "n_entered": int(entered.shape[0]),
                            "n_exited": int(exited.shape[0])}

        sup_f = sup[item_ids]
        perm = sort_items(item_ids, sup_f, "support_asc")
        items = item_ids[perm]
        sup1 = sup_f[perm].astype(np.int64)
        n1 = int(items.shape[0])

        store = ItemsetStore(items)
        n_classes = max(n1 - 1, 0)
        sizes1 = (n1 - 1 - np.arange(n_classes)).clip(min=0)
        est = pair_work(sizes1 + 1, self.ring.n_words)
        eff_p = cfg.p if cfg.partitioner in ("hash", "reverse_hash", "greedy") \
            else max(n_classes, 1)
        table = assign_partitions(n_classes, cfg.partitioner, eff_p, work=est)
        part_to_dev = np.arange(eff_p, dtype=np.int64) % max(self.engine.n_devices, 1)

        lvl1_partition = (np.concatenate([table, [table[-1] if n_classes else 0]])[:n1]
                          if n1 else np.zeros(0, np.int64))
        store.add_level(LevelRecord(k=1, parent=np.full(n1, -1, np.int64),
                                    item_rank=np.arange(n1, dtype=np.int64),
                                    support=sup1, partition=lvl1_partition))
        # max_k bounds every level, including 2 — bit-exact with the batch
        # driver (the regression was expanding level 2 regardless of max_k)
        max_k = n1 if cfg.max_k is None else cfg.max_k
        if n1 < 2 or max_k < 2:
            stats.update(self.engine.stats(since=engine_snap))
            stats["total_s"] = time.perf_counter() - t_start
            return WindowResult(store=store, n_txn=n_txn, stats=stats,
                                version=self.window_version)

        # ---- level 2: straight from the cached count matrix ----------------
        t0 = time.perf_counter()
        csub = self.cooc[np.ix_(items, items)]
        iu, ju, c2 = frequent_pairs(csub, abs_min_sup)
        if iu.size:
            res = self.engine.expand(
                self.ring.device,
                items[iu].astype(np.int32), items[ju].astype(np.int32),
                sup1[iu].astype(np.int32),
                mode=eng.MODE_TIDSET, min_sup=abs_min_sup,
                device_of_pair=part_to_dev[table[iu]],
            )
            # pairs were pre-filtered by the exact cached counts, so the
            # engine must confirm every one; disagreement means the
            # incremental state is corrupt and every further window would be
            # silently wrong.  A real exception, not an ``assert`` — this
            # must also fire under ``python -O``.
            if not res.mask.all():
                bad = np.nonzero(~res.mask)[0]
                raise RuntimeError(
                    f"cached co-occurrence counts disagree with the engine "
                    f"on {bad.size}/{res.mask.size} level-2 pair(s) "
                    f"(first: items {int(items[iu[bad[0]]])},"
                    f"{int(items[ju[bad[0]]])}) — incremental window state "
                    f"is corrupt")
            sup2 = res.supports.astype(np.int64)
            lvl_bitmaps = res.bitmaps
        else:
            sup2 = np.zeros(0, np.int64)
            lvl_bitmaps = jnp.zeros((0, self.ring.n_words), jnp.uint32)
        partition = table[iu] if iu.size else np.zeros(0, np.int64)
        store.add_level(LevelRecord(k=2, parent=iu.copy(), item_rank=ju.copy(),
                                    support=sup2, partition=partition))
        stats["phase_s"]["level2"] = time.perf_counter() - t0

        # ---- levels >= 3: the shared per-class bottom-up loop --------------
        # level-2 read from the cached counts, deep expansion not yet run
        kill_point("miner:pre_deep_expand")
        t0 = time.perf_counter()
        run_bottom_up(self.engine, store, lvl_bitmaps,
                      class_id=iu.copy(), item_rank=ju.copy(),
                      partition=partition, support=sup2,
                      abs_min_sup=abs_min_sup, mode=eng.MODE_TIDSET,
                      max_k=max_k, part_to_dev=part_to_dev)
        stats["phase_s"]["bottom_up"] = time.perf_counter() - t0
        # engine counters are lifetime-cumulative; report this slide's delta
        stats.update(self.engine.stats(since=engine_snap))
        stats["total_s"] = time.perf_counter() - t_start
        return WindowResult(store=store, n_txn=n_txn, stats=stats,
                            version=self.window_version)

    def advance(self, batch: Sequence[Sequence[int]]) -> WindowResult:
        """One window slide: admit the micro-batch, then re-mine."""
        push_stats = self.push(batch)
        result = self.mine_window()
        result.stats.update(push_stats)
        result.stats["slide_s"] = push_stats["push_s"] + result.stats["total_s"]
        return result

    def window_transactions(self) -> List[List[int]]:
        """Live window contents (for parity checks against batch mining)."""
        return self.ring.window_transactions()

    # -- serializable state (DESIGN.md §10) ---------------------------------

    def snapshot_state(self) -> MinerState:
        """Deep-copied logical state of the whole miner; safe to hand to an
        async checkpoint writer while the stream keeps sliding."""
        return MinerState(
            n_items=self.n_items,
            config=dataclasses.asdict(self.config),
            ring=self.ring.snapshot_state(),
            engine=self.engine.snapshot_state(),
            cooc=self.cooc.copy(),
            prev_frequent=(None if self._prev_frequent is None
                           else self._prev_frequent.copy()),
            window_version=int(self.window_version))

    @classmethod
    def from_state(cls, state: MinerState,
                   mesh: Optional[jax.sharding.Mesh] = None,
                   *, backend: Optional[str] = None,
                   shard: Optional[str] = None,
                   keep_transactions: Optional[bool] = None) -> "StreamingMiner":
        """Rebuild a miner from a snapshot, possibly re-meshed.

        ``mesh`` is whatever the restoring process brings — fewer devices, a
        different grid factorization, or ``None`` for single-device — and
        ``backend`` / ``shard`` override the snapshot's config for
        cross-family moves (e.g. a ``tidsharded`` checkpoint restored as
        plain ``pallas``).  All device placement is re-derived from the
        logical state under the new mesh, so the restored miner's itemsets
        are bit-exact with the snapshot's lineage (tests/test_faultinject.py
        holds every backend to it).
        """
        fields = {f.name for f in dataclasses.fields(StreamConfig)}
        cfg_kw = {k: v for k, v in dict(state.config).items() if k in fields}
        if backend is not None:
            cfg_kw["backend"] = backend
        if shard is not None:
            cfg_kw["shard"] = shard
        cfg = StreamConfig(**cfg_kw)
        keep = (state.ring.txns is not None if keep_transactions is None
                else keep_transactions)
        miner = cls(state.n_items, cfg, mesh=mesh, keep_transactions=keep)
        miner.ring.restore_state(state.ring)
        miner.cooc = np.array(state.cooc, np.int64, copy=True)
        miner._prev_frequent = (None if state.prev_frequent is None
                                else np.asarray(state.prev_frequent,
                                                np.int64).copy())
        miner.window_version = int(state.window_version)
        miner.engine.restore_state(state.engine)
        return miner
