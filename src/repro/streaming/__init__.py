"""repro.streaming — sliding-window incremental Eclat over micro-batches.

The window is a device-resident ring of packed word-blocks (``WindowRing``);
``StreamingMiner`` maintains per-item supports and the co-occurrence count
matrix incrementally (block deltas) and re-expands only the active
equivalence classes through the ``core.engine`` backend interface.  Windowed
results are bit-exact with batch ``core.eclat.mine`` over the same window
contents (DESIGN.md §5).  The miner's state is serializable
(``MinerState``/``RingState``, DESIGN.md §10): ``StreamCheckpointer`` writes
periodic async snapshots and ``restore_miner`` rebuilds — on a different
mesh factorization if the restoring process brings one.
"""
from .miner import MinerState, StreamConfig, StreamingMiner, WindowResult
from .persist import StreamCheckpointer, peek_config, restore_miner
from .window import RingState, WindowRing

__all__ = ["StreamConfig", "StreamingMiner", "WindowResult", "WindowRing",
           "MinerState", "RingState", "StreamCheckpointer", "restore_miner",
           "peek_config"]
