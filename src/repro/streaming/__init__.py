"""repro.streaming — sliding-window incremental Eclat over micro-batches.

The window is a device-resident ring of packed word-blocks (``WindowRing``);
``StreamingMiner`` maintains per-item supports and the co-occurrence count
matrix incrementally (block deltas) and re-expands only the active
equivalence classes through the ``core.engine`` backend interface.  Windowed
results are bit-exact with batch ``core.eclat.mine`` over the same window
contents (DESIGN.md §5).
"""
from .miner import StreamConfig, StreamingMiner, WindowResult
from .window import WindowRing

__all__ = ["StreamConfig", "StreamingMiner", "WindowResult", "WindowRing"]
