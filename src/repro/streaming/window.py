"""Sliding window of transactions as a device-resident ring of word-blocks.

The batch miner packs the whole database once (``bitmap.pack_transactions``)
and repacks from scratch on every change.  A sliding window makes that repack
the dominant cost, so the window is kept as a *ring of word-blocks* instead:

    ring[i, b*wpb : (b+1)*wpb]   words of block b for item i

Each micro-batch of transactions is packed into one block (``wpb`` uint32
words = ``block_txns`` transaction columns) and written over the expired
block *in place* with one ``dynamic_update_slice`` — the rest of the window
bitmap never moves, on host or device.  Support counting and intersection are
per-word elementwise, so the physical word order of the ring (which wraps)
never matters: any column permutation and any all-zero pad column leaves
every support unchanged.  That invariance is what makes the ring bit-exact
with a batch ``mine()`` over the same window contents (DESIGN.md §5).

The ring keeps a host mirror of the packed words so per-item support deltas
and the evicted block's co-occurrence delta can be formed without reading the
device array back.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from ..core import bitmap as bm

__all__ = ["WindowRing"]


@partial(jax.jit, donate_argnums=(0,))
def _write_block_jit(ring: jax.Array, block: jax.Array, start: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice_in_dim(ring, block, start, axis=1)


def _write_block(ring: jax.Array, block: jax.Array, start: jax.Array) -> jax.Array:
    """Overwrite one block's word span in place (``ring`` is donated so the
    slide is a true in-place update on TPU/GPU; CPU has no donation and
    would warn once per compile — suppressed here, for this call only)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _write_block_jit(ring, block, start)


class WindowRing:
    """Fixed-capacity sliding window: ``n_blocks`` blocks of ``block_txns``
    transaction columns each (``block_txns`` must be a multiple of 32 so block
    boundaries are word boundaries).

    ``push(batch)`` packs the micro-batch into the next ring slot, evicting
    whatever block occupied it, and returns the (new, old) packed blocks so
    the caller can form incremental support/co-occurrence deltas.
    """

    def __init__(self, n_items: int, n_blocks: int, block_txns: int,
                 keep_transactions: bool = True):
        if n_blocks < 1:
            raise ValueError("need at least one block")
        if block_txns < bm.WORD_BITS or block_txns % bm.WORD_BITS:
            raise ValueError(f"block_txns must be a positive multiple of "
                             f"{bm.WORD_BITS}, got {block_txns}")
        self.n_items = int(n_items)
        self.n_blocks = int(n_blocks)
        self.block_txns = int(block_txns)
        self.wpb = block_txns // bm.WORD_BITS          # words per block
        self.n_words = self.n_blocks * self.wpb
        self.words = np.zeros((self.n_items, self.n_words), np.uint32)
        self.device = jnp.zeros((self.n_items, self.n_words), jnp.uint32)
        self.block_counts = np.zeros(self.n_blocks, np.int64)  # txns per slot
        self.head = 0            # next slot to (over)write
        self.filled = 0          # slots holding live data
        self.n_advances = 0
        self._txns: Optional[List[List[Sequence[int]]]] = (
            [[] for _ in range(self.n_blocks)] if keep_transactions else None)

    # -- geometry -----------------------------------------------------------

    @property
    def n_txn(self) -> int:
        """Live transactions in the window (pad columns excluded)."""
        return int(self.block_counts.sum())

    @property
    def full(self) -> bool:
        return self.filled == self.n_blocks

    def _slot_span(self, slot: int) -> slice:
        return slice(slot * self.wpb, (slot + 1) * self.wpb)

    # -- the one mutating operation -----------------------------------------

    def push(self, batch: Sequence[Sequence[int]]):
        """Admit one micro-batch, evicting the expired block in place.

        Returns ``(new_block, old_block, n_evicted)`` — both ``(n_items, wpb)``
        uint32 host arrays (``old_block`` is all-zero while the window is
        still warming up).
        """
        if len(batch) > self.block_txns:
            raise ValueError(f"micro-batch of {len(batch)} txns exceeds "
                             f"block capacity {self.block_txns}")
        new_block = bm.pack_transactions(batch, self.n_items)
        if new_block.shape[1] < self.wpb:   # partial batch: zero-pad columns
            new_block = np.pad(
                new_block, ((0, 0), (0, self.wpb - new_block.shape[1])))
        slot = self.head
        span = self._slot_span(slot)
        old_block = self.words[:, span].copy()
        n_evicted = int(self.block_counts[slot])
        self.words[:, span] = new_block
        self.device = _write_block(self.device, jnp.asarray(new_block),
                                   jnp.int32(slot * self.wpb))
        self.block_counts[slot] = len(batch)
        if self._txns is not None:
            self._txns[slot] = [list(t) for t in batch]
        self.head = (self.head + 1) % self.n_blocks
        self.filled = min(self.filled + 1, self.n_blocks)
        self.n_advances += 1
        return new_block, old_block, n_evicted

    # -- introspection (tests / bench comparators) --------------------------

    def window_transactions(self) -> List[List[int]]:
        """The window's live transactions, oldest block first (requires
        ``keep_transactions=True``)."""
        if self._txns is None:
            raise RuntimeError("ring was built with keep_transactions=False")
        out: List[List[int]] = []
        oldest = self.head if self.full else 0
        for i in range(self.filled):
            slot = (oldest + i) % self.n_blocks
            out.extend(list(t) for t in self._txns[slot])
        return out

    def validate(self) -> None:
        """Host mirror == device ring, supports consistent (test hook)."""
        np.testing.assert_array_equal(np.asarray(self.device), self.words)
